#!/usr/bin/env bash
# CI gate for the Arrow reproduction.
#
#   ./ci.sh          # fmt check, release build, tests, simulator smoke bench
#   ./ci.sh --fast   # skip the bench gate
#
# The bench gate runs `benches/simulator.rs` in smoke mode, which exits
# non-zero if the Arrow system drops below 1M events/s on the clipped
# azure_code workload (override with ARROW_BENCH_MIN_EPS).
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check =="
# Advisory until the tree is confirmed rustfmt-clean (the seed predates
# any manifest, so it was never formatted); flip to strict by removing
# the `|| ...` fallback.
cargo fmt --check || echo "WARN: rustfmt drift — run 'cargo fmt' (non-fatal for now)"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--fast" ]]; then
    echo "== simulator bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT=/tmp/BENCH_simulator_smoke.json \
        cargo bench --bench simulator

    # Scheduler decision-latency gate: exits non-zero if any placement
    # decision path drops below ARROW_BENCH_MIN_DPS decisions/s. Emits
    # BENCH_scheduler.json (tracked PR over PR, like BENCH_simulator.json).
    echo "== scheduler bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT=BENCH_scheduler.json \
        cargo bench --bench scheduler
fi

echo "CI OK"
