#!/usr/bin/env bash
# CI gate for the Arrow reproduction.
#
#   ./ci.sh          # fmt check, builds, debug+release tests, bench gates
#   ./ci.sh --fast   # skip the bench gates
#
# The bench gates run `benches/simulator.rs`, `benches/scheduler.rs`,
# and `benches/scale.rs` in smoke mode, which exit non-zero if the Arrow
# system drops below 1M events/s (override: ARROW_BENCH_MIN_EPS), any
# placement path below 10k decisions/s (override: ARROW_BENCH_MIN_DPS),
# quiescent placement decisions/s at 256 instances falls below 0.5x the
# 4-instance rate (override: ARROW_BENCH_MIN_FLATNESS), or churned
# placement at 256 instances below 50k/s (ARROW_BENCH_MIN_CHURN_DPS).
# Each fresh BENCH_*.json is then diffed against the committed baseline
# with `benchdiff` (PR 4): >20% regression on the headline metric fails
# CI; placeholder or mode-mismatched baselines skip with a warning
# (ROADMAP open item). The paper-claims conformance gate (PR 5) then
# runs `arrow claims` in smoke mode: all 8 systems (the paper's six plus
# the PR-10 scheduling adversaries deflect/unified) x all Table-1
# workloads under CostModel::normalized(), exiting non-zero when any
# paper claim fails. The chaos gate (PR 6) runs `arrow chaos` in smoke
# mode: seeded fault plans against the recovery-armed cluster, exiting
# non-zero when a robustness invariant (no silent loss, determinism,
# goodput bound, recovery) fails. The sweep gate (PR 7) runs
# `benches/sweep.rs` in smoke mode: streamed 1M- and 10M-request runs
# through a counting allocator, exiting non-zero when the 10M-request
# peak allocation exceeds 1.1x the 1M-request peak
# (ARROW_SWEEP_MAX_MEM_RATIO) or throughput drops below 1M events/s;
# request counts shrink via ARROW_SWEEP_BASE_REQS / ARROW_SWEEP_REQS
# on slow hardware. The flight-recorder gate (PR 9) records a demo
# journal and replays it through both scheduling oracles, exiting
# non-zero on any decision divergence; the loadgen gate (PR 9) runs the
# open-loop soak self-test and diffs BENCH_server.json.
set -euo pipefail
cd "$(dirname "$0")/rust"

# Fail loudly — not silently — when the toolchain is absent. Authoring
# containers without Rust previously made CI look green while nothing
# compiled; that must be an error, never a skip.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: no Rust toolchain on PATH (cargo not found) — CI cannot run." >&2
    echo "       Install rustup or run inside the build image." >&2
    exit 1
fi

echo "== cargo fmt --check =="
# Advisory until the tree is confirmed rustfmt-clean (the seed predates
# any manifest, so it was never formatted); flip to strict by removing
# the `|| ...` fallback.
cargo fmt --check || echo "WARN: rustfmt drift — run 'cargo fmt' (non-fatal for now)"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (debug) =="
cargo test -q

# The bench gates run the release profile (lto=thin, codegen-units=1);
# test it too so profile-specific miscompiles/overflow behavior can't
# hide behind a debug-only test pass.
echo "== cargo test --release -q =="
cargo test --release -q

# Quarantine visibility (PR 5): print the #[ignore]d test count so a
# growing quarantine is loud in CI output. The claims tier exists to
# shrink this number — it should only ever contain hardware-calibrated
# variants that need a real testbed (`arrow calibrate`).
echo "== ignored (quarantined) tests =="
ignored=$( (cargo test --release -q -- --list --ignored 2>/dev/null || true) | grep -c ': test' || true)
echo "ignored tests: ${ignored} (expected: only the *_h800 calibrated variants)"

# The golden-schedule gate only bites across commits once the recorded
# digests are committed; the first test run self-records them (see
# tests/golden_schedule.rs), a human must `git add` the file.
if ! git ls-files --error-unmatch tests/golden/schedule_digests.json >/dev/null 2>&1; then
    echo "WARN: rust/tests/golden/schedule_digests.json is not committed —" >&2
    echo "      the cross-commit schedule-regression gate is INERT until it is." >&2
    echo "      Commit the file recorded by this test run." >&2
fi

if [[ "${1:-}" != "--fast" ]]; then
    # Smoke outputs go to a per-run temp dir: never clobbers the
    # committed BENCH_*.json baselines the diff below reads, and never
    # races another ci.sh run on a shared host.
    smoke_dir="$(mktemp -d "${TMPDIR:-/tmp}/arrow-bench-smoke.XXXXXX")"
    trap 'rm -rf "$smoke_dir"' EXIT

    echo "== simulator bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT="$smoke_dir/BENCH_simulator.json" \
        cargo bench --bench simulator

    # Scheduler decision-latency gate: exits non-zero if any placement
    # decision path drops below ARROW_BENCH_MIN_DPS decisions/s.
    echo "== scheduler bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT="$smoke_dir/BENCH_scheduler.json" \
        cargo bench --bench scheduler

    # Scale gate (PR 4): quiescent placement decisions/s must stay flat
    # (ARROW_BENCH_MIN_FLATNESS, default 0.5x) from 4 -> 256 instances,
    # churned placement above ARROW_BENCH_MIN_CHURN_DPS at 256.
    echo "== scale bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT="$smoke_dir/BENCH_scale.json" \
        cargo bench --bench scale

    # Streaming-sweep memory gate (PR 7): 1M- then 10M-request streamed
    # runs through the counting allocator; peak allocation must stay
    # within ARROW_SWEEP_MAX_MEM_RATIO (default 1.1x) of the 1M run
    # while holding ARROW_BENCH_MIN_EPS events/s. This is the longest
    # bench gate (~10M requests end to end); trim with
    # ARROW_SWEEP_BASE_REQS / ARROW_SWEEP_REQS if the host is slow.
    echo "== sweep bench (memory-flatness smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT="$smoke_dir/BENCH_sweep.json" \
        cargo bench --bench sweep

    # Regression diff against the committed baselines (>20% drop on the
    # headline metric fails — for the sweep family a >20% peak-allocation
    # *rise* fails too; placeholder/missing baselines warn + skip).
    echo "== bench baseline comparison =="
    for fam in simulator scheduler scale sweep; do
        cargo run --release -q --bin benchdiff -- \
            "BENCH_${fam}.json" "$smoke_dir/BENCH_${fam}.json"
    done

    # Paper-claims conformance gate (PR 5): the normalized-cost-model
    # claims sweep in smoke mode (capped clips + coarse rate grid, all
    # 8 systems x all Table-1 workloads — the paper's six plus the PR-10
    # adversaries deflect/unified). `arrow claims` exits non-zero when
    # any paper claim fails; the full report lands next to the bench
    # smoke outputs.
    echo "== paper-claims conformance (smoke gate) =="
    ARROW_CLAIMS_SMOKE=1 cargo run --release -q --bin arrow -- \
        claims --out "$smoke_dir/claims"

    # Claims-report drift diff (PR 8): the headline is the count of
    # *core* holding claims — slo_class:* (PR 8) and deflect:*/unified:*
    # (PR 10) claims are excluded by benchdiff so a baseline committed
    # before those claims existed still compares like-for-like.
    # Warn-skips until a smoke claims.json baseline is committed at the
    # repo root.
    cargo run --release -q --bin benchdiff -- \
        claims.json "$smoke_dir/claims/claims.json"

    # Chaos conformance gate (PR 6): seeded fault plans (flaps,
    # stragglers, stalls, crash-rejoins) swept against the recovery-armed
    # Arrow cluster in smoke mode. `arrow chaos` exits non-zero when a
    # robustness invariant fails — a silently lost request, a
    # nondeterministic faulted schedule, a goodput inversion, or a
    # post-fault recovery shortfall.
    echo "== chaos conformance (smoke gate) =="
    ARROW_CHAOS_SMOKE=1 cargo run --release -q --bin arrow -- \
        chaos --out "$smoke_dir/chaos"

    # Flight-recorder gate (PR 9): record a deterministic demo journal,
    # then replay it through the server-view oracle and again with the
    # simulator-substrate oracle. `arrow replay <journal>` exits non-zero
    # on any divergence between a recorded decision and its re-derived
    # counterpart (placement, pool states, flip count).
    echo "== record/replay (smoke gate) =="
    cargo run --release -q --bin arrow -- \
        replay --record-demo "$smoke_dir/demo.arwj" --seed 42 --steps 400
    cargo run --release -q --bin arrow -- \
        replay "$smoke_dir/demo.arwj" --verify
    cargo run --release -q --bin arrow -- \
        replay "$smoke_dir/demo.arwj" --verify --sim

    # Open-loop soak smoke (PR 9): the loadgen self-test drives the full
    # pipeline (Poisson pacer, worker pool, ledger, /metrics cross-check)
    # against the in-process stub server — exits non-zero on silent loss,
    # shed-ledger mismatch, or SLO-attainment shortfall. The emitted
    # BENCH_server.json then diffs against the committed baseline
    # (sustained RPS higher-is-better, p99 TTFT lower-is-better).
    echo "== loadgen soak (self-test smoke gate) =="
    cargo run --release -q --bin arrow -- \
        loadgen --self-test --smoke --rps 200 --duration 2 \
        --out "$smoke_dir/BENCH_server.json"
    cargo run --release -q --bin benchdiff -- \
        BENCH_server.json "$smoke_dir/BENCH_server.json"
fi

echo "CI OK"
