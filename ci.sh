#!/usr/bin/env bash
# CI gate for the Arrow reproduction.
#
#   ./ci.sh          # fmt check, builds, debug+release tests, bench gates
#   ./ci.sh --fast   # skip the bench gates
#
# The bench gates run `benches/simulator.rs` and `benches/scheduler.rs`
# in smoke mode, which exit non-zero if the Arrow system drops below
# 1M events/s (override: ARROW_BENCH_MIN_EPS) or any placement path
# below 10k decisions/s (override: ARROW_BENCH_MIN_DPS).
set -euo pipefail
cd "$(dirname "$0")/rust"

# Fail loudly — not silently — when the toolchain is absent. Authoring
# containers without Rust previously made CI look green while nothing
# compiled; that must be an error, never a skip.
if ! command -v cargo >/dev/null 2>&1; then
    echo "ERROR: no Rust toolchain on PATH (cargo not found) — CI cannot run." >&2
    echo "       Install rustup or run inside the build image." >&2
    exit 1
fi

echo "== cargo fmt --check =="
# Advisory until the tree is confirmed rustfmt-clean (the seed predates
# any manifest, so it was never formatted); flip to strict by removing
# the `|| ...` fallback.
cargo fmt --check || echo "WARN: rustfmt drift — run 'cargo fmt' (non-fatal for now)"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (debug) =="
cargo test -q

# The bench gates run the release profile (lto=thin, codegen-units=1);
# test it too so profile-specific miscompiles/overflow behavior can't
# hide behind a debug-only test pass.
echo "== cargo test --release -q =="
cargo test --release -q

# The golden-schedule gate only bites across commits once the recorded
# digests are committed; the first test run self-records them (see
# tests/golden_schedule.rs), a human must `git add` the file.
if ! git ls-files --error-unmatch tests/golden/schedule_digests.json >/dev/null 2>&1; then
    echo "WARN: rust/tests/golden/schedule_digests.json is not committed —" >&2
    echo "      the cross-commit schedule-regression gate is INERT until it is." >&2
    echo "      Commit the file recorded by this test run." >&2
fi

if [[ "${1:-}" != "--fast" ]]; then
    echo "== simulator bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT=/tmp/BENCH_simulator_smoke.json \
        cargo bench --bench simulator

    # Scheduler decision-latency gate: exits non-zero if any placement
    # decision path drops below ARROW_BENCH_MIN_DPS decisions/s. Emits
    # BENCH_scheduler.json (tracked PR over PR, like BENCH_simulator.json).
    echo "== scheduler bench (smoke gate) =="
    ARROW_BENCH_SMOKE=1 ARROW_BENCH_OUT=BENCH_scheduler.json \
        cargo bench --bench scheduler
fi

echo "CI OK"
