//! The live server's [`ClusterView`] adapter.
//!
//! The global scheduler's knowledge of a live cluster has two sources
//! (paper Fig. 5): its *own* dispatch bookkeeping — which prefills it
//! sent where and has not yet seen complete — and the engines' lock-free
//! load counters ([`super::engine::EngineStats`]). The coordinator
//! materializes both into an [`EngineSnapshot`] per engine at each
//! decision point; [`ServerView`] then exposes the exact interface the
//! simulator's `SimView` exposes, so `ArrowPolicy` runs unmodified.
//!
//! Fidelity notes (vs. the simulator's omniscient view):
//! * the coordinator does not observe chunk progress, so a queued
//!   prefill's `remaining` equals its `input_len` until `PrefillDone`
//!   arrives — a conservative (upper-bound) queue-delay estimate;
//! * `running_tokens` is the engine's cached-token count plus the KV of
//!   adoptions the engine has accepted but not yet slotted — the live
//!   analog of the simulator's `decode_wait` parking queue. Slot
//!   exhaustion therefore needs no special-case placement rule: a
//!   slot-full engine parks the request (exactly like the simulator)
//!   and its parked load steers `min_running_tokens` elsewhere;
//! * building a snapshot allocates one `Vec` per engine. That is fine
//!   here — live decisions sit next to millisecond model iterations —
//!   and the no-allocation rule (ROADMAP "Scheduling core") binds the
//!   *simulator* adapter, which stays borrow-only.

use crate::sched::{ClusterView, Liveness, PrefillQueueMoments, EPOCH_UNKNOWN};

/// One engine's scheduler-visible state, materialized at decision time.
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    /// `(input_len, remaining)` of every prefill dispatched to this
    /// engine and not yet completed, in dispatch order. Feeds the
    /// queue-walk view (`for_each_queued_prefill`) — which since PR 4
    /// only the debug-mode moments oracle and conformance tests consume.
    /// The live coordinator therefore fills it **in debug builds only**
    /// (release snapshots leave it empty and carry just the O(1)
    /// `moments`); the conformance mirrors always populate it.
    pub queued_prefills: Vec<(u32, u32)>,
    /// O(1) aggregates of `queued_prefills` (PR 4): the coordinator
    /// maintains them incrementally at dispatch / PrefillDone / failure
    /// time with the exact update rules the simulator uses, so equal
    /// queues produce bit-identical placement keys on both substrates.
    pub moments: PrefillQueueMoments,
    /// Chunk the engine's fitted predictor (and therefore `moments`)
    /// prices per-iteration overhead with.
    pub chunk_tokens: u32,
    /// Total KV tokens resident for decode (running-tokens metric).
    pub running_tokens: u64,
    /// KV capacity in tokens.
    pub max_kv_tokens: u64,
    /// Recent token interval (NaN = no evidence).
    pub avg_token_interval: f64,
    /// Any decode slots active (or adoptions pending) on the engine.
    pub has_decode_work: bool,
    /// Cluster-membership state (PR 3): the coordinator's life table,
    /// snapshotted alongside the load counters.
    pub liveness: Liveness,
}

/// [`ClusterView`] over a materialized per-engine snapshot table.
#[derive(Debug, Clone)]
pub struct ServerView {
    pub engines: Vec<EngineSnapshot>,
    /// Change epoch forwarded to policies. Engine load counters advance
    /// asynchronously in engine threads, so the live coordinator can
    /// never claim two *different* snapshots are change-free — it stamps
    /// each materialized snapshot with a fresh monotone value instead,
    /// which still collapses the several policy reads *within* one
    /// decision into the O(1) skip path. Conformance mirrors report
    /// [`EPOCH_UNKNOWN`] (always verify); scripted tests may supply real
    /// epochs to exercise the fast path.
    pub change_epoch: u64,
}

impl ClusterView for ServerView {
    fn n_instances(&self) -> usize {
        self.engines.len()
    }

    fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32)) {
        let e = &self.engines[inst];
        // Live release snapshots carry only the O(1) moments (the queue
        // list is materialized for the debug oracle and conformance
        // mirrors). A walk against an unmaterialized queue must fail
        // loudly — silently pricing every queue as empty would pile all
        // prefills onto one engine. Walks are off the release placement
        // path, so this guard costs nothing where it matters.
        assert!(
            e.queued_prefills.len() as u64 == e.moments.count,
            "queue walk on a snapshot without materialized queues — live release \
             snapshots carry only moments; use prefill_queue_moments()"
        );
        for &(input_len, remaining) in &e.queued_prefills {
            f(input_len, remaining);
        }
    }

    fn prefill_queue_moments(&self, inst: usize) -> PrefillQueueMoments {
        self.engines[inst].moments
    }

    fn prefill_chunk_tokens(&self, inst: usize) -> u32 {
        self.engines[inst].chunk_tokens
    }

    fn change_epoch(&self) -> u64 {
        self.change_epoch
    }

    fn running_tokens(&self, inst: usize) -> u64 {
        self.engines[inst].running_tokens
    }

    fn max_kv_tokens(&self, inst: usize) -> u64 {
        self.engines[inst].max_kv_tokens
    }

    fn avg_token_interval(&self, inst: usize) -> f64 {
        self.engines[inst].avg_token_interval
    }

    fn has_prefill_work(&self, inst: usize) -> bool {
        // From the moments, not the queue list: the live coordinator
        // only materializes `queued_prefills` in debug builds.
        self.engines[inst].moments.count > 0
    }

    fn has_decode_work(&self, inst: usize) -> bool {
        self.engines[inst].has_decode_work
    }

    fn liveness(&self, inst: usize) -> Liveness {
        self.engines[inst].liveness
    }
}

/// Conformance helper: materialize the exact state [`crate::sim::SimView`]
/// exposes over a `SimInstance` table into the server's snapshot form —
/// the "identical snapshot" premise of every cross-substrate test
/// (`tests/cross_substrate.rs`, `tests/prop_pools.rs`). Lives next to
/// [`EngineSnapshot`] so growing the snapshot (as PR 3 did with
/// `liveness`) updates every consumer in one place.
pub fn mirror_sim_instances(insts: &[crate::engine::SimInstance]) -> ServerView {
    ServerView {
        engines: insts
            .iter()
            .map(|i| EngineSnapshot {
                queued_prefills: i.prefill_queue_iter().collect(),
                // The instance's incrementally maintained aggregates are
                // copied verbatim — integer moments are path-independent,
                // so a coordinator rebuilding them from the queue view
                // lands on the same bits (tests/prop_predictor.rs).
                moments: i.prefill_queue_moments(),
                chunk_tokens: i.chunk_tokens,
                running_tokens: i.running_tokens(),
                max_kv_tokens: i.cost.max_kv_tokens,
                avg_token_interval: i.avg_token_interval(),
                has_decode_work: i.has_decode_work(),
                liveness: i.life,
            })
            .collect(),
        change_epoch: EPOCH_UNKNOWN,
    }
}

/// Conformance helper: the startup profile a live coordinator would hand
/// its policy, frozen from the same knowledge `sim::SimView` profiles —
/// so sim-side and server-side policies start byte-identical.
pub fn profile_sim_instances(
    insts: &[crate::engine::SimInstance],
    tpot_slo: f64,
) -> crate::sched::FixedProfile {
    use crate::sched::ProfileSource;
    let v = crate::sim::SimView(insts);
    crate::sched::FixedProfile {
        predictors: (0..insts.len()).map(|i| v.fit_predictor(i)).collect(),
        max_running_tokens: (0..insts.len())
            .map(|i| ProfileSource::max_running_tokens(&v, i, tpot_slo))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(queued: Vec<(u32, u32)>, running: u64, decode: bool) -> EngineSnapshot {
        let chunk = crate::sched::DEFAULT_CHUNK_TOKENS;
        let mut moments = PrefillQueueMoments::default();
        for &(l, r) in &queued {
            moments.add_task(l, r, chunk);
        }
        EngineSnapshot {
            queued_prefills: queued,
            moments,
            chunk_tokens: chunk,
            running_tokens: running,
            max_kv_tokens: 1000,
            avg_token_interval: f64::NAN,
            has_decode_work: decode,
            liveness: Liveness::Active,
        }
    }

    #[test]
    fn view_reads_snapshot_table() {
        let v = ServerView {
            engines: vec![snap(vec![(100, 100), (50, 50)], 0, false), snap(vec![], 70, true)],
            change_epoch: EPOCH_UNKNOWN,
        };
        assert_eq!(ClusterView::n_instances(&v), 2);
        assert_eq!(v.queued_prefill_tokens(0), 150);
        assert!(v.has_prefill_work(0) && !v.has_decode_work(0));
        assert!(!v.has_prefill_work(1) && v.has_decode_work(1));
        assert_eq!(v.running_tokens(1), 70);
        assert!(!v.is_idle(0) && !v.is_idle(1));
        let mut order = Vec::new();
        v.for_each_queued_prefill(0, &mut |l, r| order.push((l, r)));
        assert_eq!(order, vec![(100, 100), (50, 50)]);
        // The snapshot's maintained moments are what the view serves, and
        // they agree with the walk-derived oracle.
        assert_eq!(
            v.prefill_queue_moments(0),
            PrefillQueueMoments::derive_walk(&v, 0)
        );
        assert_eq!(v.change_epoch(), EPOCH_UNKNOWN);
    }

    #[test]
    fn liveness_surfaces_through_the_view() {
        let mut draining = snap(vec![], 10, true);
        draining.liveness = Liveness::Draining;
        let mut dead = snap(vec![], 0, false);
        dead.liveness = Liveness::Dead;
        let v = ServerView {
            engines: vec![snap(vec![], 0, false), draining, dead],
            change_epoch: EPOCH_UNKNOWN,
        };
        assert!(v.liveness(0).placeable() && v.liveness(0).in_cluster());
        assert!(!v.liveness(1).placeable() && v.liveness(1).in_cluster());
        assert!(!v.liveness(2).placeable() && !v.liveness(2).in_cluster());
    }
}
