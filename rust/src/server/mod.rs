//! Real-mode serving: N stateless PJRT engines + the Arrow-style global
//! scheduler + an OpenAI-ish HTTP frontend. Python is never on this path —
//! engines execute the AOT artifacts directly.
//!
//! This is the end-to-end composition proof (DESIGN.md §7): the same
//! stateless-instance mechanism as the simulator — engines accept both
//! phases, prefill KV is handed off (possibly across engines: a real
//! memcpy through the coordinator = the KV migration), decode runs under
//! continuous batching — with wall-clock latencies reported per request.

pub mod engine;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::predictor::TtftPredictor;
use crate::http::{self, HttpRequest, HttpResponse};
use crate::json::Json;
use engine::{EngineCmd, EngineEvent, EngineHandle, EngineStats};

/// `arrow serve` configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub port: u16,
    pub instances: usize,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
}

/// Completed-request latency record for /metrics.
#[derive(Debug, Clone)]
struct Done {
    ttft_s: f64,
    tpot_s: f64,
    tokens: usize,
}

struct Coordinator {
    engines: Vec<EngineHandle>,
    events: mpsc::Receiver<EngineEvent>,
    /// Per-request completion channels for HTTP handlers.
    waiters: Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>>,
    /// Request start times + max_tokens.
    inflight: HashMap<u64, (Instant, usize)>,
    done: Arc<Mutex<Vec<Done>>>,
}

impl Coordinator {
    /// Pick the prefill engine: least queued prefill work (Arrow's
    /// minimum-load rule, using live engine stats).
    fn pick_prefill(stats: &[EngineStats]) -> usize {
        stats
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| s.prefill_queue)
            .map(|(i, _)| i)
            .unwrap()
    }

    /// Pick the decode engine: least cached tokens with a free slot; the
    /// prefill engine itself wins ties (local handoff = no migration).
    fn pick_decode(stats: &[EngineStats], prefill_engine: usize) -> usize {
        let best = stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.free_slots > 0)
            .min_by_key(|(i, s)| (s.cached_tokens, usize::from(*i != prefill_engine)))
            .map(|(i, _)| i);
        best.unwrap_or(prefill_engine)
    }

    /// Handle one engine event (decode placement / completion routing).
    fn handle(&mut self, ev: EngineEvent) {
        match ev {
            EngineEvent::PrefillDone {
                req,
                engine,
                prompt_len,
                first_token,
                k,
                v,
                bucket,
            } => {
                // Place the decode phase (Arrow Alg. 2's shape: min cached
                // tokens with a free slot, prefer local handoff).
                let stats: Vec<EngineStats> =
                    self.engines.iter().map(|e| e.stats()).collect();
                let target = Self::pick_decode(&stats, engine);
                let max_tokens = self.inflight.get(&req).map(|x| x.1).unwrap_or(1);
                if max_tokens <= 1 {
                    self.finish(req, vec![first_token]);
                    return;
                }
                // KV migration: the slab moves through the coordinator (a
                // real memcpy between engines when target != source).
                self.engines[target]
                    .send(EngineCmd::StartDecode {
                        req,
                        prompt_len,
                        first_token,
                        k,
                        v,
                        bucket,
                        remaining: max_tokens - 1,
                    })
                    .ok();
            }
            EngineEvent::DecodeDone { req, tokens } => self.finish(req, tokens),
            EngineEvent::Failed { req, error } => {
                eprintln!("request {req} failed: {error}");
                self.finish(req, Vec::new());
            }
        }
    }

    fn finish(&mut self, req: u64, tokens: Vec<i32>) {
        let (start, _) = match self.inflight.remove(&req) {
            Some(x) => x,
            None => return,
        };
        let total = start.elapsed().as_secs_f64();
        // TTFT approximated at coordinator level by the engine-reported
        // spans; for the summary we report total/time-per-token.
        let n = tokens.len().max(1);
        let tpot = if n > 1 { total / (n - 1) as f64 } else { 0.0 };
        self.done.lock().unwrap().push(Done {
            ttft_s: total - tpot * (n - 1) as f64,
            tpot_s: tpot,
            tokens: n,
        });
        if let Some(tx) = self.waiters.lock().unwrap().remove(&req) {
            let _ = tx.send((tokens, total, tpot));
        }
    }
}

/// Start engines + coordinator + HTTP frontend; blocks forever (Ctrl-C to
/// stop). Returns early only on startup errors.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let (event_tx, event_rx) = mpsc::channel::<EngineEvent>();
    println!(
        "loading {} engine(s) from {} ...",
        cfg.instances, cfg.artifacts_dir
    );
    let mut engines = Vec::new();
    for i in 0..cfg.instances {
        engines.push(EngineHandle::spawn(
            i,
            &cfg.artifacts_dir,
            event_tx.clone(),
        )?);
        println!("  engine {i} ready");
    }
    // Startup profiling — the paper's TTFT-predictor fit, on real timings.
    let predictor = profile_predictor(&engines[0]);
    println!(
        "ttft predictor coefficients: {:?}",
        predictor.coefficients()
    );

    let waiters: Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let done: Arc<Mutex<Vec<Done>>> = Arc::new(Mutex::new(Vec::new()));
    let next_id = Arc::new(AtomicU64::new(1));

    let coord = Coordinator {
        engines: engines.iter().map(|e| e.clone_handle()).collect(),
        events: event_rx,
        waiters: Arc::clone(&waiters),
        inflight: HashMap::new(),
        done: Arc::clone(&done),
    };
    // Coordinator needs mutable inflight bookkeeping; submissions flow to
    // it through a channel.
    let (submit_tx, submit_rx) = mpsc::channel::<(u64, usize, Instant)>();
    let engines_for_http: Vec<EngineHandle> =
        engines.iter().map(|e| e.clone_handle()).collect();
    std::thread::spawn(move || {
        let mut coord = coord;
        loop {
            // Register new submissions, then handle one engine event.
            while let Ok((req, max_tokens, t0)) = submit_rx.try_recv() {
                coord.inflight.insert(req, (t0, max_tokens));
            }
            match coord
                .events
                .recv_timeout(std::time::Duration::from_millis(20))
            {
                Ok(ev) => {
                    // Re-drain in case a submission raced its own event.
                    while let Ok((req, max_tokens, t0)) = submit_rx.try_recv() {
                        coord.inflight.insert(req, (t0, max_tokens));
                    }
                    coord.handle(ev);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }
    });

    let shutdown = Arc::new(AtomicBool::new(false));
    let addr = format!("0.0.0.0:{}", cfg.port);
    let waiters_http = Arc::clone(&waiters);
    let done_http = Arc::clone(&done);
    let cfg_http = cfg.clone();
    http::serve(&addr, shutdown, move |req| {
        route(
            req,
            &engines_for_http,
            &waiters_http,
            &done_http,
            &next_id,
            &submit_tx,
            &cfg_http,
        )
    })?;
    Ok(())
}

fn profile_predictor(engine: &EngineHandle) -> TtftPredictor {
    // Time real prefills at each bucket through the engine, then fit.
    let mut samples: Vec<(f64, f64)> = Vec::new();
    for bucket in engine.buckets() {
        let prompt: Vec<i32> = (0..bucket as i32).map(|i| i % 97 + 1).collect();
        let t0 = Instant::now();
        if engine.blocking_prefill(&prompt).is_ok() {
            samples.push((bucket as f64, t0.elapsed().as_secs_f64()));
        }
    }
    if samples.len() >= 3 {
        let xs: Vec<f64> = samples.iter().map(|s| s.0).collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.1).collect();
        TtftPredictor::from_coefficients(
            crate::util::stats::quadratic_fit(&xs, &ys),
            2048,
            0.001,
        )
    } else {
        TtftPredictor::from_coefficients([0.01, 1e-4, 0.0], 2048, 0.001)
    }
}

#[allow(clippy::too_many_arguments)]
fn route(
    req: &HttpRequest,
    engines: &[EngineHandle],
    waiters: &Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>>,
    done: &Arc<Mutex<Vec<Done>>>,
    next_id: &Arc<AtomicU64>,
    submit: &mpsc::Sender<(u64, usize, Instant)>,
    cfg: &ServeConfig,
) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::text(200, "ok"),
        ("GET", "/metrics") => {
            let d = done.lock().unwrap();
            let ttfts: Vec<f64> = d.iter().map(|x| x.ttft_s).collect();
            let tpots: Vec<f64> = d.iter().map(|x| x.tpot_s).collect();
            let total_tokens: usize = d.iter().map(|x| x.tokens).sum();
            let stats: Vec<Json> = engines
                .iter()
                .map(|e| {
                    let s = e.stats();
                    Json::obj(vec![
                        ("prefill_queue", Json::Num(s.prefill_queue as f64)),
                        ("active_slots", Json::Num(s.active_slots as f64)),
                        ("free_slots", Json::Num(s.free_slots as f64)),
                        ("cached_tokens", Json::Num(s.cached_tokens as f64)),
                        ("iterations", Json::Num(s.iterations as f64)),
                    ])
                })
                .collect();
            let body = Json::obj(vec![
                ("completed_requests", Json::Num(d.len() as f64)),
                ("total_tokens", Json::Num(total_tokens as f64)),
                (
                    "p50_ttft_s",
                    Json::Num(crate::util::stats::percentile(&ttfts, 50.0)),
                ),
                (
                    "p90_ttft_s",
                    Json::Num(crate::util::stats::percentile(&ttfts, 90.0)),
                ),
                (
                    "p90_tpot_s",
                    Json::Num(crate::util::stats::percentile(&tpots, 90.0)),
                ),
                ("ttft_slo", Json::Num(cfg.ttft_slo)),
                ("tpot_slo", Json::Num(cfg.tpot_slo)),
                ("engines", Json::Arr(stats)),
            ]);
            HttpResponse::json(200, &body.encode())
        }
        ("POST", "/v1/completions") => {
            let body = match Json::parse(&req.body_str()) {
                Ok(b) => b,
                Err(e) => {
                    return HttpResponse::json(400, &format!("{{\"error\":\"{e}\"}}"))
                }
            };
            let tokens: Vec<i32> = match body.get("tokens").as_arr() {
                Some(a) => a
                    .iter()
                    .filter_map(|x| x.as_i64().map(|v| v as i32))
                    .collect(),
                None => {
                    return HttpResponse::json(
                        400,
                        "{\"error\":\"missing 'tokens' array\"}",
                    )
                }
            };
            if tokens.is_empty() {
                return HttpResponse::json(400, "{\"error\":\"empty prompt\"}");
            }
            let max_tokens = body.get("max_tokens").as_u64().unwrap_or(16) as usize;

            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let (tx, rx) = mpsc::channel();
            waiters.lock().unwrap().insert(id, tx);
            let t0 = Instant::now();
            submit.send((id, max_tokens, t0)).ok();

            // Prefill placement: least queued prefill (minimum load).
            let stats: Vec<EngineStats> = engines.iter().map(|e| e.stats()).collect();
            let target = Coordinator::pick_prefill(&stats);
            if engines[target]
                .send(EngineCmd::Prefill { req: id, prompt: tokens })
                .is_err()
            {
                return HttpResponse::json(503, "{\"error\":\"engine unavailable\"}");
            }

            match rx.recv_timeout(std::time::Duration::from_secs(120)) {
                Ok((tokens, total_s, tpot_s)) if !tokens.is_empty() => {
                    let out = Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        (
                            "tokens",
                            Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                        ),
                        ("latency_s", Json::Num(total_s)),
                        ("tpot_s", Json::Num(tpot_s)),
                    ]);
                    HttpResponse::json(200, &out.encode())
                }
                Ok(_) => HttpResponse::json(500, "{\"error\":\"request failed\"}"),
                Err(_) => HttpResponse::json(500, "{\"error\":\"timeout\"}"),
            }
        }
        _ => HttpResponse::not_found(),
    }
}
