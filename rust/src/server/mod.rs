//! Real-mode serving: N stateless PJRT engines driven by the *same*
//! Arrow scheduling brain as the simulator, behind an OpenAI-ish HTTP
//! frontend. Python is never on this path — engines execute the AOT
//! artifacts directly.
//!
//! This is the end-to-end composition proof (DESIGN.md §7) and, since
//! PR 2, the point of the whole `sched` layer: the coordinator owns a
//! `Box<dyn Policy>` holding the identical [`ArrowPolicy`] object the
//! simulator runs — elastic pools, Alg. 1–4, the overload policy, and a
//! real monitor-tick thread — fed through the [`view::ServerView`]
//! adapter (coordinator queue bookkeeping + lock-free `EngineStats`).
//! The coordinator contains **no placement heuristic of its own**; a
//! pool flip decided by the policy immediately changes which engine the
//! next request is dispatched to. Prefill KV is handed off (possibly
//! across engines: a real memcpy through the coordinator = the KV
//! migration) and decode runs under continuous batching, with wall-clock
//! TTFT/TPOT reported per request on `/metrics` next to the live pool
//! sizes `[P, D, P→D, D→P]`.

pub mod engine;
pub mod view;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use crate::coordinator::predictor::TtftPredictor;
use crate::http::{self, HttpRequest, HttpResponse};
use crate::json::Json;
use crate::replay;
use crate::request::{InstanceId, Request, SloClass};
use crate::sched::{
    FixedProfile, Liveness, MembershipEvent, Policy, PrefillQueueMoments, EPOCH_UNKNOWN,
};
use engine::{EngineCmd, EngineEvent, EngineHandle};
use view::{EngineSnapshot, ServerView};

/// `arrow serve` configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: String,
    pub port: u16,
    pub instances: usize,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
    /// Shared secret for the destructive `/admin/*` membership endpoints
    /// (`X-Admin-Token` header). `None` disables them entirely — the
    /// server binds 0.0.0.0, so cluster-reshaping operations must never
    /// be an unauthenticated POST away.
    pub admin_token: Option<String>,
    /// Admission control (PR 6, §5.5 overload rule): submissions beyond
    /// this many waiting requests get an immediate 503 instead of piling
    /// onto queues that decode-priority scheduling will not drain soon.
    pub max_inflight: usize,
    /// Per-request deadline on the submit waiter: a request the cluster
    /// cannot finish in time answers 504 instead of hanging the client
    /// socket forever.
    pub request_deadline_s: f64,
    /// Flight-recorder journal (PR 9): when set, every scheduling
    /// decision — placements, ticks, membership — is recorded here for
    /// deterministic offline replay (`arrow replay <journal>`). Recording
    /// never blocks dispatch: under backpressure records are dropped and
    /// counted (`/metrics` `journal_dropped`).
    pub journal_path: Option<String>,
}

/// Poison-tolerant lock (PR 6): a panicking handler thread must not wedge
/// every later `/metrics` read or completion delivery. The guarded data
/// (append-only metric vectors, waiter maps, engine registries) stays
/// structurally valid even when a writer died mid-update, so recovering
/// the guard is strictly better than propagating the poison panic.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Completed-request latency record for /metrics.
#[derive(Debug, Clone)]
struct Done {
    ttft_s: f64,
    tpot_s: f64,
    tokens: usize,
}

/// Everything the coordinator processes, serialized through one channel:
/// new submissions, engine events, monitor ticks, and membership changes.
/// One consumer means the policy needs no locking and decisions are
/// totally ordered — engine registration/deregistration is just another
/// message in the same stream (PR 3 elastic membership).
enum CoordMsg {
    Submit {
        req: u64,
        prompt: Vec<i32>,
        max_tokens: usize,
        t0: Instant,
        /// SLO class (PR 8): carried from the HTTP body into placement
        /// (class-aware Arrow) and engine queue priority.
        class: SloClass,
    },
    Engine(EngineEvent),
    Tick,
    Membership(MembershipCmd),
    /// Operator-injected fault (PR 6 `/admin/inject`): the live analog of
    /// the simulator's `Event::Fault` arm, serialized through the same
    /// single channel so recovery is totally ordered with placements.
    Fault(FaultCmd),
}

/// Faults injectable into the live cluster (PR 6 chaos drills).
enum FaultCmd {
    /// Mark an engine a straggler: stays in the cluster, policies
    /// deprioritize it (what monitor-tick detection would conclude).
    Degrade { engine: usize },
    /// Clear an injected/detected Degraded flag.
    Restore { engine: usize },
    /// Fail an engine now and scale a replacement back in after
    /// `downtime_s` — the live counterpart of `FaultKind::CrashRejoin`.
    /// Stateless instances make the rejoin a plain scale-out: the
    /// replacement takes a fresh slot, work was already re-dispatched.
    CrashRejoin { engine: usize, downtime_s: f64 },
}

/// Operator-triggered membership changes (the `/admin/*` endpoints).
enum MembershipCmd {
    /// Scale-out: load a fresh engine's artifacts on a helper thread
    /// (seconds of work that must not stall dispatch) …
    Join,
    /// … then register the loaded runtime: the only part that runs on
    /// the coordinator thread, where the slot id is assigned.
    Register(Box<crate::runtime::ModelRuntime>),
    /// Retire an engine gracefully: no new placements, shutdown once its
    /// in-flight work completes.
    Drain { engine: usize },
    /// Treat an engine as failed: drop it immediately and re-dispatch
    /// everything it held (decodes restart from prefill — their KV died
    /// with the engine).
    Fail { engine: usize },
}

/// Per-request coordinator bookkeeping.
struct Inflight {
    t0: Instant,
    max_tokens: usize,
    /// The prompt is retained so work lost to an engine failure can be
    /// re-dispatched (stateless instances: any engine can redo it).
    /// Shared with the engine's queue entry — dispatch bumps a refcount
    /// instead of copying a possibly-60k-token prompt.
    prompt: Arc<[i32]>,
    /// Which engine is decoding this request (mirror of the `decoding`
    /// ledger entry, for O(1) removal on completion).
    decode_engine: Option<usize>,
    /// Wall-clock TTFT, recorded when `PrefillDone` arrives.
    first_token_s: Option<f64>,
    /// How many times an engine refused a command for this request (PR 6):
    /// bounded stateless re-placement before the explicit failure answer.
    dispatch_attempts: u32,
    /// SLO class (PR 8): drives class-aware placement targets and the
    /// engine-side prefill queue rank, including on re-dispatch.
    class: SloClass,
}

/// Scheduler state published for `/metrics` (lock-free reads from HTTP
/// handler threads; written by the coordinator thread after every
/// decision and tick). The four pool sizes are packed into one atomic
/// (16 bits each) so a reader can never observe a torn mid-flip vector
/// that fails to partition the engine set.
pub struct SchedPublish {
    pools_packed: AtomicU64,
    flips: AtomicU64,
    /// Per-engine liveness codes (0 = active, 1 = draining, 2 = dead,
    /// 3 = degraded), refreshed after every membership transition. Mutex
    /// is fine: only `/metrics` reads it, and transitions are rare.
    states: Mutex<Vec<u8>>,
    /// Requests refused at the door by class-aware admission (PR 8),
    /// indexed by [`SloClass::index`]. Written by HTTP handler threads,
    /// read by `/metrics` — the no-silent-loss ledger of the 503 path.
    shed_by_class: [AtomicU64; 3],
}

impl SchedPublish {
    fn new() -> Self {
        SchedPublish {
            pools_packed: AtomicU64::new(0),
            flips: AtomicU64::new(0),
            states: Mutex::new(Vec::new()),
            shed_by_class: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
        }
    }

    fn record_shed(&self, class: SloClass) {
        self.shed_by_class[class.index()].fetch_add(1, Ordering::Relaxed);
    }

    /// Admission sheds per class, in [`SloClass::ALL`] order.
    pub fn sheds(&self) -> [u64; 3] {
        [
            self.shed_by_class[0].load(Ordering::Relaxed),
            self.shed_by_class[1].load(Ordering::Relaxed),
            self.shed_by_class[2].load(Ordering::Relaxed),
        ]
    }

    /// Liveness code per engine slot (0 active, 1 draining, 2 dead,
    /// 3 degraded).
    pub fn engine_states(&self) -> Vec<u8> {
        lock_ok(&self.states).clone()
    }

    fn store_pools(&self, pools: [usize; 4]) {
        let mut packed = 0u64;
        for (i, &p) in pools.iter().enumerate() {
            debug_assert!(p <= u16::MAX as usize, "pool size overflows 16 bits");
            packed |= ((p as u64) & 0xFFFF) << (16 * i);
        }
        self.pools_packed.store(packed, Ordering::Relaxed);
    }

    /// Current pool sizes [P, D, P→D, D→P] — one consistent snapshot.
    pub fn pools(&self) -> [usize; 4] {
        let packed = self.pools_packed.load(Ordering::Relaxed);
        [
            (packed & 0xFFFF) as usize,
            ((packed >> 16) & 0xFFFF) as usize,
            ((packed >> 32) & 0xFFFF) as usize,
            ((packed >> 48) & 0xFFFF) as usize,
        ]
    }

    pub fn flips(&self) -> u64 {
        self.flips.load(Ordering::Relaxed)
    }
}

struct Coordinator {
    engines: Vec<EngineHandle>,
    /// The scheduling brain — the same `ArrowPolicy` the simulator runs.
    policy: Box<dyn Policy>,
    /// Scheduler-side queue bookkeeping: `(req, input_len)` of every
    /// prefill dispatched to each engine and not yet completed. This is
    /// the q1 state of the ServerView snapshot.
    queued: Vec<Vec<(u64, u32)>>,
    /// O(1) aggregates of `queued` (PR 4), maintained incrementally at
    /// dispatch / completion / failure — never recomputed per decision.
    /// Uses the same `PrefillQueueMoments` update rules as `SimInstance`,
    /// so equal queues key placements bit-identically on both substrates.
    moments: Vec<PrefillQueueMoments>,
    /// Chunk each engine's fitted predictor prices overhead with (fixed
    /// at profiling time; `moments` must be maintained with it).
    chunks: Vec<u32>,
    /// Requests currently decoding on each engine — the failure-recovery
    /// ledger (their KV dies with the engine, so they restart from
    /// prefill on re-dispatch).
    decoding: Vec<Vec<u64>>,
    /// Membership state per engine slot; slots never shrink, ids stay
    /// stable (the sched-layer contract).
    life: Vec<Liveness>,
    /// Startup profile, extended as engines join (joiners on this host
    /// load identical artifacts, so they inherit the fitted curve and
    /// report their own KV capacity).
    profile: FixedProfile,
    /// Engine handles shared with the HTTP layer so `/metrics` can read
    /// stats of engines that joined after boot.
    registry: Arc<Mutex<Vec<EngineHandle>>>,
    /// Where joiners load their artifacts from + how engines call home.
    artifacts_dir: String,
    event_tx: mpsc::Sender<EngineEvent>,
    /// Self-sender: lets helper threads (artifact loaders) feed results
    /// back into the single coordinator channel.
    msg_tx: mpsc::Sender<CoordMsg>,
    /// Per-request completion channels for HTTP handlers.
    waiters: Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>>,
    inflight: HashMap<u64, Inflight>,
    done: Arc<Mutex<Vec<Done>>>,
    sched: Arc<SchedPublish>,
    started: Instant,
    /// Monotone stamp handed to each materialized snapshot. Engine load
    /// counters advance asynchronously, so an epoch may never be *reused
    /// across* snapshots — but within one decision the policy reads one
    /// frozen snapshot several times, and a unique per-snapshot stamp
    /// soundly collapses those repeat index-verify scans into the O(1)
    /// skip (`ArrowPolicy::refresh_index`).
    snapshot_epoch: u64,
    /// Flight recorder (PR 9): journals every policy decision with the
    /// exact `(now, inputs, snapshot)` it consumed. `None` when
    /// `--journal` was not given; recording never blocks this thread.
    recorder: Option<replay::Recorder>,
}

impl Coordinator {
    /// Materialize the scheduler's cluster snapshot: coordinator queue
    /// bookkeeping + the engines' lock-free load counters. Each snapshot
    /// gets a fresh change epoch (see `snapshot_epoch`).
    fn view(&mut self) -> ServerView {
        self.snapshot_epoch += 1;
        debug_assert!(self.snapshot_epoch != EPOCH_UNKNOWN);
        ServerView {
            engines: self
                .engines
                .iter()
                .zip(self.queued.iter().zip(&self.moments).zip(&self.chunks))
                .zip(&self.life)
                .map(|((e, ((q, &moments), &chunk_tokens)), &liveness)| {
                    let s = e.stats();
                    EngineSnapshot {
                        // Chunk progress is engine-internal; until
                        // PrefillDone, remaining == input_len. Release
                        // builds skip the clone entirely: placement reads
                        // only the O(1) moments, and the queue walk
                        // exists solely as the debug-mode oracle — the
                        // one per-engine Vec per decision was the last
                        // O(members × depth) term on the live path.
                        queued_prefills: if cfg!(debug_assertions) {
                            q.iter().map(|&(_, l)| (l, l)).collect()
                        } else {
                            Vec::new()
                        },
                        moments,
                        chunk_tokens,
                        // Parked adoptions count as decode load — the
                        // live analog of the simulator's decode_wait
                        // queue contributing to running_tokens.
                        running_tokens: s.cached_tokens + s.pending_decode_tokens,
                        max_kv_tokens: s.kv_capacity_tokens,
                        avg_token_interval: s.token_interval_s,
                        has_decode_work: s.active_slots > 0 || s.pending_decode_reqs > 0,
                        liveness,
                    }
                })
                .collect(),
            change_epoch: self.snapshot_epoch,
        }
    }

    /// Remove a request from an engine's dispatch queue, keeping the
    /// O(1) aggregates in lockstep. The coordinator observes no chunk
    /// progress, so the removed task's `remaining` equals its length.
    fn unqueue_prefill(&mut self, engine: usize, req: u64) {
        if let Some(pos) = self.queued[engine].iter().position(|&(r, _)| r == req) {
            let (_, len) = self.queued[engine].remove(pos);
            self.moments[engine].remove_task(len, len, self.chunks[engine]);
        }
    }

    fn now_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    // ------------------------------------------------ flight recorder (PR 9)
    // Each hook runs right after its policy call, capturing the logical
    // timestamp, the request fields, the snapshot the call consumed, and
    // the decision (target + pool sizes + flip count) — everything replay
    // needs to re-derive the decision bit-for-bit. All hooks no-op
    // without a recorder, and the recorder itself never blocks (bounded
    // channel, drop-and-count under backpressure).

    /// The policy's observable decision, captured the instant after the
    /// call — raw (pre-clamp) placement output, as replay re-derives it.
    fn journal_decision(&self, target: Option<usize>) -> replay::Decision {
        replay::Decision {
            target: target.map(|t| t as u32),
            pools: self.policy.pool_sizes().map(|p| p.map(|v| v as u64)),
            flips: self.policy.flip_count(),
        }
    }

    fn journal_req(r: &Request) -> replay::ReqRec {
        replay::ReqRec {
            id: r.id.0,
            arrival: r.arrival,
            input_len: r.input_len,
            output_len: r.output_len,
            class: r.class.index() as u8,
        }
    }

    fn journal(&mut self, rec: &replay::Record) {
        if let Some(r) = self.recorder.as_mut() {
            r.record(rec);
        }
    }

    fn journal_membership(&mut self, now: f64, kind: u8, engine: usize, snapshot: &ServerView) {
        if self.recorder.is_none() {
            return;
        }
        let rec = replay::Record::Membership {
            now,
            kind,
            engine: engine as u32,
            snap: replay::Snap::from_server(snapshot, &self.queued),
            profile: replay::Profile::from_fixed(&self.profile),
            out: self.journal_decision(None),
        };
        self.journal(&rec);
    }

    fn publish_sched(&self) {
        self.sched
            .store_pools(self.policy.pool_sizes().unwrap_or([0; 4]));
        self.sched.flips.store(self.policy.flip_count(), Ordering::Relaxed);
    }

    /// Publish the membership table for `/metrics`. Only membership
    /// transitions call this — liveness never changes on the per-request
    /// path, so the lock + rebuild stays off it.
    fn publish_membership(&self) {
        *lock_ok(&self.sched.states) = self
            .life
            .iter()
            .map(|l| match l {
                Liveness::Active => 0u8,
                Liveness::Draining => 1,
                Liveness::Dead => 2,
                Liveness::Degraded => 3,
            })
            .collect();
    }

    fn handle(&mut self, msg: CoordMsg) {
        match msg {
            CoordMsg::Submit {
                req,
                prompt,
                max_tokens,
                t0,
                class,
            } => {
                self.inflight.insert(
                    req,
                    Inflight {
                        t0,
                        max_tokens,
                        prompt: prompt.into(),
                        decode_engine: None,
                        first_token_s: None,
                        dispatch_attempts: 0,
                        class,
                    },
                );
                self.dispatch_prefill(req);
                self.publish_sched();
            }
            CoordMsg::Engine(ev) => self.handle_engine(ev),
            CoordMsg::Tick => {
                // Straggler detection first (PR 6) so the policy's view
                // this tick already carries fresh Degraded flags — same
                // ordering as the simulator's MonitorTick.
                self.detect_stragglers();
                // Monitor tick (paper §5.5): drained-pool settling,
                // TPOT-violation flips, idle-prefill harvesting — live.
                let now = self.now_s();
                let snapshot = self.view();
                self.policy.on_tick(now, &snapshot);
                if self.recorder.is_some() {
                    let rec = replay::Record::Tick {
                        now,
                        snap: replay::Snap::from_server(&snapshot, &self.queued),
                        out: self.journal_decision(None),
                    };
                    self.journal(&rec);
                }
                // Draining engines that emptied out shut down here.
                for i in 0..self.engines.len() {
                    self.maybe_finish_drain(i);
                }
                self.publish_sched();
            }
            CoordMsg::Membership(cmd) => self.handle_membership(cmd),
            CoordMsg::Fault(cmd) => self.handle_fault(cmd),
        }
    }

    /// Token-interval outlier detection (PR 6): flag engines whose recent
    /// inter-token gap is a multiple of the cluster median as Degraded,
    /// and clear the flag once they fall back in line. Mirrors the
    /// simulator's monitor-tick `detect_stragglers` — quorum of three
    /// finite samples, factor `STRAGGLER_FACTOR` over the median.
    fn detect_stragglers(&mut self) {
        const STRAGGLER_FACTOR: f64 = 3.0;
        let intervals: Vec<f64> = self
            .engines
            .iter()
            .map(|e| e.stats().token_interval_s)
            .collect();
        let mut finite: Vec<f64> = self
            .life
            .iter()
            .zip(&intervals)
            .filter(|(l, v)| l.in_cluster() && v.is_finite())
            .map(|(_, &v)| v)
            .collect();
        if finite.len() < 3 {
            return;
        }
        finite.sort_unstable_by(|a, b| a.total_cmp(b));
        let median = finite[finite.len() / 2];
        if !median.is_finite() || median <= 0.0 {
            return;
        }
        let mut changed = false;
        for (i, &v) in intervals.iter().enumerate() {
            match self.life[i] {
                Liveness::Active if v.is_finite() && v > STRAGGLER_FACTOR * median => {
                    self.life[i] = Liveness::Degraded;
                    println!("engine {i} degraded (token interval {v:.3}s, median {median:.3}s)");
                    changed = true;
                }
                Liveness::Degraded if !v.is_finite() || v <= STRAGGLER_FACTOR * median => {
                    self.life[i] = Liveness::Active;
                    println!("engine {i} recovered from degraded");
                    changed = true;
                }
                _ => {}
            }
        }
        if changed {
            self.publish_membership();
        }
    }

    /// Operator-injected fault (PR 6). Degrade/Restore touch only the
    /// membership table — the policy sees the flag through its next view
    /// snapshot, exactly like monitor-detected stragglers. CrashRejoin
    /// composes the PR 3 machinery: fail now, scale back in later.
    fn handle_fault(&mut self, cmd: FaultCmd) {
        match cmd {
            FaultCmd::Degrade { engine } => {
                if engine < self.life.len() && self.life[engine] == Liveness::Active {
                    self.life[engine] = Liveness::Degraded;
                    println!("engine {engine} degraded (injected)");
                    self.publish_membership();
                }
            }
            FaultCmd::Restore { engine } => {
                if engine < self.life.len() && self.life[engine] == Liveness::Degraded {
                    self.life[engine] = Liveness::Active;
                    println!("engine {engine} restored (injected)");
                    self.publish_membership();
                }
            }
            FaultCmd::CrashRejoin { engine, downtime_s } => {
                self.handle_membership(MembershipCmd::Fail { engine });
                let back = self.msg_tx.clone();
                let d = downtime_s.max(0.0);
                let spawned = std::thread::Builder::new()
                    .name("fault-rejoin".into())
                    .spawn(move || {
                        std::thread::sleep(std::time::Duration::from_secs_f64(d));
                        let _ = back.send(CoordMsg::Membership(MembershipCmd::Join));
                    });
                if let Err(e) = spawned {
                    eprintln!("fault inject: cannot spawn rejoin timer: {e}");
                }
            }
        }
    }

    /// Place (or re-place) the prefill phase of `req` from its retained
    /// prompt. Arrow Alg. 1 picks the engine; the coordinator only
    /// dispatches. The snapshot is materialized first so the policy call
    /// borrows nothing but itself.
    fn dispatch_prefill(&mut self, req: u64) {
        let Some(fl) = self.inflight.get_mut(&req) else { return };
        // A re-dispatch restarts the request wholesale: its first token
        // will be re-emitted, so wall-clock TTFT re-records too, and any
        // previous decode binding is void (the ledger entry was drained
        // by the failure handler).
        fl.first_token_s = None;
        fl.decode_engine = None;
        let prompt = Arc::clone(&fl.prompt);
        let max_tokens = fl.max_tokens;
        let class = fl.class;
        let now = self.now_s();
        let snapshot = self.view();
        let r = Request::new(req, now, prompt.len() as u32, max_tokens as u32)
            .with_class(class);
        let target = self.policy.place_prefill(now, &r, &snapshot);
        if self.recorder.is_some() {
            let rec = replay::Record::Prefill {
                now,
                req: Self::journal_req(&r),
                snap: replay::Snap::from_server(&snapshot, &self.queued),
                out: self.journal_decision(Some(target.0)),
            };
            self.journal(&rec);
        }
        // A policy must only name real instances; clamp in
        // release (stay serving) but fail loudly in debug.
        debug_assert!(target.0 < self.engines.len(), "policy placed on {target}");
        let t = target.0.min(self.engines.len() - 1);
        if self.life[t] == Liveness::Dead {
            // The policy only names a departed slot when nothing
            // placeable remains (every engine failed/drained). Fail fast:
            // queueing behind a dead engine's Shutdown would strand the
            // client for the full timeout and leak the inflight entry.
            // (A Draining slot, by contrast, is still running and may
            // legitimately serve as the last resort — its drain simply
            // completes later.)
            self.finish(req, Vec::new());
            return;
        }
        let len = prompt.len() as u32;
        self.queued[t].push((req, len));
        self.moments[t].add_task(len, len, self.chunks[t]);
        let rank = class.priority_rank();
        if self.engines[t]
            .send(EngineCmd::Prefill { req, prompt, rank })
            .is_err()
        {
            self.unqueue_prefill(t, req);
            self.retry_or_fail(req);
        }
    }

    /// An engine refused a command — its channel closed, i.e. it is dying
    /// but not yet declared Dead. Stateless re-placement (PR 6): retry
    /// the whole request a bounded number of times (the policy will see
    /// the slot die and place elsewhere) before the explicit failure
    /// answer the client gets instead of a silent hang.
    fn retry_or_fail(&mut self, req: u64) {
        const MAX_DISPATCH_ATTEMPTS: u32 = 3;
        let attempts = match self.inflight.get_mut(&req) {
            Some(fl) => {
                fl.dispatch_attempts += 1;
                fl.dispatch_attempts
            }
            None => return,
        };
        if attempts < MAX_DISPATCH_ATTEMPTS {
            self.dispatch_prefill(req);
        } else {
            self.finish(req, Vec::new());
        }
    }

    /// Membership transition (PR 3): registration/deregistration flow
    /// through the same single-channel coordinator as every placement, so
    /// the policy's pool re-seeding is totally ordered with decisions.
    fn handle_membership(&mut self, cmd: MembershipCmd) {
        match cmd {
            MembershipCmd::Join => {
                // Loading AOT artifacts takes seconds; on the coordinator
                // thread that would freeze every placement and completion
                // for the duration — the availability dip scale-out is
                // supposed to prevent. A helper thread does the load and
                // the runtime comes back as `Register` through the same
                // channel, totally ordered like everything else.
                let dir = self.artifacts_dir.clone();
                let back = self.msg_tx.clone();
                let spawned = std::thread::Builder::new()
                    .name("engine-loader".into())
                    .spawn(move || match crate::runtime::ModelRuntime::load(&dir) {
                        Ok(rt) => {
                            let _ = back
                                .send(CoordMsg::Membership(MembershipCmd::Register(Box::new(rt))));
                        }
                        Err(e) => eprintln!("scale-out failed: {e}"),
                    });
                if let Err(e) = spawned {
                    eprintln!("scale-out failed: cannot spawn loader: {e}");
                }
            }
            MembershipCmd::Register(rt) => {
                let id = self.engines.len();
                let handle = match EngineHandle::start(id, *rt, self.event_tx.clone()) {
                    Ok(h) => h,
                    Err(e) => {
                        eprintln!("scale-out failed: {e}");
                        return;
                    }
                };
                // Register the slot everywhere before the policy learns
                // of it, so the view it sees already covers the joiner.
                lock_ok(&self.registry).push(handle.clone_handle());
                self.engines.push(handle);
                self.queued.push(Vec::new());
                self.moments.push(PrefillQueueMoments::default());
                self.decoding.push(Vec::new());
                self.life.push(Liveness::Active);
                // Startup-equivalent profiling: identical artifacts on
                // this host, so the joiner inherits the fitted curve and
                // contributes its own reported KV capacity.
                let predictor = self.profile.predictors[0].clone();
                self.chunks.push(predictor.chunk_tokens());
                self.profile.predictors.push(predictor);
                self.profile
                    .max_running_tokens
                    .push(self.engines[id].stats().kv_capacity_tokens.max(1));
                let now = self.now_s();
                let snapshot = self.view();
                self.policy.on_membership(
                    now,
                    MembershipEvent::InstanceJoined { id: InstanceId(id) },
                    &snapshot,
                    &self.profile,
                );
                // The record carries the post-join profile: replay
                // re-seeds with exactly what the live policy saw.
                self.journal_membership(now, replay::MEMBER_JOINED, id, &snapshot);
                println!("engine {id} joined ({} total)", self.engines.len());
                self.publish_sched();
                self.publish_membership();
            }
            MembershipCmd::Drain { engine } => {
                if engine >= self.engines.len() || self.life[engine] != Liveness::Active {
                    return;
                }
                self.life[engine] = Liveness::Draining;
                let now = self.now_s();
                let snapshot = self.view();
                self.policy.on_membership(
                    now,
                    MembershipEvent::InstanceDraining { id: InstanceId(engine) },
                    &snapshot,
                    &self.profile,
                );
                self.journal_membership(now, replay::MEMBER_DRAINING, engine, &snapshot);
                println!("engine {engine} draining");
                self.publish_membership();
                self.maybe_finish_drain(engine);
                self.publish_sched();
            }
            MembershipCmd::Fail { engine } => {
                if engine >= self.engines.len() || self.life[engine] == Liveness::Dead {
                    return;
                }
                self.life[engine] = Liveness::Dead;
                let _ = self.engines[engine].send(EngineCmd::Shutdown);
                let now = self.now_s();
                let snapshot = self.view();
                self.policy.on_membership(
                    now,
                    MembershipEvent::InstanceLost { id: InstanceId(engine) },
                    &snapshot,
                    &self.profile,
                );
                // Journaled before the re-dispatch loop below: replay
                // must observe the loss, then each re-placement, in the
                // exact order the policy was called.
                self.journal_membership(now, replay::MEMBER_LOST, engine, &snapshot);
                // Re-dispatch everything the engine held: queued prefills
                // restart verbatim; decodes restart from prefill (their
                // KV died with the engine). Stateless instances make this
                // a pure re-placement — no session state to rebuild.
                let queued: Vec<u64> = self.queued[engine].drain(..).map(|(r, _)| r).collect();
                self.moments[engine] = PrefillQueueMoments::default();
                let decoding: Vec<u64> = std::mem::take(&mut self.decoding[engine]);
                let n = queued.len() + decoding.len();
                for req in queued.into_iter().chain(decoding) {
                    self.dispatch_prefill(req);
                }
                println!("engine {engine} failed; re-dispatched {n} request(s)");
                self.publish_sched();
                self.publish_membership();
            }
        }
    }

    /// A draining engine with nothing left anywhere — coordinator queues
    /// or engine-side slots — shuts down and leaves the table as Dead.
    fn maybe_finish_drain(&mut self, i: usize) {
        if self.life[i] != Liveness::Draining {
            return;
        }
        let s = self.engines[i].stats();
        if self.queued[i].is_empty()
            && self.decoding[i].is_empty()
            && s.prefill_queue == 0
            && s.active_slots == 0
            && s.pending_decode_reqs == 0
        {
            self.life[i] = Liveness::Dead;
            let _ = self.engines[i].send(EngineCmd::Shutdown);
            println!("engine {i} drained and left the cluster");
            self.publish_sched();
            self.publish_membership();
        }
    }

    /// Handle one engine event (decode placement / completion routing).
    fn handle_engine(&mut self, ev: EngineEvent) {
        match ev {
            EngineEvent::PrefillDone {
                req,
                engine,
                prompt_len,
                first_token,
                k,
                v,
                bucket,
            } => {
                if self.life.get(engine).copied() == Some(Liveness::Dead) {
                    // A failed engine's parting words: the request was
                    // already re-dispatched elsewhere — ignore.
                    return;
                }
                self.unqueue_prefill(engine, req);
                let (max_tokens, class) = match self.inflight.get_mut(&req) {
                    Some(fl) => {
                        // First token exists now — wall-clock TTFT.
                        fl.first_token_s = Some(fl.t0.elapsed().as_secs_f64());
                        (fl.max_tokens, fl.class)
                    }
                    None => (1, SloClass::Standard),
                };
                if max_tokens <= 1 {
                    self.finish(req, vec![first_token]);
                    return;
                }
                // Arrow Alg. 2 picks the decode engine; local handoff
                // (target == engine) avoids the cross-engine memcpy.
                let now = self.now_s();
                let snapshot = self.view();
                let r = Request::new(req, now, prompt_len as u32, max_tokens as u32)
                    .with_class(class);
                let target =
                    self.policy
                        .place_decode(now, &r, InstanceId(engine), &snapshot);
                if self.recorder.is_some() {
                    let rec = replay::Record::Decode {
                        now,
                        req: Self::journal_req(&r),
                        from: engine as u32,
                        snap: replay::Snap::from_server(&snapshot, &self.queued),
                        out: self.journal_decision(Some(target.0)),
                    };
                    self.journal(&rec);
                }
                debug_assert!(target.0 < self.engines.len(), "policy placed on {target}");
                let t = target.0.min(self.engines.len() - 1);
                if self.life[t] == Liveness::Dead {
                    // Nothing placeable is left (see dispatch_prefill);
                    // fail fast rather than strand the request behind a
                    // dead engine's Shutdown.
                    self.finish(req, Vec::new());
                    return;
                }
                // KV migration: the slab moves through the coordinator (a
                // real memcpy between engines when target != source).
                self.decoding[t].push(req);
                if let Some(fl) = self.inflight.get_mut(&req) {
                    fl.decode_engine = Some(t);
                }
                if self.engines[t]
                    .send(EngineCmd::StartDecode {
                        req,
                        prompt_len,
                        first_token,
                        k,
                        v,
                        bucket,
                        remaining: max_tokens - 1,
                    })
                    .is_err()
                {
                    // The decode target died mid-handoff; its KV copy is
                    // gone with it. Retract the ledger entry and restart
                    // from prefill elsewhere (bounded attempts).
                    self.decoding[t].retain(|&r| r != req);
                    if let Some(fl) = self.inflight.get_mut(&req) {
                        fl.decode_engine = None;
                    }
                    self.retry_or_fail(req);
                }
                self.publish_sched();
            }
            EngineEvent::DecodeDone { req, engine, tokens } => {
                if self.life.get(engine).copied() == Some(Liveness::Dead) {
                    // Parting words of a failed engine: the request was
                    // already re-dispatched — let the retry finish it.
                    return;
                }
                self.finish(req, tokens)
            }
            EngineEvent::Failed { req, engine, error } => {
                if self.life.get(engine).copied() == Some(Liveness::Dead) {
                    // Expected fallout of the declared failure (e.g. the
                    // engine failing its whole batch on shutdown); the
                    // re-dispatch already covers these requests.
                    return;
                }
                eprintln!("request {req} failed: {error}");
                self.unqueue_prefill(engine, req);
                self.finish(req, Vec::new());
            }
        }
    }

    fn finish(&mut self, req: u64, tokens: Vec<i32>) {
        let fl = match self.inflight.remove(&req) {
            Some(x) => x,
            None => return,
        };
        // Whatever ends a request clears its decode-ledger entry.
        if let Some(e) = fl.decode_engine {
            self.decoding[e].retain(|&r| r != req);
        }
        let total = fl.t0.elapsed().as_secs_f64();
        let n = tokens.len().max(1);
        // Real TTFT was recorded at PrefillDone; fall back to the whole
        // latency for requests that failed before prefill completed.
        let ttft = fl.first_token_s.unwrap_or(total);
        let tpot = if n > 1 {
            (total - ttft).max(0.0) / (n - 1) as f64
        } else {
            0.0
        };
        lock_ok(&self.done).push(Done {
            ttft_s: ttft,
            tpot_s: tpot,
            tokens: n,
        });
        if let Some(tx) = lock_ok(&self.waiters).remove(&req) {
            let _ = tx.send((tokens, total, tpot));
        }
    }
}

/// Start engines + coordinator + HTTP frontend; blocks forever (Ctrl-C to
/// stop). Returns early only on startup errors.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let (event_tx, event_rx) = mpsc::channel::<EngineEvent>();
    println!(
        "loading {} engine(s) from {} ...",
        cfg.instances, cfg.artifacts_dir
    );
    let mut engines = Vec::new();
    for i in 0..cfg.instances {
        engines.push(EngineHandle::spawn(
            i,
            &cfg.artifacts_dir,
            event_tx.clone(),
        )?);
        println!("  engine {i} ready");
    }
    // Startup profiling (paper §5.3) — real probe-prompt timings fitted
    // into the same FixedProfile the policy would get from any substrate.
    let profile = profile_engines(&engines);
    println!(
        "ttft predictor coefficients: {:?}",
        profile.predictors[0].coefficients()
    );

    // The scheduling brain: the identical ArrowPolicy the simulator runs.
    let arrow_cfg = ArrowConfig::new(cfg.ttft_slo, cfg.tpot_slo, cfg.instances);
    let mut policy: Box<dyn Policy> =
        Box::new(ArrowPolicy::new(arrow_cfg.clone(), cfg.instances));
    policy.init(&profile);
    println!("scheduling policy: {}", policy.name());

    // Flight recorder (PR 9): journal header + policy-reconstruction
    // metadata, written before the first decision can happen.
    let (mut recorder, flusher, jstats) = match &cfg.journal_path {
        Some(p) => {
            let (r, f, s) =
                replay::Recorder::create(std::path::Path::new(p), replay::DEFAULT_JOURNAL_CAPACITY)?;
            println!("flight recorder: journaling decisions to {p}");
            (Some(r), Some(f), Some(s))
        }
        None => (None, None, None),
    };
    if let Some(r) = recorder.as_mut() {
        r.record(&replay::Record::Meta(replay::Meta {
            policy: "arrow-slo-aware".into(),
            ttft_slo: arrow_cfg.ttft_slo,
            tpot_slo: arrow_cfg.tpot_slo,
            initial_prefill: arrow_cfg.initial_prefill as u64,
            decode_low_watermark: arrow_cfg.decode_low_watermark,
            tpot_violation_ticks: arrow_cfg.tpot_violation_ticks,
            tpot_violation_frac: arrow_cfg.tpot_violation_frac,
            class_aware: arrow_cfg.class_aware,
            instances: cfg.instances as u64,
            split_prefill: Vec::new(),
            split_decode: Vec::new(),
            profile: replay::Profile::from_fixed(&profile),
        }));
    }

    let waiters: Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let done: Arc<Mutex<Vec<Done>>> = Arc::new(Mutex::new(Vec::new()));
    let sched = Arc::new(SchedPublish::new());
    let next_id = Arc::new(AtomicU64::new(1));
    // Engine handles shared with /metrics; grows on scale-out.
    let registry: Arc<Mutex<Vec<EngineHandle>>> = Arc::new(Mutex::new(
        engines.iter().map(|e| e.clone_handle()).collect(),
    ));

    let (msg_tx, msg_rx) = mpsc::channel::<CoordMsg>();

    // Bridge engine events into the coordinator's single input channel.
    let bridge_tx = msg_tx.clone();
    std::thread::Builder::new()
        .name("event-bridge".into())
        .spawn(move || {
            while let Ok(ev) = event_rx.recv() {
                if bridge_tx.send(CoordMsg::Engine(ev)).is_err() {
                    return;
                }
            }
        })?;

    // Monitor-tick thread: the live counterpart of the simulator's
    // MonitorTick event, same period.
    let tick_tx = msg_tx.clone();
    std::thread::Builder::new()
        .name("monitor-tick".into())
        .spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                crate::sim::MONITOR_PERIOD,
            ));
            if tick_tx.send(CoordMsg::Tick).is_err() {
                return;
            }
        })?;

    let mut coord = Coordinator {
        engines: engines.iter().map(|e| e.clone_handle()).collect(),
        policy,
        queued: (0..cfg.instances).map(|_| Vec::new()).collect(),
        moments: vec![PrefillQueueMoments::default(); cfg.instances],
        chunks: profile
            .predictors
            .iter()
            .map(|p| p.chunk_tokens())
            .collect(),
        decoding: (0..cfg.instances).map(|_| Vec::new()).collect(),
        life: vec![Liveness::Active; cfg.instances],
        profile,
        registry: Arc::clone(&registry),
        artifacts_dir: cfg.artifacts_dir.clone(),
        event_tx,
        msg_tx: msg_tx.clone(),
        waiters: Arc::clone(&waiters),
        inflight: HashMap::new(),
        done: Arc::clone(&done),
        sched: Arc::clone(&sched),
        started: Instant::now(),
        snapshot_epoch: 0,
        recorder,
    };
    coord.publish_sched(); // initial pool split visible before traffic
    coord.publish_membership(); // …and the initial membership table
    std::thread::Builder::new()
        .name("coordinator".into())
        .spawn(move || {
            while let Ok(msg) = msg_rx.recv() {
                coord.handle(msg);
            }
        })?;

    let shutdown = Arc::new(AtomicBool::new(false));
    let addr = format!("0.0.0.0:{}", cfg.port);
    let registry_http = Arc::clone(&registry);
    let waiters_http = Arc::clone(&waiters);
    let done_http = Arc::clone(&done);
    let sched_http = Arc::clone(&sched);
    let cfg_http = cfg.clone();
    let journal = JournalHandles {
        stats: jstats,
        flusher: flusher.clone(),
        shutdown: Arc::clone(&shutdown),
    };
    http::serve(&addr, Arc::clone(&shutdown), move |req| {
        route(
            req,
            &registry_http,
            &waiters_http,
            &done_http,
            &sched_http,
            &next_id,
            &msg_tx,
            &cfg_http,
            &journal,
        )
    })?;
    // Clean exit (`POST /admin/shutdown`): the accept loop has returned;
    // flush + fsync whatever the coordinator journaled since the
    // endpoint's own barrier (e.g. the drain-path membership records).
    if let Some(f) = &flusher {
        f.flush_sync();
        println!("flight recorder: journal flushed");
    }
    Ok(())
}

/// Journal + shutdown plumbing shared with the HTTP handler threads.
struct JournalHandles {
    /// `/metrics` counters (`journal_events` / `journal_dropped`).
    stats: Option<Arc<replay::JournalStats>>,
    /// Durability barrier for `/admin/shutdown`.
    flusher: Option<replay::Flusher>,
    /// The accept-loop stop flag — set by `/admin/shutdown`.
    shutdown: Arc<AtomicBool>,
}

/// Time real prefills at each bucket through engine 0, fit the TTFT
/// quadratic, and read each engine's profiled KV capacity. All engines
/// load identical artifacts on one host, so one fitted curve serves the
/// whole cluster (heterogeneous deployments would probe per engine, §8);
/// Max Running Tokens uses the engine-reported memory bound.
fn profile_engines(engines: &[EngineHandle]) -> FixedProfile {
    let mut samples: Vec<(f64, f64)> = Vec::new();
    let mut max_bucket = 2048usize;
    for bucket in engines[0].buckets() {
        max_bucket = max_bucket.max(bucket);
        let prompt: Vec<i32> = (0..bucket as i32).map(|i| i % 97 + 1).collect();
        let t0 = Instant::now();
        if engines[0].blocking_prefill(&prompt).is_ok() {
            samples.push((bucket as f64, t0.elapsed().as_secs_f64()));
        }
    }
    let predictor = if samples.len() >= 3 {
        let xs: Vec<f64> = samples.iter().map(|s| s.0).collect();
        let ys: Vec<f64> = samples.iter().map(|s| s.1).collect();
        TtftPredictor::from_coefficients(
            crate::util::stats::quadratic_fit(&xs, &ys),
            max_bucket as u32,
            0.001,
        )
    } else {
        TtftPredictor::from_coefficients([0.01, 1e-4, 0.0], max_bucket as u32, 0.001)
    };
    // kv_capacity_tokens is stored by EngineHandle::spawn before the
    // engine thread starts, so it is always visible here.
    FixedProfile {
        predictors: engines.iter().map(|_| predictor.clone()).collect(),
        max_running_tokens: engines
            .iter()
            .map(|e| e.stats().kv_capacity_tokens.max(1))
            .collect(),
    }
}

#[allow(clippy::too_many_arguments)]
fn route(
    req: &HttpRequest,
    registry: &Arc<Mutex<Vec<EngineHandle>>>,
    waiters: &Arc<Mutex<HashMap<u64, mpsc::Sender<(Vec<i32>, f64, f64)>>>>,
    done: &Arc<Mutex<Vec<Done>>>,
    sched: &Arc<SchedPublish>,
    next_id: &Arc<AtomicU64>,
    submit: &mpsc::Sender<CoordMsg>,
    cfg: &ServeConfig,
    journal: &JournalHandles,
) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => HttpResponse::text(200, "ok"),
        ("GET", "/metrics") => {
            let d = lock_ok(done);
            let ttfts: Vec<f64> = d.iter().map(|x| x.ttft_s).collect();
            let tpots: Vec<f64> = d.iter().map(|x| x.tpot_s).collect();
            let total_tokens: usize = d.iter().map(|x| x.tokens).sum();
            let engines: Vec<EngineHandle> = lock_ok(registry)
                .iter()
                .map(|e| e.clone_handle())
                .collect();
            let stats: Vec<Json> = engines
                .iter()
                .map(|e| {
                    let s = e.stats();
                    Json::obj(vec![
                        ("prefill_queue", Json::Num(s.prefill_queue as f64)),
                        ("active_slots", Json::Num(s.active_slots as f64)),
                        ("free_slots", Json::Num(s.free_slots as f64)),
                        ("cached_tokens", Json::Num(s.cached_tokens as f64)),
                        ("iterations", Json::Num(s.iterations as f64)),
                        (
                            "kv_capacity_tokens",
                            Json::Num(s.kv_capacity_tokens as f64),
                        ),
                        // NaN (no evidence) encodes as JSON null.
                        ("token_interval_s", Json::Num(s.token_interval_s)),
                    ])
                })
                .collect();
            let pct = crate::util::stats::percentile;
            // Proof the server runs Arrow: live pool sizes + flip count
            // from the shared policy's pool bookkeeping — and, since
            // PR 3, the membership table (instance count + drain state).
            let pools = sched.pools();
            let states = sched.engine_states();
            let live = states.iter().filter(|&&s| s != 2).count();
            let body = Json::obj(vec![
                ("completed_requests", Json::Num(d.len() as f64)),
                ("total_tokens", Json::Num(total_tokens as f64)),
                ("p50_ttft_s", Json::Num(pct(&ttfts, 50.0))),
                ("p90_ttft_s", Json::Num(pct(&ttfts, 90.0))),
                ("p99_ttft_s", Json::Num(pct(&ttfts, 99.0))),
                ("p50_tpot_s", Json::Num(pct(&tpots, 50.0))),
                ("p90_tpot_s", Json::Num(pct(&tpots, 90.0))),
                ("p99_tpot_s", Json::Num(pct(&tpots, 99.0))),
                ("ttft_slo", Json::Num(cfg.ttft_slo)),
                ("tpot_slo", Json::Num(cfg.tpot_slo)),
                (
                    "pools",
                    Json::Arr(pools.iter().map(|&p| Json::Num(p as f64)).collect()),
                ),
                ("flips", Json::Num(sched.flips() as f64)),
                // Class-aware admission ledger (PR 8): 503s per class.
                (
                    "shed_by_class",
                    Json::obj(
                        SloClass::ALL
                            .iter()
                            .zip(sched.sheds())
                            .map(|(c, n)| (c.label(), Json::Num(n as f64)))
                            .collect(),
                    ),
                ),
                ("instances", Json::Num(states.len() as f64)),
                ("live_instances", Json::Num(live as f64)),
                (
                    "engine_states",
                    Json::Arr(
                        states
                            .iter()
                            .map(|&s| {
                                Json::Str(
                                    match s {
                                        0 => "active",
                                        1 => "draining",
                                        3 => "degraded",
                                        _ => "dead",
                                    }
                                    .into(),
                                )
                            })
                            .collect(),
                    ),
                ),
                ("engines", Json::Arr(stats)),
                // Flight-recorder ledger (PR 9): decisions journaled vs
                // dropped under backpressure. Zero/zero when recording
                // is off; a nonzero dropped count means the journal has
                // a gap (replay reports exactly where).
                (
                    "journal_events",
                    Json::Num(journal.stats.as_ref().map_or(0, |s| s.events()) as f64),
                ),
                (
                    "journal_dropped",
                    Json::Num(journal.stats.as_ref().map_or(0, |s| s.dropped()) as f64),
                ),
            ]);
            HttpResponse::json(200, &body.encode())
        }
        // ------------------------------------------------ admin (PR 3)
        // Elastic membership: operators scale the engine set at runtime.
        // All three commands serialize into the coordinator channel, so
        // the pool re-seed is totally ordered with placements. These are
        // the server's first *destructive* endpoints and the bind is
        // 0.0.0.0 — they require the configured shared secret.
        ("POST", "/admin/scale-out") | ("POST", "/admin/drain") | ("POST", "/admin/fail") => {
            if !admin_authorized(req, cfg) {
                return admin_forbidden();
            }
            let cmd = if req.path == "/admin/scale-out" {
                MembershipCmd::Join
            } else {
                let engine = Json::parse(&req.body_str())
                    .ok()
                    .and_then(|b| b.get("engine").as_u64());
                let Some(engine) = engine else {
                    return HttpResponse::json(400, "{\"error\":\"missing 'engine' index\"}");
                };
                if req.path == "/admin/drain" {
                    MembershipCmd::Drain { engine: engine as usize }
                } else {
                    MembershipCmd::Fail { engine: engine as usize }
                }
            };
            let accepted = if req.path == "/admin/scale-out" {
                "{\"status\":\"joining\"}"
            } else {
                "{\"status\":\"accepted\"}"
            };
            match submit.send(CoordMsg::Membership(cmd)) {
                Ok(()) => HttpResponse::json(202, accepted),
                Err(_) => HttpResponse::json(503, "{\"error\":\"coordinator unavailable\"}"),
            }
        }
        // --------------------------------------------- shutdown (PR 9)
        // Clean stop: drain every engine through the normal membership
        // path (no new placements; in-flight work completes), fsync the
        // flight-recorder journal, then stop the accept loop. The old
        // `shutdown` AtomicBool existed since PR 2 but nothing ever set
        // it — the server could only be killed, which tears the journal.
        ("POST", "/admin/shutdown") => {
            if !admin_authorized(req, cfg) {
                return admin_forbidden();
            }
            let n = lock_ok(registry).len();
            for engine in 0..n {
                let _ = submit.send(CoordMsg::Membership(MembershipCmd::Drain { engine }));
            }
            // Durability barrier: everything journaled up to this point
            // is on disk before we advertise the shutdown. The drain
            // records above land via the final flush in `serve`.
            if let Some(f) = &journal.flusher {
                f.flush_sync();
            }
            journal.shutdown.store(true, Ordering::Relaxed);
            HttpResponse::json(202, "{\"status\":\"shutting down\"}")
        }
        // ------------------------------------------------ chaos (PR 6)
        // Deterministic fault injection for live drills: degrade/restore
        // a straggler flag, or crash an engine and scale a replacement
        // back in after a downtime. Same guard as the other /admin/*
        // endpoints — faults reshape the cluster.
        ("POST", "/admin/inject") => {
            if !admin_authorized(req, cfg) {
                return admin_forbidden();
            }
            let body = match Json::parse(&req.body_str()) {
                Ok(b) => b,
                Err(e) => {
                    return HttpResponse::json(400, &format!("{{\"error\":\"{e}\"}}"))
                }
            };
            let Some(engine) = body.get("engine").as_u64() else {
                return HttpResponse::json(400, "{\"error\":\"missing 'engine' index\"}");
            };
            let engine = engine as usize;
            let cmd = match body.get("kind").as_str() {
                Some("degrade") => FaultCmd::Degrade { engine },
                Some("restore") => FaultCmd::Restore { engine },
                Some("crash") => FaultCmd::CrashRejoin {
                    engine,
                    downtime_s: body.get("downtime_s").as_f64().unwrap_or(5.0).max(0.0),
                },
                _ => {
                    return HttpResponse::json(
                        400,
                        "{\"error\":\"'kind' must be degrade|restore|crash\"}",
                    )
                }
            };
            match submit.send(CoordMsg::Fault(cmd)) {
                Ok(()) => HttpResponse::json(202, "{\"status\":\"injected\"}"),
                Err(_) => HttpResponse::json(503, "{\"error\":\"coordinator unavailable\"}"),
            }
        }
        ("POST", "/v1/completions") => {
            let body = match Json::parse(&req.body_str()) {
                Ok(b) => b,
                Err(e) => {
                    return HttpResponse::json(400, &format!("{{\"error\":\"{e}\"}}"))
                }
            };
            let tokens: Vec<i32> = match body.get("tokens").as_arr() {
                Some(a) => a
                    .iter()
                    .filter_map(|x| x.as_i64().map(|v| v as i32))
                    .collect(),
                None => {
                    return HttpResponse::json(
                        400,
                        "{\"error\":\"missing 'tokens' array\"}",
                    )
                }
            };
            if tokens.is_empty() {
                return HttpResponse::json(400, "{\"error\":\"empty prompt\"}");
            }
            // Validate max_tokens (PR 6): absent defaults to 16, but a
            // *present* malformed value (0, negative, fractional, or
            // absurd) is a client error — the old `unwrap_or(16)` would
            // silently run a nonsense budget instead.
            const MAX_MAX_TOKENS: u64 = 100_000;
            let max_tokens = match body.get("max_tokens") {
                Json::Null => 16usize,
                v => match v.as_u64() {
                    Some(m) if (1..=MAX_MAX_TOKENS).contains(&m) => m as usize,
                    _ => {
                        return HttpResponse::json(
                            400,
                            "{\"error\":\"'max_tokens' must be an integer in [1, 100000]\"}",
                        )
                    }
                },
            };

            // SLO class (PR 8): optional "class" body field; absent means
            // Standard — exactly the pre-class behavior.
            let class = match body.get("class") {
                Json::Null => SloClass::Standard,
                v => match v.as_str().and_then(SloClass::from_label) {
                    Some(c) => c,
                    None => {
                        return HttpResponse::json(
                            400,
                            "{\"error\":\"'class' must be interactive|standard|batch\"}",
                        )
                    }
                },
            };

            // Admission control (PR 6, §5.5 overload rule): shed at the
            // door with an honest 503 once too many requests are already
            // waiting — decode-priority scheduling will not drain a
            // runaway queue soon, and an eternal hang helps nobody.
            // Class-aware (PR 8): batch work sheds at half the cap so
            // overload degrades the right traffic first. Standard and
            // interactive keep the full PR-6 cap — default (class-less)
            // clients see exactly the old admission behavior.
            let cap = match class {
                SloClass::Batch => (cfg.max_inflight / 2).max(1),
                SloClass::Standard | SloClass::Interactive => cfg.max_inflight,
            };
            if lock_ok(waiters).len() >= cap {
                sched.record_shed(class);
                return HttpResponse::json(503, "{\"error\":\"overloaded, retry later\"}");
            }

            let id = next_id.fetch_add(1, Ordering::Relaxed);
            let (tx, rx) = mpsc::channel();
            lock_ok(waiters).insert(id, tx);
            // All placement happens on the coordinator thread, where the
            // policy lives; the HTTP handler only submits and waits.
            if submit
                .send(CoordMsg::Submit {
                    req: id,
                    prompt: tokens,
                    max_tokens,
                    t0: Instant::now(),
                    class,
                })
                .is_err()
            {
                lock_ok(waiters).remove(&id);
                return HttpResponse::json(503, "{\"error\":\"coordinator unavailable\"}");
            }

            let deadline = std::time::Duration::from_secs_f64(cfg.request_deadline_s);
            match rx.recv_timeout(deadline) {
                Ok((tokens, total_s, tpot_s)) if !tokens.is_empty() => {
                    let out = Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        (
                            "tokens",
                            Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                        ),
                        ("latency_s", Json::Num(total_s)),
                        ("tpot_s", Json::Num(tpot_s)),
                    ]);
                    HttpResponse::json(200, &out.encode())
                }
                Ok(_) => HttpResponse::json(500, "{\"error\":\"request failed\"}"),
                Err(_) => {
                    // Deadline exceeded (PR 6): reclaim the waiter entry —
                    // it also backs the admission count, so a leak would
                    // ratchet the server toward a permanent 503.
                    lock_ok(waiters).remove(&id);
                    HttpResponse::json(504, "{\"error\":\"deadline exceeded\"}")
                }
            }
        }
        _ => HttpResponse::not_found(),
    }
}

/// Constant-time byte-string equality for secret comparison. `==` on
/// slices bails at the first differing byte, so response timing leaks
/// how long a correct prefix an attacker has guessed — with 0.0.0.0
/// admin endpoints, that is an oracle for recovering the token byte by
/// byte. This fold always walks `max(len_a, len_b)` positions and ORs
/// every difference into one accumulator: timing depends only on the
/// lengths, never on where (or whether) the contents differ.
fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    let mut diff = a.len() ^ b.len();
    for i in 0..a.len().max(b.len()) {
        let x = a.get(i).copied().unwrap_or(0) as usize;
        let y = b.get(i).copied().unwrap_or(0) as usize;
        diff |= x ^ y;
    }
    diff == 0
}

/// Shared guard for every destructive `/admin/*` endpoint.
fn admin_authorized(req: &HttpRequest, cfg: &ServeConfig) -> bool {
    match &cfg.admin_token {
        Some(tok) => req
            .headers
            .get("x-admin-token")
            .is_some_and(|v| ct_eq(v.as_bytes(), tok.as_bytes())),
        None => false,
    }
}

fn admin_forbidden() -> HttpResponse {
    HttpResponse::json(
        403,
        "{\"error\":\"admin endpoints require X-Admin-Token (set \
         admin_token / ARROW_ADMIN_TOKEN to enable)\"}",
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ct_eq_agrees_with_slice_equality() {
        let cases: &[(&[u8], &[u8])] = &[
            (b"", b""),
            (b"", b"a"),
            (b"a", b""),
            (b"secret-token", b"secret-token"),
            (b"secret-token", b"secret-tokem"),
            (b"secret-token", b"Aecret-token"),
            (b"secret-token", b"secret-token-longer"),
            (b"short", b"a-much-longer-candidate"),
            (b"\x00\x00", b"\x00\x00"),
            (b"\x00\x01", b"\x00\x00"),
        ];
        for (a, b) in cases {
            assert_eq!(ct_eq(a, b), a == b, "ct_eq({a:?}, {b:?})");
        }
    }

    fn cfg_with_token(tok: Option<&str>) -> ServeConfig {
        ServeConfig {
            artifacts_dir: String::new(),
            port: 0,
            instances: 1,
            ttft_slo: 2.0,
            tpot_slo: 0.5,
            admin_token: tok.map(String::from),
            max_inflight: 8,
            request_deadline_s: 1.0,
            journal_path: None,
        }
    }

    fn req_with_header(value: Option<&str>) -> HttpRequest {
        let mut headers = std::collections::BTreeMap::new();
        if let Some(v) = value {
            headers.insert("x-admin-token".to_string(), v.to_string());
        }
        HttpRequest {
            method: "POST".into(),
            path: "/admin/drain".into(),
            headers,
            body: Vec::new(),
        }
    }

    #[test]
    fn admin_guard_accepts_only_the_exact_token() {
        let cfg = cfg_with_token(Some("test-admin-token"));
        assert!(admin_authorized(&req_with_header(Some("test-admin-token")), &cfg));
        assert!(!admin_authorized(&req_with_header(Some("test-admin-tokeX")), &cfg));
        assert!(!admin_authorized(&req_with_header(Some("test-admin-token2")), &cfg));
        assert!(!admin_authorized(&req_with_header(Some("")), &cfg));
        assert!(!admin_authorized(&req_with_header(None), &cfg));
        // No configured token disables admin entirely — even an empty
        // header must not match an unset secret.
        let off = cfg_with_token(None);
        assert!(!admin_authorized(&req_with_header(Some("")), &off));
        assert!(!admin_authorized(&req_with_header(None), &off));
    }
}
