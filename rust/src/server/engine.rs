//! A real-mode stateless engine: one OS thread owning a [`ModelRuntime`]
//! (its own PJRT client + compiled executables) and a decode batch state.
//!
//! The engine accepts both prefill and decode work (stateless instances,
//! paper §5.2) and runs a continuous-batching loop: each pass drains
//! pending commands, serves one queued prefill, then executes one decode
//! iteration over all active slots.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::Result;

use crate::runtime::{DecodeBatchState, ModelRuntime};

/// Commands from the coordinator to an engine.
pub enum EngineCmd {
    /// Run the prefill phase of a request.
    Prefill { req: u64, prompt: Vec<i32> },
    /// Adopt a prefilled request for decoding (KV slab included — this is
    /// the migration payload when the prefill ran elsewhere).
    StartDecode {
        req: u64,
        prompt_len: usize,
        first_token: i32,
        k: Vec<f32>,
        v: Vec<f32>,
        bucket: usize,
        remaining: usize,
    },
    /// Synchronous prefill used by startup profiling.
    BlockingPrefill {
        prompt: Vec<i32>,
        reply: mpsc::Sender<Result<i32, String>>,
    },
    Shutdown,
}

/// Events from engines back to the coordinator.
pub enum EngineEvent {
    PrefillDone {
        req: u64,
        engine: usize,
        prompt_len: usize,
        first_token: i32,
        k: Vec<f32>,
        v: Vec<f32>,
        bucket: usize,
    },
    DecodeDone {
        req: u64,
        /// All output tokens (first token included).
        tokens: Vec<i32>,
    },
    Failed {
        req: u64,
        error: String,
    },
}

/// Live load metrics published by the engine (lock-free reads).
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub prefill_queue: usize,
    pub active_slots: usize,
    pub free_slots: usize,
    pub cached_tokens: u64,
    pub iterations: u64,
}

#[derive(Default)]
struct SharedStats {
    prefill_queue: AtomicUsize,
    active_slots: AtomicUsize,
    free_slots: AtomicUsize,
    cached_tokens: AtomicU64,
    iterations: AtomicU64,
}

/// Handle to a spawned engine thread.
pub struct EngineHandle {
    pub id: usize,
    tx: mpsc::Sender<EngineCmd>,
    stats: Arc<SharedStats>,
    buckets: Vec<usize>,
}

impl EngineHandle {
    pub fn spawn(
        id: usize,
        artifacts_dir: &str,
        events: mpsc::Sender<EngineEvent>,
    ) -> Result<EngineHandle> {
        let rt = ModelRuntime::load(artifacts_dir)?;
        let buckets = rt.info.prefill_buckets.clone();
        let (tx, rx) = mpsc::channel::<EngineCmd>();
        let stats = Arc::new(SharedStats::default());
        let stats_thread = Arc::clone(&stats);
        std::thread::Builder::new()
            .name(format!("engine-{id}"))
            .spawn(move || engine_loop(id, rt, rx, events, stats_thread))?;
        Ok(EngineHandle {
            id,
            tx,
            stats,
            buckets,
        })
    }

    pub fn clone_handle(&self) -> EngineHandle {
        EngineHandle {
            id: self.id,
            tx: self.tx.clone(),
            stats: Arc::clone(&self.stats),
            buckets: self.buckets.clone(),
        }
    }

    pub fn send(&self, cmd: EngineCmd) -> Result<(), mpsc::SendError<EngineCmd>> {
        self.tx.send(cmd)
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            prefill_queue: self.stats.prefill_queue.load(Ordering::Relaxed),
            active_slots: self.stats.active_slots.load(Ordering::Relaxed),
            free_slots: self.stats.free_slots.load(Ordering::Relaxed),
            cached_tokens: self.stats.cached_tokens.load(Ordering::Relaxed),
            iterations: self.stats.iterations.load(Ordering::Relaxed),
        }
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    /// Synchronous prefill (startup profiling only).
    pub fn blocking_prefill(&self, prompt: &[i32]) -> Result<i32, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(EngineCmd::BlockingPrefill {
                prompt: prompt.to_vec(),
                reply,
            })
            .map_err(|e| e.to_string())?;
        rx.recv().map_err(|e| e.to_string())?
    }
}

/// Per-slot decode bookkeeping inside the engine loop.
struct SlotState {
    req: u64,
    remaining: usize,
    tokens: Vec<i32>,
}

fn engine_loop(
    id: usize,
    rt: ModelRuntime,
    rx: mpsc::Receiver<EngineCmd>,
    events: mpsc::Sender<EngineEvent>,
    stats: Arc<SharedStats>,
) {
    let mut decode = rt.new_decode_state();
    let mut slots: Vec<Option<SlotState>> = (0..decode.batch()).map(|_| None).collect();
    let mut prefill_q: VecDeque<(u64, Vec<i32>)> = VecDeque::new();
    let mut pending_decode: VecDeque<EngineCmd> = VecDeque::new();

    let publish = |prefill_q: &VecDeque<(u64, Vec<i32>)>,
                   decode: &DecodeBatchState,
                   iters: u64| {
        stats
            .prefill_queue
            .store(prefill_q.len(), Ordering::Relaxed);
        stats
            .active_slots
            .store(decode.active_count(), Ordering::Relaxed);
        stats
            .free_slots
            .store(decode.batch() - decode.active_count(), Ordering::Relaxed);
        stats
            .cached_tokens
            .store(decode.total_cached_tokens(), Ordering::Relaxed);
        stats.iterations.store(iters, Ordering::Relaxed);
    };

    let mut iterations = 0u64;
    publish(&prefill_q, &decode, iterations); // initial state (all free)
    loop {
        // 1. Drain commands without blocking (blocking only when idle).
        let has_work = !prefill_q.is_empty()
            || decode.active_count() > 0
            || !pending_decode.is_empty();
        let cmd = if has_work {
            rx.try_recv().ok()
        } else {
            rx.recv().ok()
        };
        match cmd {
            Some(EngineCmd::Shutdown) | None if !has_work => return,
            Some(EngineCmd::Shutdown) => return,
            Some(EngineCmd::Prefill { req, prompt }) => {
                prefill_q.push_back((req, prompt));
            }
            Some(cmd @ EngineCmd::StartDecode { .. }) => pending_decode.push_back(cmd),
            Some(EngineCmd::BlockingPrefill { prompt, reply }) => {
                let r = rt
                    .prefill(&prompt)
                    .map(|o| o.first_token)
                    .map_err(|e| e.to_string());
                let _ = reply.send(r);
            }
            None => {}
        }

        // 2. Admit pending decode adoptions into free slots.
        while let Some(slot) = decode.free_slot() {
            let cmd = match pending_decode.pop_front() {
                Some(c) => c,
                None => break,
            };
            if let EngineCmd::StartDecode {
                req,
                prompt_len,
                first_token,
                k,
                v,
                bucket,
                remaining,
            } = cmd
            {
                if prompt_len + remaining > decode.capacity_per_slot() {
                    let _ = events.send(EngineEvent::Failed {
                        req,
                        error: format!(
                            "request needs {} tokens > slot capacity {}",
                            prompt_len + remaining,
                            decode.capacity_per_slot()
                        ),
                    });
                    continue;
                }
                decode.insert_prefill(slot, prompt_len, &k, &v, first_token, bucket);
                slots[slot] = Some(SlotState {
                    req,
                    remaining,
                    tokens: vec![first_token],
                });
            }
        }

        // 3. One queued prefill (whole bucket — prompts are short here).
        if let Some((req, prompt)) = prefill_q.pop_front() {
            match rt.prefill(&prompt) {
                Ok(out) => {
                    let _ = events.send(EngineEvent::PrefillDone {
                        req,
                        engine: id,
                        prompt_len: prompt.len(),
                        first_token: out.first_token,
                        k: out.k,
                        v: out.v,
                        bucket: out.bucket,
                    });
                }
                Err(e) => {
                    let _ = events.send(EngineEvent::Failed {
                        req,
                        error: e.to_string(),
                    });
                }
            }
        }

        // 4. One decode iteration over all active slots.
        if decode.active_count() > 0 {
            match rt.decode_step(&mut decode) {
                Ok(next) => {
                    iterations += 1;
                    for slot in 0..slots.len() {
                        let finished = if let Some(st) = slots[slot].as_mut() {
                            st.tokens.push(next[slot]);
                            st.remaining -= 1;
                            st.remaining == 0
                        } else {
                            false
                        };
                        if finished {
                            let st = slots[slot].take().unwrap();
                            decode.release(slot);
                            let _ = events.send(EngineEvent::DecodeDone {
                                req: st.req,
                                tokens: st.tokens,
                            });
                        }
                    }
                }
                Err(e) => {
                    // Fail everything in the batch — engine-level error.
                    for slot in 0..slots.len() {
                        if let Some(st) = slots[slot].take() {
                            decode.release(slot);
                            let _ = events.send(EngineEvent::Failed {
                                req: st.req,
                                error: e.to_string(),
                            });
                        }
                    }
                }
            }
        }

        publish(&prefill_q, &decode, iterations);
    }
}
