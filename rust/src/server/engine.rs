//! A real-mode stateless engine: one OS thread owning a [`ModelRuntime`]
//! (its own PJRT client + compiled executables) and a decode batch state.
//!
//! The engine accepts both prefill and decode work (stateless instances,
//! paper §5.2) and runs a continuous-batching loop: each pass drains
//! pending commands, serves one queued prefill, then executes one decode
//! iteration over all active slots.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{DecodeBatchState, ModelRuntime};

/// Commands from the coordinator to an engine.
pub enum EngineCmd {
    /// Run the prefill phase of a request. The prompt is shared with the
    /// coordinator's retained copy (failure re-dispatch) — an `Arc`
    /// refcount, not a per-dispatch memcpy of up-to-60k-token prompts.
    /// `rank` is the SLO-class queue priority (PR 8): lower ranks are
    /// served first, equal ranks keep FIFO order, so an all-default-rank
    /// stream behaves exactly like the old plain queue.
    Prefill {
        req: u64,
        prompt: Arc<[i32]>,
        rank: u8,
    },
    /// Adopt a prefilled request for decoding (KV slab included — this is
    /// the migration payload when the prefill ran elsewhere).
    StartDecode {
        req: u64,
        prompt_len: usize,
        first_token: i32,
        k: Vec<f32>,
        v: Vec<f32>,
        bucket: usize,
        remaining: usize,
    },
    /// Synchronous prefill used by startup profiling.
    BlockingPrefill {
        prompt: Vec<i32>,
        reply: mpsc::Sender<Result<i32, String>>,
    },
    Shutdown,
}

/// Events from engines back to the coordinator.
pub enum EngineEvent {
    PrefillDone {
        req: u64,
        engine: usize,
        prompt_len: usize,
        first_token: i32,
        k: Vec<f32>,
        v: Vec<f32>,
        bucket: usize,
    },
    DecodeDone {
        req: u64,
        /// Which engine completed the decode — lets the coordinator drop
        /// stale events from an engine it already declared failed.
        engine: usize,
        /// All output tokens (first token included).
        tokens: Vec<i32>,
    },
    Failed {
        req: u64,
        engine: usize,
        error: String,
    },
}

/// Live load metrics published by the engine (lock-free reads). These
/// are the raw inputs of the server's [`crate::sched::ClusterView`]
/// adapter (`server::view::ServerView`): `cached_tokens` is the paper's
/// "running tokens" decode-load metric, `kv_capacity_tokens` the memory
/// bound, and `token_interval_s` the §5.3 recent-token-interval TPOT
/// proxy (NaN until the first decode iterations happen).
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub prefill_queue: usize,
    pub active_slots: usize,
    pub free_slots: usize,
    pub cached_tokens: u64,
    pub iterations: u64,
    /// Total KV tokens this engine can hold (slots × per-slot capacity).
    pub kv_capacity_tokens: u64,
    /// Recent average wall-clock gap between decode iterations (an EMA);
    /// NaN when no decode iterations have run recently.
    pub token_interval_s: f64,
    /// Decode adoptions accepted but not yet in a slot (the engine-side
    /// analog of the simulator's `decode_wait` parking queue). Counted
    /// into scheduler-visible decode load so the handoff window cannot
    /// make an engine look idle.
    pub pending_decode_reqs: usize,
    /// Prompt KV tokens across those pending adoptions.
    pub pending_decode_tokens: u64,
}

struct SharedStats {
    prefill_queue: AtomicUsize,
    active_slots: AtomicUsize,
    free_slots: AtomicUsize,
    cached_tokens: AtomicU64,
    iterations: AtomicU64,
    kv_capacity: AtomicU64,
    /// f64 bits of the token-interval EMA (NaN = no evidence yet).
    token_interval_bits: AtomicU64,
    pending_decode_reqs: AtomicUsize,
    pending_decode_tokens: AtomicU64,
}

impl SharedStats {
    fn new() -> Self {
        SharedStats {
            prefill_queue: AtomicUsize::new(0),
            active_slots: AtomicUsize::new(0),
            free_slots: AtomicUsize::new(0),
            cached_tokens: AtomicU64::new(0),
            iterations: AtomicU64::new(0),
            kv_capacity: AtomicU64::new(0),
            token_interval_bits: AtomicU64::new(f64::NAN.to_bits()),
            pending_decode_reqs: AtomicUsize::new(0),
            pending_decode_tokens: AtomicU64::new(0),
        }
    }
}

/// Handle to a spawned engine thread.
pub struct EngineHandle {
    pub id: usize,
    tx: mpsc::Sender<EngineCmd>,
    stats: Arc<SharedStats>,
    buckets: Vec<usize>,
}

impl EngineHandle {
    pub fn spawn(
        id: usize,
        artifacts_dir: &str,
        events: mpsc::Sender<EngineEvent>,
    ) -> Result<EngineHandle> {
        let rt = ModelRuntime::load(artifacts_dir)?;
        EngineHandle::start(id, rt, events)
    }

    /// Start the engine thread around an already-loaded runtime. Cheap —
    /// the expensive half of [`EngineHandle::spawn`] is `ModelRuntime::
    /// load`, which elastic scale-out runs on a helper thread so the
    /// coordinator never stalls (the loaded runtime then registers
    /// through the coordinator channel and gets its slot id here).
    pub fn start(
        id: usize,
        rt: ModelRuntime,
        events: mpsc::Sender<EngineEvent>,
    ) -> Result<EngineHandle> {
        let buckets = rt.info.prefill_buckets.clone();
        let (tx, rx) = mpsc::channel::<EngineCmd>();
        let stats = Arc::new(SharedStats::new());
        // KV capacity is fixed by the loaded artifacts; publish it here,
        // before the engine thread even starts, so startup profiling can
        // never observe a zero capacity.
        stats.kv_capacity.store(
            (rt.info.decode_batch * rt.info.max_seq_len) as u64,
            Ordering::Relaxed,
        );
        let stats_thread = Arc::clone(&stats);
        std::thread::Builder::new()
            .name(format!("engine-{id}"))
            .spawn(move || engine_loop(id, rt, rx, events, stats_thread))?;
        Ok(EngineHandle {
            id,
            tx,
            stats,
            buckets,
        })
    }

    pub fn clone_handle(&self) -> EngineHandle {
        EngineHandle {
            id: self.id,
            tx: self.tx.clone(),
            stats: Arc::clone(&self.stats),
            buckets: self.buckets.clone(),
        }
    }

    pub fn send(&self, cmd: EngineCmd) -> Result<(), mpsc::SendError<EngineCmd>> {
        self.tx.send(cmd)
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            prefill_queue: self.stats.prefill_queue.load(Ordering::Relaxed),
            active_slots: self.stats.active_slots.load(Ordering::Relaxed),
            free_slots: self.stats.free_slots.load(Ordering::Relaxed),
            cached_tokens: self.stats.cached_tokens.load(Ordering::Relaxed),
            iterations: self.stats.iterations.load(Ordering::Relaxed),
            kv_capacity_tokens: self.stats.kv_capacity.load(Ordering::Relaxed),
            token_interval_s: f64::from_bits(
                self.stats.token_interval_bits.load(Ordering::Relaxed),
            ),
            pending_decode_reqs: self.stats.pending_decode_reqs.load(Ordering::Relaxed),
            pending_decode_tokens: self.stats.pending_decode_tokens.load(Ordering::Relaxed),
        }
    }

    pub fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    /// Synchronous prefill (startup profiling only).
    pub fn blocking_prefill(&self, prompt: &[i32]) -> Result<i32, String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(EngineCmd::BlockingPrefill {
                prompt: prompt.to_vec(),
                reply,
            })
            .map_err(|e| e.to_string())?;
        rx.recv().map_err(|e| e.to_string())?
    }
}

/// Per-slot decode bookkeeping inside the engine loop.
struct SlotState {
    req: u64,
    remaining: usize,
    tokens: Vec<i32>,
}

fn engine_loop(
    id: usize,
    rt: ModelRuntime,
    rx: mpsc::Receiver<EngineCmd>,
    events: mpsc::Sender<EngineEvent>,
    stats: Arc<SharedStats>,
) {
    let mut decode = rt.new_decode_state();
    let mut slots: Vec<Option<SlotState>> = (0..decode.batch()).map(|_| None).collect();
    let mut prefill_q: VecDeque<(u64, Arc<[i32]>, u8)> = VecDeque::new();
    let mut pending_decode: VecDeque<EngineCmd> = VecDeque::new();
    // Recent token-interval EMA (paper §5.3 TPOT proxy). Idle gaps are
    // not decode evidence: the anchor resets when the batch drains.
    let mut last_decode_iter: Option<Instant> = None;
    let mut interval_ema = f64::NAN;

    let publish = |prefill_q: &VecDeque<(u64, Arc<[i32]>, u8)>,
                   pending_decode: &VecDeque<EngineCmd>,
                   decode: &DecodeBatchState,
                   iters: u64| {
        stats
            .prefill_queue
            .store(prefill_q.len(), Ordering::Relaxed);
        stats
            .active_slots
            .store(decode.active_count(), Ordering::Relaxed);
        stats
            .free_slots
            .store(decode.batch() - decode.active_count(), Ordering::Relaxed);
        stats
            .cached_tokens
            .store(decode.total_cached_tokens(), Ordering::Relaxed);
        stats.iterations.store(iters, Ordering::Relaxed);
        // Parked adoptions are decode load the slots don't show yet.
        let mut pend_tokens = 0u64;
        for c in pending_decode {
            if let EngineCmd::StartDecode { prompt_len, .. } = c {
                pend_tokens += *prompt_len as u64;
            }
        }
        stats
            .pending_decode_reqs
            .store(pending_decode.len(), Ordering::Relaxed);
        stats
            .pending_decode_tokens
            .store(pend_tokens, Ordering::Relaxed);
    };

    let mut iterations = 0u64;
    publish(&prefill_q, &pending_decode, &decode, iterations); // initial state
    loop {
        // 1. Drain ALL queued commands (blocking only when idle).
        //    Draining the whole channel each pass keeps the published
        //    pending-decode load fresh even while long prefills occupy
        //    the loop — the scheduler must never see a stale "idle".
        let has_work = !prefill_q.is_empty()
            || decode.active_count() > 0
            || !pending_decode.is_empty();
        let mut cmd = if has_work {
            rx.try_recv().ok()
        } else {
            rx.recv().ok()
        };
        if cmd.is_none() && !has_work {
            return; // channel closed while idle
        }
        while let Some(c) = cmd {
            match c {
                EngineCmd::Shutdown => return,
                EngineCmd::Prefill { req, prompt, rank } => {
                    // Rank-ordered insert (PR 8): before the first entry
                    // with a *strictly* greater rank — equal ranks stay
                    // FIFO. Unlike the simulator there is no in-progress
                    // head to protect: step 3 below always runs the
                    // popped prefill to completion in the same pass.
                    let pos = (0..prefill_q.len())
                        .find(|&i| prefill_q[i].2 > rank)
                        .unwrap_or(prefill_q.len());
                    prefill_q.insert(pos, (req, prompt, rank));
                }
                c @ EngineCmd::StartDecode { .. } => pending_decode.push_back(c),
                EngineCmd::BlockingPrefill { prompt, reply } => {
                    let r = rt
                        .prefill(&prompt)
                        .map(|o| o.first_token)
                        .map_err(|e| e.to_string());
                    let _ = reply.send(r);
                }
            }
            cmd = rx.try_recv().ok();
        }

        // 2. Admit pending decode adoptions into free slots.
        while let Some(slot) = decode.free_slot() {
            let cmd = match pending_decode.pop_front() {
                Some(c) => c,
                None => break,
            };
            if let EngineCmd::StartDecode {
                req,
                prompt_len,
                first_token,
                k,
                v,
                bucket,
                remaining,
            } = cmd
            {
                if prompt_len + remaining > decode.capacity_per_slot() {
                    let _ = events.send(EngineEvent::Failed {
                        req,
                        engine: id,
                        error: format!(
                            "request needs {} tokens > slot capacity {}",
                            prompt_len + remaining,
                            decode.capacity_per_slot()
                        ),
                    });
                    continue;
                }
                decode.insert_prefill(slot, prompt_len, &k, &v, first_token, bucket);
                slots[slot] = Some(SlotState {
                    req,
                    remaining,
                    tokens: vec![first_token],
                });
            }
        }

        // 3. One queued prefill (whole bucket — prompts are short here).
        if let Some((req, prompt, _rank)) = prefill_q.pop_front() {
            match rt.prefill(&prompt) {
                Ok(out) => {
                    let _ = events.send(EngineEvent::PrefillDone {
                        req,
                        engine: id,
                        prompt_len: prompt.len(),
                        first_token: out.first_token,
                        k: out.k,
                        v: out.v,
                        bucket: out.bucket,
                    });
                }
                Err(e) => {
                    let _ = events.send(EngineEvent::Failed {
                        req,
                        engine: id,
                        error: e.to_string(),
                    });
                }
            }
        }

        // 4. One decode iteration over all active slots.
        if decode.active_count() > 0 {
            match rt.decode_step(&mut decode) {
                Ok(next) => {
                    iterations += 1;
                    let t_iter = Instant::now();
                    if let Some(prev) = last_decode_iter {
                        let gap = t_iter.duration_since(prev).as_secs_f64();
                        interval_ema = if interval_ema.is_nan() {
                            gap
                        } else {
                            0.8 * interval_ema + 0.2 * gap
                        };
                        stats
                            .token_interval_bits
                            .store(interval_ema.to_bits(), Ordering::Relaxed);
                    }
                    last_decode_iter = Some(t_iter);
                    for slot in 0..slots.len() {
                        let finished = if let Some(st) = slots[slot].as_mut() {
                            st.tokens.push(next[slot]);
                            st.remaining -= 1;
                            st.remaining == 0
                        } else {
                            false
                        };
                        if finished {
                            let st = slots[slot].take().unwrap();
                            decode.release(slot);
                            let _ = events.send(EngineEvent::DecodeDone {
                                req: st.req,
                                engine: id,
                                tokens: st.tokens,
                            });
                        }
                    }
                }
                Err(e) => {
                    // Fail everything in the batch — engine-level error.
                    for slot in 0..slots.len() {
                        if let Some(st) = slots[slot].take() {
                            decode.release(slot);
                            let _ = events.send(EngineEvent::Failed {
                                req: st.req,
                                engine: id,
                                error: e.to_string(),
                            });
                        }
                    }
                }
            }
        }

        if decode.active_count() == 0 {
            // Batch drained: both the anchor AND the published EMA reset,
            // so an idle engine reads as "no recent evidence" (NaN), not
            // as a frozen snapshot of its last (possibly violating)
            // interval that would trigger spurious TPOT flips.
            last_decode_iter = None;
            interval_ema = f64::NAN;
            stats
                .token_interval_bits
                .store(f64::NAN.to_bits(), Ordering::Relaxed);
        }
        publish(&prefill_q, &pending_decode, &decode, iterations);
    }
}
