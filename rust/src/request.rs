//! Core request types shared by the simulator, the coordinator and the
//! real-mode server.
//!
//! Following the paper's key insight (§3.4), *prefill* and *decode* are
//! properties of requests, not instances: a request is split into a
//! prefill sub-request and a decode sub-request that the global scheduler
//! places independently (possibly on different stateless instances).

/// Seconds since the start of the run (simulated or wall-clock).
pub type Time = f64;

/// Globally unique request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// Stateless-instance id (index into the cluster's instance table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub usize);

impl std::fmt::Display for RequestId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "r{}", self.0)
    }
}

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "i{}", self.0)
    }
}

/// Which phase a sub-request belongs to (paper Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Service tier of a request (PR 8). The paper's goodput criterion (§6)
/// judges every request against one TTFT/TPOT pair; production traffic is
/// tiered — interactive chat, standard API calls, and batch/background
/// jobs each carry their own deadlines. A class scales the workload's
/// base SLO pair and carries a priority rank used by class-aware
/// scheduling and admission control.
///
/// `Standard` reproduces today's behavior exactly: its targets *are* the
/// base pair (no arithmetic applied), its rank is the default queue
/// rank, and it is never shed ahead of other work — so an all-Standard
/// trace (the default) schedules bit-identically to a class-blind run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum SloClass {
    /// Tight deadlines (0.5x the base TTFT/TPOT): chat-style traffic.
    Interactive,
    /// The workload's base SLO pair, unchanged.
    #[default]
    Standard,
    /// Lax deadlines (4x base): summarization / background agents. First
    /// to be deprioritized and first to be shed under overload.
    Batch,
}

impl SloClass {
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    /// Queue priority: lower ranks run first. Standard keeps rank equal
    /// to the implicit FIFO rank of a class-blind queue minus nothing —
    /// equal ranks preserve arrival order, so all-Standard traffic is
    /// scheduled exactly as before.
    pub fn priority_rank(self) -> u8 {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    /// This class's TTFT target given the workload's base target.
    /// Standard returns `base` untouched (no multiply — bit-stable).
    pub fn ttft_slo(self, base: f64) -> f64 {
        match self {
            SloClass::Interactive => 0.5 * base,
            SloClass::Standard => base,
            SloClass::Batch => 4.0 * base,
        }
    }

    /// This class's TPOT target given the workload's base target.
    pub fn tpot_slo(self, base: f64) -> f64 {
        match self {
            SloClass::Interactive => 0.5 * base,
            SloClass::Standard => base,
            SloClass::Batch => 4.0 * base,
        }
    }

    /// Stable per-class index for counter arrays (`[T; 3]`).
    pub fn index(self) -> usize {
        match self {
            SloClass::Interactive => 0,
            SloClass::Standard => 1,
            SloClass::Batch => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    /// Parse a class label (HTTP body field / CLI); `None` on unknown.
    pub fn from_label(s: &str) -> Option<SloClass> {
        match s {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }
}

/// A request as it arrives at the frontend: timestamps and lengths only —
/// exactly what the production traces record (§3.1).
///
/// `Copy`: the struct is a handful of bytes of plain data, and the
/// simulator's hot path hands requests to the policy on every
/// arrival/prefill-done event — passing by value must never allocate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time (seconds from run start).
    pub arrival: Time,
    /// Number of prompt tokens.
    pub input_len: u32,
    /// Number of tokens to generate (from the trace; the simulator stops
    /// the request after this many tokens — stand-in for EOS).
    pub output_len: u32,
    /// Service tier (PR 8). Defaults to [`SloClass::Standard`], which is
    /// indistinguishable from the pre-class behavior.
    pub class: SloClass,
}

impl Request {
    pub fn new(id: u64, arrival: Time, input_len: u32, output_len: u32) -> Self {
        Request {
            id: RequestId(id),
            arrival,
            input_len: input_len.max(1),
            output_len: output_len.max(1),
            class: SloClass::Standard,
        }
    }

    /// Builder-style class override (trace layer / server frontend).
    pub fn with_class(mut self, class: SloClass) -> Self {
        self.class = class;
        self
    }

    /// Total KV-cache tokens this request will occupy at completion.
    pub fn total_tokens(&self) -> u64 {
        self.input_len as u64 + self.output_len as u64
    }
}

/// Lifecycle of a request moving through the disaggregated pipeline
/// (paper Fig. 3: q1 → p1 → q2 → c → q3 → p2..pm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Waiting in a prefill instance's queue (q1).
    PrefillQueued,
    /// Prefill computation running (p1), chunked.
    Prefilling,
    /// Prefill done; waiting for the decode instance to fetch KV (q2 + c).
    Migrating,
    /// In the decode instance's queue, KV present (q3).
    DecodeQueued,
    /// Iterative decode in progress (p2..pm).
    Decoding,
    /// All output tokens produced.
    Finished,
    /// Dropped (OOM / capacity exhaustion in a baseline system).
    Failed,
}

/// Why a request was explicitly shed (PR 6 chaos contract: a request may
/// fail, but it must never be *silently* lost — every `Failed` record
/// carries its reason).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Arrived when no in-cluster instance could accept it.
    NoCapacity,
    /// Prompt larger than any instance's KV capacity.
    Oversized,
    /// KV migration timed out (and retries, if enabled, were exhausted).
    TransferTimeout,
    /// Still unfinished when the run ended (force-failed by the sweep).
    DeadlineExceeded,
}

/// Per-request latency record — everything the metrics layer needs to
/// compute TTFT, TPOT, and SLO attainment.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: RequestId,
    pub arrival: Time,
    pub input_len: u32,
    pub output_len: u32,
    /// Service tier (PR 8): copied from the request at admission; the
    /// metrics layer judges each record against its *own* class targets.
    pub class: SloClass,
    /// Time the first token was emitted (end of prefill). None => failed
    /// before prefill completed.
    pub first_token: Option<Time>,
    /// Emission time of every output token (first included). Populated
    /// only in retained mode ([`RequestRecord::new`]) — golden digests and
    /// the chaos tier compare it bit-for-bit. Streaming records
    /// ([`RequestRecord::new_streaming`]) never allocate it; TTFT/TPOT/
    /// max-gap come from the incremental folds maintained by
    /// [`RequestRecord::push_token`], which are bit-identical in both
    /// modes.
    pub token_times: Vec<Time>,
    /// Tokens emitted so far (== `token_times.len()` in retained mode).
    n_tokens: u32,
    /// Emission time of the most recent token (NaN before the first).
    last_token: Time,
    /// Folded max inter-token gap under `total_cmp` (NaN below 2 tokens).
    max_gap: Time,
    /// Whether `push_token` also records into `token_times`.
    retain: bool,
    pub state: RequestState,
    /// Which instance ran the prefill / decode phases (for Fig. 4 + debug).
    pub prefill_instance: Option<InstanceId>,
    pub decode_instance: Option<InstanceId>,
    /// Set iff the request was explicitly shed: `state == Failed` without
    /// a reason is a *silently lost* request, which the chaos tier
    /// (`tests/chaos.rs`) treats as a bug.
    pub shed: Option<ShedReason>,
}

impl RequestRecord {
    pub fn new(req: &Request) -> Self {
        RequestRecord {
            id: req.id,
            arrival: req.arrival,
            input_len: req.input_len,
            output_len: req.output_len,
            class: req.class,
            first_token: None,
            // The simulator pushes exactly output_len token timestamps for
            // a finished request; reserving up front keeps the per-token
            // hot path free of reallocation.
            token_times: Vec::with_capacity(req.output_len as usize),
            n_tokens: 0,
            last_token: f64::NAN,
            max_gap: f64::NAN,
            retain: true,
            state: RequestState::PrefillQueued,
            prefill_instance: None,
            decode_instance: None,
            shed: None,
        }
    }

    /// Streaming-mode record: `token_times` is never allocated, so a
    /// record costs O(1) memory regardless of `output_len`. TTFT/TPOT/
    /// max-gap come from the same incremental folds as retained mode.
    pub fn new_streaming(req: &Request) -> Self {
        let mut rec = RequestRecord::new(req);
        rec.token_times = Vec::new();
        rec.retain = false;
        rec
    }

    /// Record a token emission at time `t`. Sets `first_token` on the
    /// first call, folds the inter-token gap incrementally (same
    /// `total_cmp` max as re-walking `token_times`, bit for bit), and
    /// appends to `token_times` only in retained mode.
    pub fn push_token(&mut self, t: Time) {
        if self.first_token.is_none() {
            self.first_token = Some(t);
        }
        if self.n_tokens > 0 {
            let gap = t - self.last_token;
            self.max_gap = if self.n_tokens == 1 {
                gap
            } else {
                // Equal under total_cmp implies identical bits, so
                // keeping the incumbent matches Iterator::max_by exactly.
                match self.max_gap.total_cmp(&gap) {
                    std::cmp::Ordering::Less => gap,
                    _ => self.max_gap,
                }
            };
        }
        self.last_token = t;
        self.n_tokens += 1;
        if self.retain {
            self.token_times.push(t);
        }
    }

    /// Forget all emitted tokens (fault-recovery restart: the request is
    /// re-prefilled from scratch, so its latency clock starts over).
    pub fn reset_tokens(&mut self) {
        self.first_token = None;
        self.token_times.clear();
        self.n_tokens = 0;
        self.last_token = f64::NAN;
        self.max_gap = f64::NAN;
    }

    /// Tokens emitted so far (`token_times.len()` without needing the
    /// vector — valid in streaming mode too).
    pub fn tokens_emitted(&self) -> u32 {
        self.n_tokens
    }

    /// Time-to-first-token (paper Eq. 1): q1 + p1.
    pub fn ttft(&self) -> Option<f64> {
        self.first_token.map(|t| t - self.arrival)
    }

    /// Time-per-output-token (paper Eq. 3): mean inter-token gap. A
    /// one-token request has TPOT 0 by the paper's definition.
    pub fn tpot(&self) -> Option<f64> {
        let ft = self.first_token?;
        let m = self.n_tokens;
        if m == 0 {
            return None;
        }
        if m == 1 {
            return Some(0.0);
        }
        Some((self.last_token - ft) / (m - 1) as f64)
    }

    /// Maximum inter-token gap (stall detector; stricter than mean TPOT).
    /// Folded at push time; a NaN timestamp (broken trace/clock) surfaces
    /// as a NaN gap via `total_cmp`, never as a panic.
    pub fn max_token_gap(&self) -> Option<f64> {
        if self.n_tokens < 2 {
            return None;
        }
        Some(self.max_gap)
    }

    pub fn finished(&self) -> bool {
        self.state == RequestState::Finished
    }

    /// Did this request meet both SLOs? Unfinished/failed => violated.
    pub fn meets_slo(&self, ttft_slo: f64, tpot_slo: f64) -> bool {
        if !self.finished() {
            return false;
        }
        match (self.ttft(), self.tpot()) {
            (Some(a), Some(b)) => a <= ttft_slo && b <= tpot_slo,
            _ => false,
        }
    }

    /// Did this request meet *its own class's* SLOs, derived from the
    /// workload's base pair? For `Standard` this is exactly
    /// [`RequestRecord::meets_slo`] on the base pair (no arithmetic).
    pub fn meets_class_slo(&self, base_ttft: f64, base_tpot: f64) -> bool {
        self.meets_slo(
            self.class.ttft_slo(base_ttft),
            self.class.tpot_slo(base_tpot),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_record(arrival: f64, times: &[f64]) -> RequestRecord {
        let req = Request::new(1, arrival, 10, times.len() as u32);
        let mut rec = RequestRecord::new(&req);
        for &t in times {
            rec.push_token(t);
        }
        if !times.is_empty() {
            rec.state = RequestState::Finished;
        }
        rec
    }

    #[test]
    fn ttft_is_first_token_minus_arrival() {
        let rec = mk_record(1.0, &[3.5, 4.0, 4.5]);
        assert!((rec.ttft().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn tpot_mean_gap() {
        // gaps: 0.5, 0.5 -> tpot 0.5
        let rec = mk_record(0.0, &[1.0, 1.5, 2.0]);
        assert!((rec.tpot().unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tpot_single_token_zero() {
        // Paper Eq. 3: m == 1 => TPOT = 0.
        let rec = mk_record(0.0, &[1.0]);
        assert_eq!(rec.tpot(), Some(0.0));
    }

    #[test]
    fn tpot_nonmonotone_example() {
        // Paper §4.3 non-monotonicity: a late stall can still average out.
        let early = mk_record(0.0, &[1.0, 1.1, 1.2, 4.0]); // stall at end
        let late = mk_record(0.0, &[1.0, 2.0, 2.05, 2.1]);
        assert!(early.max_token_gap().unwrap() > late.max_token_gap().unwrap());
        // but mean TPOT of `early` (1.0) equals... compute:
        assert!((early.tpot().unwrap() - 1.0).abs() < 1e-9);
        assert!(late.tpot().unwrap() < early.tpot().unwrap());
    }

    #[test]
    fn slo_requires_finish() {
        let req = Request::new(2, 0.0, 5, 5);
        let rec = RequestRecord::new(&req);
        assert!(!rec.meets_slo(100.0, 100.0));
        let ok = mk_record(0.0, &[0.5, 0.6]);
        assert!(ok.meets_slo(1.0, 0.2));
        assert!(!ok.meets_slo(0.4, 0.2)); // ttft 0.5 > 0.4
        assert!(!ok.meets_slo(1.0, 0.05)); // tpot 0.1 > 0.05
    }

    /// PR 7: the incremental folds must agree bit-for-bit with re-walking
    /// `token_times`, and streaming records (no vector at all) must agree
    /// with retained ones, including through a reset (restart path).
    #[test]
    fn incremental_folds_match_token_times_rewalk() {
        let cases: &[&[f64]] = &[
            &[],
            &[1.0],
            &[1.0, 1.5, 2.0],
            &[1.0, 1.1, 1.2, 4.0],
            &[1.0, f64::NAN, 2.0],
            &[3.0, 3.0, 3.0],
            &[0.0, -0.0, 1.0],
        ];
        for times in cases {
            let retained = mk_record(0.0, times);
            // Oracle: re-walk the retained vector the pre-PR-7 way.
            let walk_gap = retained
                .token_times
                .windows(2)
                .map(|w| w[1] - w[0])
                .max_by(|a, b| a.total_cmp(b));
            assert_eq!(
                retained.max_token_gap().map(f64::to_bits),
                walk_gap.map(f64::to_bits),
                "fold vs rewalk: {times:?}"
            );
            assert_eq!(retained.tokens_emitted() as usize, times.len());
            // Streaming twin: no token_times allocation, same metrics.
            let req = Request::new(1, 0.0, 10, times.len().max(1) as u32);
            let mut streaming = RequestRecord::new_streaming(&req);
            assert_eq!(streaming.token_times.capacity(), 0);
            for &t in *times {
                streaming.push_token(t);
            }
            assert!(streaming.token_times.is_empty());
            assert_eq!(
                streaming.ttft().map(f64::to_bits),
                retained.ttft().map(f64::to_bits)
            );
            assert_eq!(
                streaming.tpot().map(f64::to_bits),
                retained.tpot().map(f64::to_bits)
            );
            assert_eq!(
                streaming.max_token_gap().map(f64::to_bits),
                retained.max_token_gap().map(f64::to_bits)
            );
            // Reset (fault-recovery restart) clears every fold.
            streaming.reset_tokens();
            assert_eq!(streaming.first_token, None);
            assert_eq!(streaming.tokens_emitted(), 0);
            assert_eq!(streaming.tpot(), None);
            assert_eq!(streaming.max_token_gap(), None);
        }
    }

    #[test]
    fn request_min_lengths_clamped() {
        let r = Request::new(3, 0.0, 0, 0);
        assert_eq!(r.input_len, 1);
        assert_eq!(r.output_len, 1);
        assert_eq!(r.total_tokens(), 2);
    }

    /// PR 8: Standard is the default class and its targets are the base
    /// pair *bit for bit* — no multiply may sneak in, or all-default
    /// traces would stop reproducing pre-class schedules/metrics exactly.
    #[test]
    fn standard_class_is_transparent() {
        let r = Request::new(4, 0.0, 5, 5);
        assert_eq!(r.class, SloClass::Standard);
        for base in [3.0, 0.1, 0.3 + 0.1 + 0.2, f64::MIN_POSITIVE] {
            assert_eq!(SloClass::Standard.ttft_slo(base).to_bits(), base.to_bits());
            assert_eq!(SloClass::Standard.tpot_slo(base).to_bits(), base.to_bits());
        }
        let rec = mk_record(0.0, &[0.5, 0.6]);
        assert_eq!(rec.meets_class_slo(1.0, 0.2), rec.meets_slo(1.0, 0.2));
    }

    #[test]
    fn class_ranks_and_targets_are_ordered() {
        assert!(SloClass::Interactive.priority_rank() < SloClass::Standard.priority_rank());
        assert!(SloClass::Standard.priority_rank() < SloClass::Batch.priority_rank());
        assert!(SloClass::Interactive.ttft_slo(2.0) < SloClass::Standard.ttft_slo(2.0));
        assert!(SloClass::Standard.tpot_slo(0.1) < SloClass::Batch.tpot_slo(0.1));
        for (i, c) in SloClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(SloClass::from_label(c.label()), Some(*c));
        }
        assert_eq!(SloClass::from_label("premium"), None);
    }

    #[test]
    fn class_flows_from_request_to_record() {
        let r = Request::new(5, 0.0, 5, 5).with_class(SloClass::Batch);
        assert_eq!(r.class, SloClass::Batch);
        let rec = RequestRecord::new(&r);
        assert_eq!(rec.class, SloClass::Batch);
        // Batch targets are 4x base: a TTFT of 3.0 misses base 1.0 but
        // meets the batch-scaled 4.0.
        let mut rec = rec;
        rec.push_token(3.0);
        rec.push_token(3.05);
        rec.state = RequestState::Finished;
        assert!(!rec.meets_slo(1.0, 0.2));
        assert!(rec.meets_class_slo(1.0, 0.2));
    }
}
