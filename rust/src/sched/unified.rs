//! Unified-elastic scheduling (PR 10): the DynaServe/DOPD-style
//! adversary where **every** instance serves both phases and a movable
//! **cut point** balances prefill-vs-decode token share per instance.
//!
//! Where Arrow partitions instances into elastic *pools* and moves whole
//! instances between roles, [`UnifiedPolicy`] keeps one flat membership
//! (each instance sits in exactly one pool slot — the `Prefill` slot, by
//! convention — and never flips) and instead moves a scalar `cut ∈
//! [cut_min, cut_max]`: the target fraction of each instance's resident
//! token load that should be prefill work. Placement steers toward the
//! cut:
//!
//! * **Prefill** goes to the member with the least *cut-weighted* token
//!   load — prefill tokens priced at `1/cut`, decode tokens at
//!   `1/(1-cut)` — so at equilibrium every member's prefill share of
//!   resident tokens converges to the cut.
//! * **Decode** stays **local** to the prefill instance (every member is
//!   decode-capable, so the KV never moves — the unified design's core
//!   economy); only a departed instance forces a migration to the
//!   least-loaded member.
//!
//! The cut itself is re-derived on monitor ticks from the same integer
//! queue-delay moments Arrow prices queues with: mean predicted prefill
//! delay (via [`TtftPredictor::queue_delay_moments`]) against the TTFT
//! budget raises it, TPOT breaches or decode utilization above the
//! watermark lower it, calm reverts it toward the balanced midpoint.
//! Every comparison is a ratio of SLO-derived quantities and every step
//! is a dimensionless fraction, so cost-scale invariance holds by
//! construction — the metamorphic tier pins it.

use crate::coordinator::pools::{Pool, Pools};
use crate::coordinator::predictor::TtftPredictor;
use crate::request::{InstanceId, Request, Time};
use crate::sched::{ClusterView, MembershipEvent, Policy, ProfileSource};

/// Tunables for [`UnifiedPolicy`]. All fractions/ratios — no absolute
/// seconds anywhere near a placement path.
#[derive(Debug, Clone)]
pub struct UnifiedConfig {
    /// TTFT SLO the cut controller judges prefill pressure against.
    pub ttft_slo: f64,
    /// TPOT SLO the cut controller judges decode pressure against.
    pub tpot_slo: f64,
    /// Decode utilization (fraction of each member's capacity) above
    /// which decode counts as pressed.
    pub decode_watermark: f64,
    /// Cut-point bounds: prefill may never claim less/more than this
    /// share of a member's token load.
    pub cut_min: f64,
    pub cut_max: f64,
    /// Per-tick cut adjustment step.
    pub cut_step: f64,
    /// Fraction of the TTFT budget the mean predicted queue delay may
    /// reach before prefill counts as pressed.
    pub pressure_frac: f64,
}

impl UnifiedConfig {
    pub fn new(ttft_slo: f64, tpot_slo: f64) -> Self {
        UnifiedConfig {
            ttft_slo,
            tpot_slo,
            decode_watermark: 0.5,
            cut_min: 0.1,
            cut_max: 0.9,
            cut_step: 0.05,
            pressure_frac: 0.5,
        }
    }
}

/// Unified-elastic policy. See module docs.
pub struct UnifiedPolicy {
    cfg: UnifiedConfig,
    /// Flat membership: every member lives in the `Prefill` slot and
    /// never transitions — `pool_sizes()` reports `[n, 0, 0, 0]` and
    /// `flip_count()` stays 0 (the flip-conservation property is trivial
    /// for a policy that moves a cut point instead of instances).
    members: Pools,
    /// Movable cut point: target prefill share of per-member token load.
    cut: f64,
    predictors: Vec<TtftPredictor>,
    max_running_tokens: Vec<u64>,
}

impl UnifiedPolicy {
    pub fn new(cfg: UnifiedConfig, n_instances: usize) -> Self {
        let cut = ((cfg.cut_min + cfg.cut_max) / 2.0).clamp(cfg.cut_min, cfg.cut_max);
        UnifiedPolicy {
            cfg,
            members: Pools::new(n_instances, n_instances),
            cut,
            predictors: Vec::new(),
            max_running_tokens: Vec::new(),
        }
    }

    /// Current cut point (tests / snapshots).
    pub fn cut(&self) -> f64 {
        self.cut
    }

    /// Flat membership bookkeeping (conformance tests).
    pub fn members(&self) -> &Pools {
        &self.members
    }

    fn predictor(&self, inst: usize) -> &TtftPredictor {
        self.predictors.get(inst).expect("policy not initialized")
    }

    fn mrt(&self, inst: usize) -> u64 {
        self.max_running_tokens.get(inst).copied().unwrap_or(u64::MAX)
    }

    /// Cut-weighted token load of member `i` if it accepted `incoming`
    /// more prefill tokens: prefill tokens priced at `1/cut`, decode
    /// tokens at `1/(1-cut)`. Argmin placement over this score drives
    /// each member's prefill share of resident tokens toward the cut
    /// (the bounds keep both denominators away from zero).
    fn weighted_load(&self, view: &dyn ClusterView, i: usize, incoming: u64) -> f64 {
        let p = view.prefill_queue_moments(i).sum_remaining + incoming;
        let d = view.running_tokens(i);
        p as f64 / self.cut + d as f64 / (1.0 - self.cut)
    }

    /// Last-ditch placement when the membership table is empty
    /// (everything lost/draining): first healthy live instance, then any
    /// placeable, else 0 — the same ladder Arrow ends on.
    fn last_ditch(view: &dyn ClusterView) -> InstanceId {
        (0..view.n_instances())
            .map(InstanceId)
            .find(|id| {
                let l = view.liveness(id.0);
                l.placeable() && !l.is_degraded()
            })
            .or_else(|| {
                (0..view.n_instances())
                    .map(InstanceId)
                    .find(|id| view.liveness(id.0).placeable())
            })
            .unwrap_or(InstanceId(0))
    }
}

impl Policy for UnifiedPolicy {
    fn name(&self) -> &'static str {
        "unified-elastic"
    }

    fn init(&mut self, profile: &dyn ProfileSource) {
        let n = profile.n_instances();
        self.predictors = (0..n).map(|i| profile.fit_predictor(i)).collect();
        self.max_running_tokens = (0..n)
            .map(|i| profile.max_running_tokens(i, self.cfg.tpot_slo))
            .collect();
    }

    fn place_prefill(&mut self, _now: Time, req: &Request, view: &dyn ClusterView) -> InstanceId {
        let incoming = req.input_len as u64;
        // First pass: healthy members with KV headroom, minimizing the
        // post-acceptance cut-weighted load (ties to lowest id; NaN
        // cannot arise — the score is a sum of finite ratios).
        let mut best: Option<(InstanceId, f64)> = None;
        let mut fallback: Option<InstanceId> = None;
        for id in self.members.members_iter(Pool::Prefill) {
            let i = id.0;
            let life = view.liveness(i);
            if !life.placeable() {
                continue;
            }
            if fallback.map_or(true, |f| id < f) {
                fallback = Some(id);
            }
            if life.is_degraded()
                || view.running_tokens(i) + incoming > view.max_kv_tokens(i)
            {
                continue;
            }
            let score = self.weighted_load(view, i, incoming);
            let better = match best {
                None => true,
                Some((bid, bs)) => match score.total_cmp(&bs) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Equal => id < bid,
                    std::cmp::Ordering::Greater => false,
                },
            };
            if better {
                best = Some((id, score));
            }
        }
        best.map(|(id, _)| id)
            .or(fallback)
            .unwrap_or_else(|| Self::last_ditch(view))
    }

    fn place_decode(
        &mut self,
        _now: Time,
        req: &Request,
        prefill_instance: InstanceId,
        view: &dyn ClusterView,
    ) -> InstanceId {
        // Every member serves both phases: decode stays where the KV
        // already is. Only a departed prefill instance forces migration.
        if self.members.contains(prefill_instance)
            && view.liveness(prefill_instance.0).in_cluster()
        {
            return prefill_instance;
        }
        // Migration target: least-loaded healthy member that fits the
        // incoming KV within capacity and its TPOT budget.
        let incoming = req.input_len as u64;
        let mut best: Option<(InstanceId, u64)> = None;
        let mut fallback: Option<InstanceId> = None;
        for id in self.members.members_iter(Pool::Prefill) {
            let i = id.0;
            if !view.liveness(i).placeable() {
                continue;
            }
            if fallback.map_or(true, |f| id < f) {
                fallback = Some(id);
            }
            let tokens = view.running_tokens(i);
            let interval = view.avg_token_interval(i);
            if view.liveness(i).is_degraded()
                || tokens + incoming > self.mrt(i).min(view.max_kv_tokens(i))
                || !(interval.is_nan() || interval <= self.cfg.tpot_slo)
            {
                continue;
            }
            let better = match best {
                None => true,
                Some((bid, bt)) => tokens < bt || (tokens == bt && id < bid),
            };
            if better {
                best = Some((id, tokens));
            }
        }
        best.map(|(id, _)| id)
            .or(fallback)
            .unwrap_or(prefill_instance)
    }

    /// Monitor tick: re-derive the cut point from the same integer
    /// queue-delay moments Arrow prices with. Pure ratios — see module
    /// docs for the invariance argument.
    fn on_tick(&mut self, _now: Time, view: &dyn ClusterView) {
        let mut n = 0usize;
        let mut delay_sum = 0.0;
        let mut util_sum = 0.0;
        let mut tpot_breach = false;
        for id in self.members.members_iter(Pool::Prefill) {
            let i = id.0;
            let m = view.prefill_queue_moments(i);
            delay_sum += self.predictor(i).queue_delay_moments(&m);
            let cap = self.mrt(i).min(view.max_kv_tokens(i)) as f64;
            util_sum += view.running_tokens(i) as f64 / cap.max(1.0);
            let v = view.avg_token_interval(i);
            tpot_breach |= !v.is_nan() && v > self.cfg.tpot_slo;
            n += 1;
        }
        if n == 0 {
            return;
        }
        let mean_delay = delay_sum / n as f64;
        let mean_util = util_sum / n as f64;
        // NaN (broken predictor) counts as pressure, never a free pass.
        let prefill_pressed = !(mean_delay <= self.cfg.pressure_frac * self.cfg.ttft_slo);
        let decode_pressed = tpot_breach || mean_util > self.cfg.decode_watermark;
        let mid = (self.cfg.cut_min + self.cfg.cut_max) / 2.0;
        if prefill_pressed && !decode_pressed {
            self.cut += self.cfg.cut_step;
        } else if decode_pressed && !prefill_pressed {
            self.cut -= self.cfg.cut_step;
        } else if !prefill_pressed && !decode_pressed {
            // Calm: decay toward the balanced midpoint, without
            // overshooting it.
            if self.cut > mid {
                self.cut = (self.cut - self.cfg.cut_step).max(mid);
            } else if self.cut < mid {
                self.cut = (self.cut + self.cfg.cut_step).min(mid);
            }
        }
        self.cut = self.cut.clamp(self.cfg.cut_min, self.cfg.cut_max);
    }

    fn on_membership(
        &mut self,
        _now: Time,
        ev: MembershipEvent,
        _view: &dyn ClusterView,
        profile: &dyn ProfileSource,
    ) {
        match ev {
            MembershipEvent::InstanceJoined { id } => {
                if self.members.contains(id) {
                    return; // idempotent, like Arrow's membership
                }
                let i = id.0;
                while self.predictors.len() <= i {
                    let j = self.predictors.len();
                    self.predictors.push(profile.fit_predictor(j));
                    self.max_running_tokens
                        .push(profile.max_running_tokens(j, self.cfg.tpot_slo));
                }
                self.predictors[i] = profile.fit_predictor(i);
                self.max_running_tokens[i] =
                    profile.max_running_tokens(i, self.cfg.tpot_slo);
                // A joiner lands in the one slot every member occupies —
                // there is no role decision to make in a unified design.
                self.members.join(id, Pool::Prefill);
            }
            MembershipEvent::InstanceDraining { id } | MembershipEvent::InstanceLost { id } => {
                self.members.remove(id);
            }
        }
    }

    fn pool_sizes(&self) -> Option<[usize; 4]> {
        Some(self.members.sizes())
    }

    fn flip_count(&self) -> u64 {
        self.members.flip_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::engine::SimInstance;
    use crate::request::RequestId;
    use crate::sim::SimView;

    fn cluster(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect()
    }

    fn policy(n: usize) -> (UnifiedPolicy, Vec<SimInstance>) {
        let insts = cluster(n);
        let mut p = UnifiedPolicy::new(UnifiedConfig::new(3.0, 0.1), n);
        p.init(&SimView(&insts));
        (p, insts)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request::new(id, 0.0, input, output)
    }

    #[test]
    fn every_instance_sits_in_exactly_one_slot_and_never_flips() {
        let (p, _) = policy(4);
        assert_eq!(p.pool_sizes(), Some([4, 0, 0, 0]));
        assert_eq!(p.flip_count(), 0);
        for i in 0..4 {
            assert_eq!(p.members().pool_of(InstanceId(i)), Some(Pool::Prefill));
        }
    }

    #[test]
    fn prefill_spreads_by_token_share() {
        let (mut p, mut insts) = policy(4);
        // Instance 0 carries prefill backlog, 1 carries decode load:
        // a fresh prefill must land on an unloaded member (2, by tie).
        insts[0].enqueue_prefill(RequestId(9), 50_000);
        assert!(insts[1].try_reserve_kv(20_000));
        insts[1].enqueue_decode(RequestId(10), 20_000, 100);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(2));
    }

    #[test]
    fn decode_always_stays_local_to_a_live_member() {
        let (mut p, mut insts) = policy(4);
        for i in 0..4 {
            let t = p.place_decode(0.0, &req(i as u64, 1000, 10), InstanceId(i), &SimView(&insts));
            assert_eq!(t, InstanceId(i), "unified decode never migrates KV");
        }
        // A departed instance forces migration to the least-loaded member.
        insts[3].life = crate::sched::Liveness::Dead;
        p.on_membership(
            0.0,
            MembershipEvent::InstanceLost { id: InstanceId(3) },
            &SimView(&insts),
            &SimView(&insts),
        );
        let t = p.place_decode(0.0, &req(9, 1000, 10), InstanceId(3), &SimView(&insts));
        assert_eq!(t, InstanceId(0), "migrated off the lost instance");
        assert_eq!(p.pool_sizes(), Some([3, 0, 0, 0]));
    }

    #[test]
    fn cut_point_tracks_pressure_and_stays_bounded() {
        let (mut p, mut insts) = policy(4);
        let mid = p.cut();
        // Prefill pressure on every member: cut rises.
        for (r, inst) in insts.iter_mut().enumerate() {
            for k in 0..4 {
                inst.enqueue_prefill(RequestId((100 + 10 * r + k) as u64), 100_000);
            }
        }
        for tick in 0..64 {
            p.on_tick(tick as f64, &SimView(&insts));
        }
        assert!(p.cut() > mid, "prefill pressure must raise the cut");
        assert!(p.cut() <= 0.9, "cut stays within bounds");
        // Decode pressure (TPOT breach) with no prefill queue: cut falls.
        let (mut p2, mut insts2) = policy(4);
        for inst in insts2.iter_mut() {
            inst.seed_token_interval(0.5); // >> 0.1s TPOT SLO
        }
        for tick in 0..64 {
            p2.on_tick(tick as f64, &SimView(&insts2));
        }
        assert!(p2.cut() < mid, "decode pressure must lower the cut");
        assert!(p2.cut() >= 0.1, "cut stays within bounds");
        // Calm again: the cut decays back to the midpoint exactly.
        for inst in insts2.iter_mut() {
            inst.reset_monitor();
        }
        for tick in 0..64 {
            p2.on_tick(tick as f64, &SimView(&insts2));
        }
        assert_eq!(p2.cut(), mid, "calm reverts the cut to the midpoint");
    }

    #[test]
    fn degraded_member_is_deprioritized_but_still_last_resort() {
        let (mut p, mut insts) = policy(2);
        insts[0].life = crate::sched::Liveness::Degraded;
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(1), "healthy member preferred");
        insts[1].life = crate::sched::Liveness::Degraded;
        let t = p.place_prefill(0.0, &req(2, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(0), "a straggler beats nothing");
    }
}
