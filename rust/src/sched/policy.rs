//! The global-scheduling policy interface.
//!
//! Both Arrow ([`crate::coordinator::arrow`]) and the baselines
//! ([`crate::baselines`]) implement [`Policy`]. The substrate (simulator
//! event loop or live server coordinator) owns engines and timing;
//! policies own only *decisions* — which instance prefills a request,
//! which decodes it, and when instances move between pools. This split is
//! the paper's stateless-instance insight (§3.4): roles live in the
//! scheduler's pool bookkeeping, never in the engine.
//!
//! # Contract with the substrate
//!
//! * **Determinism.** A policy must be a pure function of its own state
//!   and the arguments it is handed — no wall clock, no ambient
//!   randomness. The simulator's byte-identical-schedule guarantee and
//!   the cross-substrate golden test (`tests/cross_substrate.rs`) hold
//!   only under this contract.
//! * **Substrate-blindness.** Policies read cluster load exclusively
//!   through [`ClusterView`] and learn instance capability exclusively
//!   through [`ProfileSource`]; they must not downcast or otherwise
//!   detect which substrate is calling.
//! * **Hot path.** `place_prefill`/`place_decode` run once per request;
//!   implementations should avoid per-call allocation (see
//!   [`ClusterView::for_each_queued_prefill`] and
//!   `Pools::members_iter` for allocation-free queries) and must never
//!   panic on degenerate float comparisons — use `f64::total_cmp`, not
//!   `partial_cmp().unwrap()`.

use super::{ClusterView, MembershipEvent, ProfileSource};
use crate::request::{InstanceId, Request, Time};

pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Called once before serving starts (the paper's startup profiling
    /// hook — TTFT predictor fitting + Max Running Tokens measurement).
    fn init(&mut self, _profile: &dyn ProfileSource) {}

    /// Select the instance that will run `req`'s prefill phase (Alg. 1
    /// for Arrow; trivial for baselines).
    fn place_prefill(&mut self, now: Time, req: &Request, view: &dyn ClusterView)
        -> InstanceId;

    /// Select the instance that will run `req`'s decode phase (Alg. 2).
    fn place_decode(
        &mut self,
        now: Time,
        req: &Request,
        prefill_instance: InstanceId,
        view: &dyn ClusterView,
    ) -> InstanceId;

    /// Periodic monitor tick (paper §5.5: TPOT-violation and idle-prefill
    /// instance scheduling happen here).
    fn on_tick(&mut self, _now: Time, _view: &dyn ClusterView) {}

    /// Cluster membership changed (PR 3: elastic membership). The view
    /// already reflects the new state; `profile` covers every table slot
    /// including joiners (the substrate profiles a joining instance the
    /// same way it profiled the startup set). Policies with pool
    /// bookkeeping re-seed it here; stateless policies can ignore the
    /// event (default no-op) — they must then only ever be run under
    /// fixed membership.
    fn on_membership(
        &mut self,
        _now: Time,
        _ev: MembershipEvent,
        _view: &dyn ClusterView,
        _profile: &dyn ProfileSource,
    ) {
    }

    /// Pool sizes [Prefill, Decode, P→D, D→P] for snapshots, if the
    /// policy maintains elastic pools.
    fn pool_sizes(&self) -> Option<[usize; 4]> {
        None
    }

    /// Number of instance flips performed so far (ablation metric).
    fn flip_count(&self) -> u64 {
        0
    }
}

/// Trivial policies used by simulator unit tests.
pub mod tests_support {
    use super::*;

    /// Everything on instance 0 (colocated single instance).
    pub struct AllToOne;

    impl Policy for AllToOne {
        fn name(&self) -> &'static str {
            "all-to-one"
        }

        fn place_prefill(&mut self, _: Time, _: &Request, _: &dyn ClusterView) -> InstanceId {
            InstanceId(0)
        }

        fn place_decode(
            &mut self,
            _: Time,
            _: &Request,
            _prefill: InstanceId,
            _: &dyn ClusterView,
        ) -> InstanceId {
            InstanceId(0)
        }
    }

    /// Fixed prefill/decode instance sets, round-robin within each.
    ///
    /// An empty set no longer panics with a mod-by-zero on the first
    /// placement (PR 8): a phase whose set is empty falls back to the
    /// other phase's set (degenerate colocated split). Both sets empty is
    /// an unusable policy and panics with an explicit message instead of
    /// an arithmetic error deep in a modulo.
    pub struct StaticSplit {
        pub prefill: Vec<usize>,
        pub decode: Vec<usize>,
    }

    impl StaticSplit {
        /// Round-robin over `primary`, falling back to `fallback` when
        /// `primary` is empty.
        fn pick(primary: &[usize], fallback: &[usize], id: u64, phase: &str) -> InstanceId {
            let set = if !primary.is_empty() { primary } else { fallback };
            assert!(
                !set.is_empty(),
                "StaticSplit: both prefill and decode instance sets are empty — \
                 cannot place {phase} for request r{id}"
            );
            InstanceId(set[id as usize % set.len()])
        }
    }

    impl Policy for StaticSplit {
        fn name(&self) -> &'static str {
            "static-split"
        }

        fn place_prefill(&mut self, _: Time, req: &Request, _: &dyn ClusterView) -> InstanceId {
            StaticSplit::pick(&self.prefill, &self.decode, req.id.0, "prefill")
        }

        fn place_decode(
            &mut self,
            _: Time,
            req: &Request,
            _prefill: InstanceId,
            _: &dyn ClusterView,
        ) -> InstanceId {
            StaticSplit::pick(&self.decode, &self.prefill, req.id.0, "decode")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::StaticSplit;
    use super::*;

    /// Minimal no-op view so the placement methods can be exercised
    /// without standing up a substrate.
    struct NullView;

    impl ClusterView for NullView {
        fn n_instances(&self) -> usize {
            0
        }
        fn for_each_queued_prefill(&self, _: usize, _: &mut dyn FnMut(u32, u32)) {}
        fn running_tokens(&self, _: usize) -> u64 {
            0
        }
        fn max_kv_tokens(&self, _: usize) -> u64 {
            0
        }
        fn avg_token_interval(&self, _: usize) -> f64 {
            f64::NAN
        }
        fn has_prefill_work(&self, _: usize) -> bool {
            false
        }
        fn has_decode_work(&self, _: usize) -> bool {
            false
        }
    }

    /// PR 8 regression: an empty phase set used to panic with a
    /// mod-by-zero (`% 0`) on the first placement. Now it falls back to
    /// the other set.
    #[test]
    fn empty_phase_set_falls_back_to_other_phase() {
        let mut p = StaticSplit {
            prefill: vec![],
            decode: vec![3, 4],
        };
        let r = Request::new(0, 0.0, 8, 8);
        assert_eq!(p.place_prefill(0.0, &r, &NullView), InstanceId(3));
        let r1 = Request::new(1, 0.0, 8, 8);
        assert_eq!(p.place_prefill(0.0, &r1, &NullView), InstanceId(4));
        assert_eq!(p.place_decode(0.0, &r1, InstanceId(3), &NullView), InstanceId(4));

        let mut q = StaticSplit {
            prefill: vec![7],
            decode: vec![],
        };
        assert_eq!(q.place_decode(0.0, &r, InstanceId(7), &NullView), InstanceId(7));
    }

    #[test]
    #[should_panic(expected = "both prefill and decode instance sets are empty")]
    fn both_sets_empty_panics_with_clear_message() {
        let mut p = StaticSplit {
            prefill: vec![],
            decode: vec![],
        };
        let r = Request::new(0, 0.0, 8, 8);
        p.place_prefill(0.0, &r, &NullView);
    }
}
