//! Load-aware prefill deflection (PR 10): Arrow's elastic pools plus the
//! *Towards Load-Aware Prefill Deflection* insight — a flip takes a
//! drain window to pay off, but a **small** prefill can be chunk-
//! colocated onto a decode instance *right now*.
//!
//! [`DeflectPolicy`] wraps a plain [`ArrowPolicy`] and intercepts exactly
//! one decision: when Algorithm 1's SLO test fails on every prefill-
//! capable candidate (the condition under which Arrow would wait for —
//! or burn — a whole-instance flip), a prefill no longer than the
//! deflection cap is sent to the least-loaded decode-capable instance
//! instead. The engine's SLO-aware chunking (`iter_time_budget`) mixes
//! the deflected chunk with the decode batch, so the colocated window
//! needs **no new substrate hook**: the ranked-enqueue path and decode
//! priority already protect the co-resident decode head.
//!
//! Guards (all ratio-of-SLO or token-count based — no absolute-seconds
//! constants, so cost-scale invariance holds by construction):
//!
//! * **Trigger** — deflection happens only under prefill pressure: both
//!   Alg. 1 acceptance tests (P, then D→P pool argmin) must fail for the
//!   request's own class TTFT target. On a quiescent cluster the wrapper
//!   delegates every decision verbatim, so its schedule is bit-identical
//!   to plain Arrow's (`tests/deflection.rs` pins this).
//! * **Size cap** — only prefills with `input_len <=`
//!   [`DeflectConfig::deflect_max_tokens`] are eligible; an oversized
//!   prefill would monopolize the mixed iterations it shares with
//!   decode.
//! * **Interference guard** — a target whose recent token interval
//!   already breaches the request's TPOT budget is refused: deflecting
//!   onto it would convert a TTFT miss into a TPOT miss.
//! * **Capacity** — the target must fit the deflected KV within both its
//!   profiled Max Running Tokens and its KV memory (the request decodes
//!   locally afterwards — zero transfer, like Arrow's local handoff).
//! * **Hopelessness** — a request whose own prefill time alone exceeds
//!   its TTFT target is never deflected (Insight 2 monotonicity: no
//!   placement can rescue it; Arrow's hopeless branch handles it
//!   without a flip).
//!
//! Everything else — decode placement, monitor ticks, membership events,
//! pool bookkeeping — is delegated to the wrapped Arrow policy, so every
//! PR-1..9 contract (allocation-free placement, determinism, substrate
//! blindness, chaos recovery) is inherited rather than re-implemented.

use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use crate::coordinator::pools::Pool;
use crate::coordinator::predictor::TtftPredictor;
use crate::request::{InstanceId, Request, Time};
use crate::sched::{ClusterView, MembershipEvent, Policy, ProfileSource, DEFAULT_CHUNK_TOKENS};

/// Tunables for [`DeflectPolicy`].
#[derive(Debug, Clone)]
pub struct DeflectConfig {
    /// The wrapped Arrow policy's configuration (SLOs, watermarks, class
    /// awareness) — deflection judges pressure against the same targets.
    pub arrow: ArrowConfig,
    /// Largest prefill (input tokens) eligible for deflection. Defaults
    /// to one chunk budget: a deflected prefill then completes in a
    /// single mixed iteration, the regime the deflection paper targets.
    /// Dimensionless (a token count), so time dilation leaves it alone.
    pub deflect_max_tokens: u32,
}

impl DeflectConfig {
    pub fn new(ttft_slo: f64, tpot_slo: f64, n_instances: usize) -> Self {
        DeflectConfig {
            arrow: ArrowConfig::new(ttft_slo, tpot_slo, n_instances),
            deflect_max_tokens: DEFAULT_CHUNK_TOKENS,
        }
    }
}

/// Arrow + load-aware prefill deflection. See module docs.
pub struct DeflectPolicy {
    cfg: DeflectConfig,
    inner: ArrowPolicy,
    /// Own predictor/capacity tables (same [`ProfileSource`] data the
    /// inner policy fits): the wrapper prices queues itself so the
    /// pressure test never has to reach into Arrow's private cache.
    predictors: Vec<TtftPredictor>,
    max_running_tokens: Vec<u64>,
    /// Prefills deflected so far (ablation metric, mirrors flip_count).
    deflections: u64,
}

impl DeflectPolicy {
    pub fn new(cfg: DeflectConfig, n_instances: usize) -> Self {
        let inner = ArrowPolicy::new(cfg.arrow.clone(), n_instances);
        DeflectPolicy {
            cfg,
            inner,
            predictors: Vec::new(),
            max_running_tokens: Vec::new(),
            deflections: 0,
        }
    }

    /// Number of prefills deflected onto decode instances so far.
    pub fn deflection_count(&self) -> u64 {
        self.deflections
    }

    /// The wrapped policy's pool bookkeeping (conformance tests).
    pub fn pools(&self) -> &crate::coordinator::pools::Pools {
        self.inner.pools()
    }

    fn predictor(&self, inst: usize) -> &TtftPredictor {
        self.predictors.get(inst).expect("policy not initialized")
    }

    fn mrt(&self, inst: usize) -> u64 {
        self.max_running_tokens.get(inst).copied().unwrap_or(u64::MAX)
    }

    /// Argmin of predicted prefill queue delay over `pool`, by direct
    /// member scan (allocation-free; O(1) moments per member). Ties go to
    /// the lowest id and NaN orders last — the same semantics as Arrow's
    /// keyed index, so the pressure test below reproduces Alg. 1's
    /// acceptance decisions exactly.
    fn min_delay_scan(&self, pool: Pool, view: &dyn ClusterView) -> Option<(InstanceId, f64)> {
        let mut best: Option<(InstanceId, f64)> = None;
        for id in self.inner.pools().members_iter(pool) {
            let m = view.prefill_queue_moments(id.0);
            let d = self.predictor(id.0).queue_delay_moments(&m);
            let better = match best {
                None => true,
                Some((bid, bd)) => match d.total_cmp(&bd) {
                    std::cmp::Ordering::Less => true,
                    std::cmp::Ordering::Equal => id < bid,
                    std::cmp::Ordering::Greater => false,
                },
            };
            if better {
                best = Some((id, d));
            }
        }
        best
    }

    /// Would Alg. 1 accept this pool's argmin for `req`? (the exact
    /// acceptance predicate of the wrapped policy: queue delay + own
    /// prefill time within the class TTFT target, candidate not a
    /// straggler).
    fn pool_accepts(
        &self,
        pool: Pool,
        req: &Request,
        ttft_slo: f64,
        view: &dyn ClusterView,
    ) -> bool {
        self.min_delay_scan(pool, view).is_some_and(|(id, delay)| {
            delay + self.predictor(id.0).prefill_seconds(req.input_len) <= ttft_slo
                && !view.liveness(id.0).is_degraded()
        })
    }

    /// The request's class-scaled targets (mirrors the wrapped policy's
    /// PR-8 semantics, including the class-blind toggle).
    fn ttft_slo_for(&self, req: &Request) -> f64 {
        if self.cfg.arrow.class_aware {
            req.class.ttft_slo(self.cfg.arrow.ttft_slo)
        } else {
            self.cfg.arrow.ttft_slo
        }
    }

    fn tpot_slo_for(&self, req: &Request) -> f64 {
        if self.cfg.arrow.class_aware {
            req.class.tpot_slo(self.cfg.arrow.tpot_slo)
        } else {
            self.cfg.arrow.tpot_slo
        }
    }

    /// The deflection decision: `Some(target)` iff the size cap, the
    /// pressure trigger, and every target guard all pass. Read-only —
    /// pool bookkeeping is untouched, so a refused deflection leaves the
    /// wrapped policy to decide exactly as plain Arrow would.
    fn try_deflect(&self, req: &Request, view: &dyn ClusterView) -> Option<InstanceId> {
        // Size cap: oversized prefills are never deflected.
        if req.input_len > self.cfg.deflect_max_tokens {
            return None;
        }
        let ttft_slo = self.ttft_slo_for(req);
        // Trigger: only under prefill pressure — i.e. when both Alg. 1
        // acceptance tests would fail and Arrow would look for a flip.
        if self.pool_accepts(Pool::Prefill, req, ttft_slo, view)
            || self.pool_accepts(Pool::DecodeToPrefill, req, ttft_slo, view)
        {
            return None;
        }
        // Hopeless requests gain nothing from deflection: own prefill
        // time alone already exceeds the target on every instance of a
        // homogeneous cluster, and on heterogeneous ones the hopeless
        // branch of the wrapped Alg. 1 still avoids burning a flip.
        let hopeless = self
            .min_delay_scan(Pool::Prefill, view)
            .or_else(|| self.min_delay_scan(Pool::DecodeToPrefill, view))
            .is_some_and(|(id, _)| {
                self.predictor(id.0).prefill_seconds(req.input_len) > ttft_slo
            });
        if hopeless {
            return None;
        }
        // Target: least-loaded decode-capable instance — load counts both
        // resident decode tokens and already-queued (possibly previously
        // deflected) prefill tokens, so a burst of deflections spreads
        // across targets instead of thundering onto one. Ties go to the
        // lowest id. One allocation-free pass over D ∪ P→D.
        let tpot_slo = self.tpot_slo_for(req);
        let incoming = req.input_len as u64;
        let mut best: Option<(InstanceId, u64)> = None;
        for id in self
            .inner
            .pools()
            .members_iter(Pool::Decode)
            .chain(self.inner.pools().members_iter(Pool::PrefillToDecode))
        {
            let i = id.0;
            let life = view.liveness(i);
            if !life.placeable() || life.is_degraded() {
                continue;
            }
            // Interference guard: a target already past the TPOT budget
            // must not absorb extra prefill work (NaN = no evidence =
            // admissible, matching Alg. 2's convention).
            let interval = view.avg_token_interval(i);
            if !(interval.is_nan() || interval <= tpot_slo) {
                continue;
            }
            // Capacity: the deflected KV must fit — the request decodes
            // locally afterwards, so judge it like a decode admission.
            let tokens = view.running_tokens(i);
            if tokens + incoming > self.mrt(i).min(view.max_kv_tokens(i)) {
                continue;
            }
            let load = tokens + view.queued_prefill_tokens(i);
            let better = match best {
                None => true,
                Some((bid, bt)) => load < bt || (load == bt && id < bid),
            };
            if better {
                best = Some((id, load));
            }
        }
        best.map(|(id, _)| id)
    }
}

impl Policy for DeflectPolicy {
    fn name(&self) -> &'static str {
        "arrow-deflect"
    }

    fn init(&mut self, profile: &dyn ProfileSource) {
        let n = profile.n_instances();
        self.predictors = (0..n).map(|i| profile.fit_predictor(i)).collect();
        self.max_running_tokens = (0..n)
            .map(|i| profile.max_running_tokens(i, self.cfg.arrow.tpot_slo))
            .collect();
        self.inner.init(profile);
    }

    fn place_prefill(&mut self, now: Time, req: &Request, view: &dyn ClusterView) -> InstanceId {
        if let Some(target) = self.try_deflect(req, view) {
            self.deflections += 1;
            return target;
        }
        self.inner.place_prefill(now, req, view)
    }

    fn place_decode(
        &mut self,
        now: Time,
        req: &Request,
        prefill_instance: InstanceId,
        view: &dyn ClusterView,
    ) -> InstanceId {
        // Delegated verbatim. A deflected request prefilled on a decode-
        // capable instance, so Arrow's local-handoff branch keeps its
        // decode there — zero KV transfer, the whole point of deflection.
        self.inner.place_decode(now, req, prefill_instance, view)
    }

    fn on_tick(&mut self, now: Time, view: &dyn ClusterView) {
        self.inner.on_tick(now, view);
    }

    fn on_membership(
        &mut self,
        now: Time,
        ev: MembershipEvent,
        view: &dyn ClusterView,
        profile: &dyn ProfileSource,
    ) {
        // Keep the wrapper's own tables in sync with joiners before the
        // wrapped policy re-seeds its pools (same refresh rule Arrow
        // applies: a rejoining slot may carry different hardware).
        if let MembershipEvent::InstanceJoined { id } = ev {
            let i = id.0;
            while self.predictors.len() <= i {
                let j = self.predictors.len();
                self.predictors.push(profile.fit_predictor(j));
                self.max_running_tokens
                    .push(profile.max_running_tokens(j, self.cfg.arrow.tpot_slo));
            }
            self.predictors[i] = profile.fit_predictor(i);
            self.max_running_tokens[i] =
                profile.max_running_tokens(i, self.cfg.arrow.tpot_slo);
        }
        self.inner.on_membership(now, ev, view, profile);
    }

    fn pool_sizes(&self) -> Option<[usize; 4]> {
        self.inner.pool_sizes()
    }

    fn flip_count(&self) -> u64 {
        self.inner.flip_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::engine::SimInstance;
    use crate::request::RequestId;
    use crate::sim::SimView;

    fn cluster(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect()
    }

    fn policy(n: usize) -> (DeflectPolicy, Vec<SimInstance>) {
        let insts = cluster(n);
        let mut p = DeflectPolicy::new(DeflectConfig::new(3.0, 0.1, n), n);
        p.init(&SimView(&insts));
        (p, insts)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request::new(id, 0.0, input, output)
    }

    fn press_prefill_pool(insts: &mut [SimInstance]) {
        // Backlog both seed prefill instances (0, 1) far past any SLO.
        for inst in insts.iter_mut().take(2) {
            for r in 0..4 {
                inst.enqueue_prefill(RequestId(100 + r), 100_000);
            }
        }
    }

    #[test]
    fn quiescent_cluster_delegates_to_arrow() {
        let (mut p, insts) = policy(4);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert!(t.0 < 2, "no pressure: plain Arrow placement, got {t}");
        assert_eq!(p.deflection_count(), 0);
    }

    #[test]
    fn pressure_deflects_small_prefill_instead_of_flipping() {
        let (mut p, mut insts) = policy(4);
        press_prefill_pool(&mut insts);
        assert_eq!(p.pools().sizes(), [2, 2, 0, 0]);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert!(t.0 >= 2, "small prefill deflects to a decode instance, got {t}");
        assert_eq!(p.deflection_count(), 1);
        // No flip was burned: the pools are untouched.
        assert_eq!(p.pools().sizes(), [2, 2, 0, 0]);
        assert_eq!(p.flip_count(), 0);
        // The decode then stays local — zero KV transfer.
        let d = p.place_decode(0.0, &req(1, 1000, 10), t, &SimView(&insts));
        assert_eq!(d, t);
    }

    #[test]
    fn oversized_prefill_is_never_deflected() {
        let (mut p, mut insts) = policy(4);
        press_prefill_pool(&mut insts);
        // Same pressure, but the request exceeds the deflection cap: the
        // wrapped Arrow decides — and under idle decode it flips.
        let big = req(1, DEFAULT_CHUNK_TOKENS + 1, 10);
        let t = p.place_prefill(0.0, &big, &SimView(&insts));
        assert_eq!(p.deflection_count(), 0);
        assert!(t.0 >= 2, "Arrow's own steal still applies, got {t}");
        assert!(p.flip_count() >= 1, "delegation reached Arrow's flip");
    }

    #[test]
    fn interference_guard_refuses_tpot_breaching_target() {
        let (mut p, mut insts) = policy(4);
        press_prefill_pool(&mut insts);
        // Both decode instances report token intervals far past the TPOT
        // budget: the guard must refuse deflection entirely.
        for inst in insts.iter_mut().skip(2) {
            inst.seed_token_interval(0.5); // >> 0.1s TPOT SLO
        }
        p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(p.deflection_count(), 0, "guard must block deflection");
    }

    #[test]
    fn capacity_guard_skips_full_target() {
        let (mut p, mut insts) = policy(4);
        press_prefill_pool(&mut insts);
        // Fill instance 2's KV completely; 3 stays empty: the deflection
        // argmin must land on 3.
        let cap = insts[2].cost.max_kv_tokens;
        assert!(insts[2].try_reserve_kv(cap));
        insts[2].enqueue_decode(RequestId(60), cap as u32, 100);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(3));
        assert_eq!(p.deflection_count(), 1);
    }
}
