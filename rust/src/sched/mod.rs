//! The scheduling core: one engine-agnostic brain shared by every
//! execution substrate.
//!
//! The paper's contribution is the *scheduler* — SLO-aware request
//! scheduling (Alg. 1–2), elastic pools, and instance scheduling
//! (Alg. 3–4) over stateless instances. This module makes that brain a
//! standalone layer: policies ([`Policy`]) read the cluster exclusively
//! through [`ClusterView`], a read-only per-instance snapshot interface,
//! and learn instance capabilities at startup through [`ProfileSource`].
//! Neither trait mentions the simulator or the PJRT server, so the exact
//! same `ArrowPolicy` object drives both:
//!
//! * the discrete-event simulator adapts via [`crate::sim::SimView`]
//!   (a zero-cost borrow of the `SimInstance` table), and
//! * the live server adapts via [`crate::server::view::ServerView`]
//!   (coordinator queue bookkeeping + lock-free `EngineStats`).
//!
//! # The `ClusterView` contract
//!
//! * **Snapshot semantics.** All accessors describe one instant; a policy
//!   may call them any number of times within one decision and must see
//!   consistent values.
//! * **No allocation.** Placement runs once per arriving request on the
//!   simulator hot path (ROADMAP "Performance architecture"); accessors
//!   must not allocate. Queue inspection therefore uses *internal*
//!   iteration ([`ClusterView::for_each_queued_prefill`]) — a `&mut dyn
//!   FnMut` visitor is dyn-compatible and allocation-free, where a
//!   returned iterator would need a `Box`.
//! * **NaN is "no evidence".** [`ClusterView::avg_token_interval`]
//!   returns NaN when an instance has produced no recent tokens; policies
//!   must treat degenerate floats with `f64::total_cmp`, never
//!   `partial_cmp().unwrap()`.
//! * **O(1) load aggregates (PR 4).** Queue-delay inputs are exposed as
//!   incrementally maintained integer moments
//!   ([`ClusterView::prefill_queue_moments`]) so placement never walks a
//!   queue, and [`ClusterView::change_epoch`] lets policies skip even the
//!   per-instance freshness check when the substrate proves nothing
//!   changed. See ROADMAP "Scale architecture (PR 4)".

pub mod deflect;
pub mod policy;
pub mod unified;

pub use deflect::{DeflectConfig, DeflectPolicy};
pub use policy::{tests_support, Policy};
pub use unified::{UnifiedConfig, UnifiedPolicy};

use crate::coordinator::predictor::TtftPredictor;
use crate::request::InstanceId;

/// Chunked-prefill token budget assumed by default views and engines
/// (Sarathi-style; the canonical value [`crate::engine::instance`]
/// re-exports).
pub const DEFAULT_CHUNK_TOKENS: u32 = 2048;

/// Sentinel returned by [`ClusterView::change_epoch`] when the view
/// cannot prove anything about change history: consumers must fall back
/// to verifying per-instance aggregates. Any real epoch must be
/// `!= EPOCH_UNKNOWN`.
pub const EPOCH_UNKNOWN: u64 = u64::MAX;

/// Incrementally maintained aggregates ("moments") of one instance's
/// prefill queue — everything the fitted TTFT quadratic
/// `c0 + c1·len + c2·len²` needs to price the queue's total remaining
/// delay in O(1) (PR 4 tentpole):
///
/// ```text
/// Σ_tasks remaining_seconds(len, rem)
///   = c1·Σrem + c2·Σ(len² − done²) + overhead·Σ⌈rem/chunk⌉
/// ```
///
/// All fields are exact integers, so the aggregates are
/// **path-independent**: maintaining them incrementally through any
/// interleaving of [`PrefillQueueMoments::add_task`] /
/// [`PrefillQueueMoments::advance_head`] / task completion yields
/// *bit-identical* values to deriving them from a queue walk — the
/// cross-substrate conformance contract (`tests/prop_predictor.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PrefillQueueMoments {
    /// Number of queued (incl. in-progress head) prefill tasks.
    pub count: u64,
    /// Σ remaining tokens over queued tasks.
    pub sum_remaining: u64,
    /// Σ (input_len² − done²) over queued tasks (done = len − remaining).
    /// u128: a single 4-billion-token prompt already saturates u64.
    pub sum_sq_span: u128,
    /// Σ ⌈remaining / chunk⌉ — chunk iterations still to run, priced at
    /// the profiled per-iteration overhead.
    pub sum_chunks: u64,
}

impl PrefillQueueMoments {
    fn chunks_of(remaining: u32, chunk: u32) -> u64 {
        (remaining as u64).div_ceil(chunk.max(1) as u64)
    }

    fn sq_span_of(input_len: u32, remaining: u32) -> u128 {
        debug_assert!(remaining <= input_len);
        let l = input_len as u128;
        let d = (input_len - remaining) as u128;
        l * l - d * d
    }

    /// Account a queued task `(input_len, remaining)`. Fresh enqueues
    /// have `remaining == input_len`; mirrors rebuilding from a queue
    /// view may add partially-done heads directly.
    pub fn add_task(&mut self, input_len: u32, remaining: u32, chunk: u32) {
        self.count += 1;
        self.sum_remaining += remaining as u64;
        self.sum_sq_span += Self::sq_span_of(input_len, remaining);
        self.sum_chunks += Self::chunks_of(remaining, chunk);
    }

    /// Remove a queued task (dequeue before completion — e.g. the
    /// server's PrefillDone, which observes no chunk progress).
    pub fn remove_task(&mut self, input_len: u32, remaining: u32, chunk: u32) {
        debug_assert!(self.count >= 1);
        self.count -= 1;
        self.sum_remaining -= remaining as u64;
        self.sum_sq_span -= Self::sq_span_of(input_len, remaining);
        self.sum_chunks -= Self::chunks_of(remaining, chunk);
    }

    /// The head task advanced from `old_remaining` to `new_remaining`
    /// (one chunked-prefill iteration). When the head *finishes*
    /// (`new_remaining == 0`) its residual contribution is zero, so the
    /// subsequent pop only decrements `count`.
    pub fn advance_head(
        &mut self,
        input_len: u32,
        old_remaining: u32,
        new_remaining: u32,
        chunk: u32,
    ) {
        debug_assert!(new_remaining <= old_remaining);
        self.sum_remaining -= (old_remaining - new_remaining) as u64;
        self.sum_sq_span -=
            Self::sq_span_of(input_len, old_remaining) - Self::sq_span_of(input_len, new_remaining);
        self.sum_chunks -= Self::chunks_of(old_remaining, chunk) - Self::chunks_of(new_remaining, chunk);
    }

    /// A finished head (remaining 0) leaves the queue: only the task
    /// count changes — every other contribution already telescoped to 0
    /// through [`PrefillQueueMoments::advance_head`].
    pub fn pop_finished_head(&mut self) {
        debug_assert!(self.count >= 1);
        self.count -= 1;
    }

    /// Derive moments from a queue view — the walk-based oracle the
    /// incremental path is conformance-tested against.
    pub fn derive_walk<V: ClusterView + ?Sized>(view: &V, inst: usize) -> PrefillQueueMoments {
        let chunk = view.prefill_chunk_tokens(inst);
        let mut m = PrefillQueueMoments::default();
        view.for_each_queued_prefill(inst, &mut |l, r| m.add_task(l, r, chunk));
        m
    }
}

/// Map a float to `u64` key bits whose unsigned order equals
/// `f64::total_cmp` order (the classic IEEE total-order twist). Lets the
/// pool argmin index ([`crate::coordinator::pools::Pools`]) store
/// predicted delays in an integer-ordered set: NaN sorts after every
/// finite delay, `-0.0` before `+0.0` — exactly like the scan it
/// replaces.
pub fn f64_key_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Inverse of [`f64_key_bits`].
pub fn f64_from_key_bits(k: u64) -> f64 {
    if k >> 63 == 1 {
        f64::from_bits(k ^ (1 << 63))
    } else {
        f64::from_bits(!k)
    }
}

/// Cluster-membership state of one instance slot (PR 3).
///
/// Instance ids are table indices, so a slot is never recycled: an
/// instance that leaves stays in the table as `Dead` and a rejoining
/// instance reuses its old slot. `Draining` instances finish the work
/// they already hold but must receive no new placements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    /// Serving; placements allowed.
    Active,
    /// Serving, but observed as a straggler (PR 6): token intervals are a
    /// multiple of the cluster median. Still in the cluster, still
    /// placeable as a last resort — policies deprioritize it so healthy
    /// capacity absorbs new work while the monitor watches for recovery.
    Degraded,
    /// Leaving gracefully: finishes in-flight work, accepts nothing new.
    Draining,
    /// Not part of the cluster (never joined, left, or failed).
    Dead,
}

impl Liveness {
    /// May the scheduler place *new* work on this instance? Degraded
    /// counts: a slow instance beats a dead letter queue — policies
    /// *prefer* healthy instances via [`Liveness::is_degraded`] but may
    /// still fall back to a straggler when nothing healthy remains.
    pub fn placeable(self) -> bool {
        matches!(self, Liveness::Active | Liveness::Degraded)
    }

    /// Is the instance still part of the cluster (able to finish work it
    /// already holds — Active, Degraded or Draining)?
    pub fn in_cluster(self) -> bool {
        !matches!(self, Liveness::Dead)
    }

    /// Straggler flag (PR 6): placeable, but only when nothing healthy
    /// can take the work.
    pub fn is_degraded(self) -> bool {
        matches!(self, Liveness::Degraded)
    }
}

/// A cluster-membership change, delivered to policies through
/// [`Policy::on_membership`]. The substrate (simulator event loop or live
/// coordinator) owns detection and work recovery; the policy owns only
/// the scheduling consequences — re-seeding pools and re-running the
/// Alg. 2/4 flip logic against the new capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipEvent {
    /// A new (or returning) instance is live at table index `id` and
    /// visible through the view; the accompanying [`ProfileSource`]
    /// covers it (the substrate profiles joiners exactly like startup).
    InstanceJoined { id: InstanceId },
    /// The instance will leave once its in-flight work drains; it must
    /// receive no further placements.
    InstanceDraining { id: InstanceId },
    /// The instance failed (or never joined): it is gone *now*; the
    /// substrate re-queues whatever work it held.
    InstanceLost { id: InstanceId },
}

impl MembershipEvent {
    pub fn id(self) -> InstanceId {
        match self {
            MembershipEvent::InstanceJoined { id }
            | MembershipEvent::InstanceDraining { id }
            | MembershipEvent::InstanceLost { id } => id,
        }
    }
}

/// Read-only, substrate-agnostic snapshot of cluster load at decision
/// time. Instances are addressed by their table index (`InstanceId.0`).
pub trait ClusterView {
    /// Number of instances in the cluster (fixed for a view's lifetime).
    fn n_instances(&self) -> usize;

    /// Visit `(input_len, remaining_tokens)` of every queued prefill on
    /// `inst`, in queue order — the public queue view the TTFT predictor
    /// consumes (Insight 1). Internal iteration keeps the trait
    /// dyn-compatible without boxing an iterator per call.
    fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32));

    /// Total queued prefill tokens still to process on `inst`.
    fn queued_prefill_tokens(&self, inst: usize) -> u64 {
        let mut total = 0u64;
        self.for_each_queued_prefill(inst, &mut |_, remaining| total += remaining as u64);
        total
    }

    /// O(1) prefill-queue aggregates of `inst` (PR 4): what
    /// [`TtftPredictor::queue_delay_moments`] consumes instead of walking
    /// the queue. Substrates maintain these incrementally at event time;
    /// the default derives them by walking (correct for simple test
    /// doubles, never used on a hot path).
    fn prefill_queue_moments(&self, inst: usize) -> PrefillQueueMoments {
        PrefillQueueMoments::derive_walk(self, inst)
    }

    /// Chunked-prefill budget of `inst` — the `chunk` the moments'
    /// `sum_chunks` is computed with. Must equal the chunk the
    /// instance's fitted [`TtftPredictor`] assumes.
    fn prefill_chunk_tokens(&self, _inst: usize) -> u32 {
        DEFAULT_CHUNK_TOKENS
    }

    /// Monotone change counter over *all* scheduler-visible load state
    /// (queues and decode tokens) of every instance in this view. Two
    /// equal non-[`EPOCH_UNKNOWN`] values from the same substrate prove
    /// nothing changed in between, letting policies skip index refresh
    /// entirely (O(1) placement). The default — and any view that cannot
    /// make that promise — returns [`EPOCH_UNKNOWN`], which forces the
    /// (cheap, aggregate-compare) per-instance freshness check.
    fn change_epoch(&self) -> u64 {
        EPOCH_UNKNOWN
    }

    /// Total KV tokens of running + admitted decode requests — the
    /// paper's "running tokens" decode-load metric (§5.3).
    fn running_tokens(&self, inst: usize) -> u64;

    /// KV capacity of `inst` in tokens (memory bound for admission).
    fn max_kv_tokens(&self, inst: usize) -> u64;

    /// Recent average token generation interval on `inst` (§5.3/§5.5
    /// TPOT proxy). NaN when there is no recent evidence.
    fn avg_token_interval(&self, inst: usize) -> f64;

    /// Does `inst` still hold prefill work (queued or in progress)?
    fn has_prefill_work(&self, inst: usize) -> bool;

    /// Does `inst` still hold decode work (running or parked)?
    fn has_decode_work(&self, inst: usize) -> bool;

    /// No work of either phase — harvest candidate (§5.5 condition 3).
    fn is_idle(&self, inst: usize) -> bool {
        !self.has_prefill_work(inst) && !self.has_decode_work(inst)
    }

    /// Cluster-membership state of the slot (PR 3). Defaults to `Active`
    /// so fixed-membership views (and simple test doubles) need not
    /// implement it; elastic substrates override.
    fn liveness(&self, _inst: usize) -> Liveness {
        Liveness::Active
    }
}

/// A [`ClusterView`] plus a substrate-supplied change epoch: the event
/// loop wraps its raw view (`Epoched(SimView(&insts), clock)`) so
/// policies can prove "nothing changed since my last decision" in O(1).
/// Every accessor forwards verbatim — including the O(1) moment
/// overrides, which a default-method re-derivation would silently
/// de-optimize.
pub struct Epoched<V>(pub V, pub u64);

impl<V: ClusterView> ClusterView for Epoched<V> {
    fn n_instances(&self) -> usize {
        self.0.n_instances()
    }
    fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32)) {
        self.0.for_each_queued_prefill(inst, f)
    }
    fn queued_prefill_tokens(&self, inst: usize) -> u64 {
        self.0.queued_prefill_tokens(inst)
    }
    fn prefill_queue_moments(&self, inst: usize) -> PrefillQueueMoments {
        self.0.prefill_queue_moments(inst)
    }
    fn prefill_chunk_tokens(&self, inst: usize) -> u32 {
        self.0.prefill_chunk_tokens(inst)
    }
    fn change_epoch(&self) -> u64 {
        self.1
    }
    fn running_tokens(&self, inst: usize) -> u64 {
        self.0.running_tokens(inst)
    }
    fn max_kv_tokens(&self, inst: usize) -> u64 {
        self.0.max_kv_tokens(inst)
    }
    fn avg_token_interval(&self, inst: usize) -> f64 {
        self.0.avg_token_interval(inst)
    }
    fn has_prefill_work(&self, inst: usize) -> bool {
        self.0.has_prefill_work(inst)
    }
    fn has_decode_work(&self, inst: usize) -> bool {
        self.0.has_decode_work(inst)
    }
    fn is_idle(&self, inst: usize) -> bool {
        self.0.is_idle(inst)
    }
    fn liveness(&self, inst: usize) -> Liveness {
        self.0.liveness(inst)
    }
}

/// Startup profiling access (paper §5.3): how a policy learns each
/// instance's prefill curve and Max Running Tokens before serving. The
/// simulator answers from cost models; the live server answers from
/// timed probe prompts — the policy cannot tell the difference.
pub trait ProfileSource {
    /// Number of instances that will be profiled.
    fn n_instances(&self) -> usize;

    /// Fit the TTFT quadratic for instance `i` (heterogeneous clusters
    /// profile each instance separately, §8).
    fn fit_predictor(&self, i: usize) -> TtftPredictor;

    /// Profiled Max Running Tokens of instance `i`: the largest decode
    /// batch token count that still meets `tpot_slo`, capped by memory.
    fn max_running_tokens(&self, i: usize, tpot_slo: f64) -> u64;
}

/// Pre-measured profile table — what the live server builds from real
/// probe timings at startup, and what cross-substrate tests use to hand
/// two policies byte-identical starting knowledge.
pub struct FixedProfile {
    pub predictors: Vec<TtftPredictor>,
    pub max_running_tokens: Vec<u64>,
}

impl ProfileSource for FixedProfile {
    fn n_instances(&self) -> usize {
        self.predictors.len()
    }

    fn fit_predictor(&self, i: usize) -> TtftPredictor {
        self.predictors[i].clone()
    }

    fn max_running_tokens(&self, i: usize, _tpot_slo: f64) -> u64 {
        self.max_running_tokens[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal hand-rolled view: checks the provided (default) methods.
    struct TwoInstances;

    impl ClusterView for TwoInstances {
        fn n_instances(&self) -> usize {
            2
        }
        fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32)) {
            if inst == 0 {
                f(1000, 600);
                f(500, 500);
            }
        }
        fn running_tokens(&self, inst: usize) -> u64 {
            if inst == 0 {
                0
            } else {
                77
            }
        }
        fn max_kv_tokens(&self, _inst: usize) -> u64 {
            100
        }
        fn avg_token_interval(&self, _inst: usize) -> f64 {
            f64::NAN
        }
        fn has_prefill_work(&self, inst: usize) -> bool {
            inst == 0
        }
        fn has_decode_work(&self, inst: usize) -> bool {
            inst == 1
        }
    }

    #[test]
    fn default_accessors_derive_from_primitives() {
        let v = TwoInstances;
        assert_eq!(v.queued_prefill_tokens(0), 1100);
        assert_eq!(v.queued_prefill_tokens(1), 0);
        assert!(!v.is_idle(0), "queued prefill is work");
        assert!(!v.is_idle(1), "decode is work");
        // Moment defaults derive from the queue walk with the default
        // chunk, and an unannotated view cannot promise change history.
        let m = v.prefill_queue_moments(0);
        assert_eq!(m.count, 2);
        assert_eq!(m.sum_remaining, 1100);
        assert_eq!(
            m.sum_sq_span,
            (1000u128 * 1000 - 400 * 400) + 500 * 500
        );
        assert_eq!(m.sum_chunks, 1 + 1);
        assert_eq!(v.prefill_queue_moments(1), PrefillQueueMoments::default());
        assert_eq!(v.change_epoch(), EPOCH_UNKNOWN);
    }

    #[test]
    fn moments_updates_are_path_independent() {
        // Incremental maintenance through enqueue/advance/pop must land
        // on the exact integers a fresh walk derives — the conformance
        // contract both substrates' bookkeeping relies on.
        let chunk = 2048;
        let mut inc = PrefillQueueMoments::default();
        inc.add_task(5000, 5000, chunk); // fresh enqueue
        inc.add_task(300, 300, chunk);
        inc.advance_head(5000, 5000, 2952, chunk); // one 2048 chunk
        inc.advance_head(5000, 2952, 904, chunk);
        let mut walk = PrefillQueueMoments::default();
        walk.add_task(5000, 904, chunk); // rebuilt from (len, remaining)
        walk.add_task(300, 300, chunk);
        assert_eq!(inc, walk);
        // Head finishes: residual contributions telescope to zero.
        inc.advance_head(5000, 904, 0, chunk);
        inc.pop_finished_head();
        let mut rest = PrefillQueueMoments::default();
        rest.add_task(300, 300, chunk);
        assert_eq!(inc, rest);
        // Server-style dequeue (no observed progress) is the inverse of
        // the fresh add.
        inc.remove_task(300, 300, chunk);
        assert_eq!(inc, PrefillQueueMoments::default());
    }

    #[test]
    fn key_bits_preserve_total_cmp_order_and_roundtrip() {
        let xs = [
            f64::NEG_INFINITY,
            -1.5,
            -0.0,
            0.0,
            1e-300,
            3.25,
            f64::INFINITY,
            f64::NAN,
        ];
        for w in xs.windows(2) {
            assert!(
                f64_key_bits(w[0]) < f64_key_bits(w[1]),
                "{} !< {}",
                w[0],
                w[1]
            );
        }
        for &x in &xs {
            let back = f64_from_key_bits(f64_key_bits(x));
            assert_eq!(back.to_bits(), x.to_bits(), "roundtrip of {x}");
        }
    }

    #[test]
    fn epoched_forwards_everything_and_reports_its_epoch() {
        let v = Epoched(TwoInstances, 42);
        assert_eq!(v.change_epoch(), 42);
        assert_eq!(ClusterView::n_instances(&v), 2);
        assert_eq!(v.queued_prefill_tokens(0), 1100);
        assert_eq!(
            v.prefill_queue_moments(0),
            TwoInstances.prefill_queue_moments(0)
        );
        assert_eq!(v.running_tokens(1), 77);
        assert!(v.avg_token_interval(0).is_nan());
        assert!(v.liveness(0).placeable());
    }

    #[test]
    fn fixed_profile_answers_per_instance() {
        let p = FixedProfile {
            predictors: vec![
                TtftPredictor::from_coefficients([0.0, 1e-4, 0.0], 2048, 0.0),
                TtftPredictor::from_coefficients([0.0, 2e-4, 0.0], 2048, 0.0),
            ],
            max_running_tokens: vec![10, 20],
        };
        assert_eq!(ProfileSource::n_instances(&p), 2);
        assert_eq!(p.max_running_tokens(1, 0.1), 20);
        let fast = p.fit_predictor(0).prefill_seconds(1000);
        let slow = p.fit_predictor(1).prefill_seconds(1000);
        assert!(slow > fast);
    }
}
