//! The scheduling core: one engine-agnostic brain shared by every
//! execution substrate.
//!
//! The paper's contribution is the *scheduler* — SLO-aware request
//! scheduling (Alg. 1–2), elastic pools, and instance scheduling
//! (Alg. 3–4) over stateless instances. This module makes that brain a
//! standalone layer: policies ([`Policy`]) read the cluster exclusively
//! through [`ClusterView`], a read-only per-instance snapshot interface,
//! and learn instance capabilities at startup through [`ProfileSource`].
//! Neither trait mentions the simulator or the PJRT server, so the exact
//! same `ArrowPolicy` object drives both:
//!
//! * the discrete-event simulator adapts via [`crate::sim::SimView`]
//!   (a zero-cost borrow of the `SimInstance` table), and
//! * the live server adapts via [`crate::server::view::ServerView`]
//!   (coordinator queue bookkeeping + lock-free `EngineStats`).
//!
//! # The `ClusterView` contract
//!
//! * **Snapshot semantics.** All accessors describe one instant; a policy
//!   may call them any number of times within one decision and must see
//!   consistent values.
//! * **No allocation.** Placement runs once per arriving request on the
//!   simulator hot path (ROADMAP "Performance architecture"); accessors
//!   must not allocate. Queue inspection therefore uses *internal*
//!   iteration ([`ClusterView::for_each_queued_prefill`]) — a `&mut dyn
//!   FnMut` visitor is dyn-compatible and allocation-free, where a
//!   returned iterator would need a `Box`.
//! * **NaN is "no evidence".** [`ClusterView::avg_token_interval`]
//!   returns NaN when an instance has produced no recent tokens; policies
//!   must treat degenerate floats with `f64::total_cmp`, never
//!   `partial_cmp().unwrap()`.

pub mod policy;

pub use policy::{tests_support, Policy};

use crate::coordinator::predictor::TtftPredictor;
use crate::request::InstanceId;

/// Cluster-membership state of one instance slot (PR 3).
///
/// Instance ids are table indices, so a slot is never recycled: an
/// instance that leaves stays in the table as `Dead` and a rejoining
/// instance reuses its old slot. `Draining` instances finish the work
/// they already hold but must receive no new placements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    /// Serving; placements allowed.
    Active,
    /// Leaving gracefully: finishes in-flight work, accepts nothing new.
    Draining,
    /// Not part of the cluster (never joined, left, or failed).
    Dead,
}

impl Liveness {
    /// May the scheduler place *new* work on this instance?
    pub fn placeable(self) -> bool {
        matches!(self, Liveness::Active)
    }

    /// Is the instance still part of the cluster (able to finish work it
    /// already holds — Active or Draining)?
    pub fn in_cluster(self) -> bool {
        !matches!(self, Liveness::Dead)
    }
}

/// A cluster-membership change, delivered to policies through
/// [`Policy::on_membership`]. The substrate (simulator event loop or live
/// coordinator) owns detection and work recovery; the policy owns only
/// the scheduling consequences — re-seeding pools and re-running the
/// Alg. 2/4 flip logic against the new capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipEvent {
    /// A new (or returning) instance is live at table index `id` and
    /// visible through the view; the accompanying [`ProfileSource`]
    /// covers it (the substrate profiles joiners exactly like startup).
    InstanceJoined { id: InstanceId },
    /// The instance will leave once its in-flight work drains; it must
    /// receive no further placements.
    InstanceDraining { id: InstanceId },
    /// The instance failed (or never joined): it is gone *now*; the
    /// substrate re-queues whatever work it held.
    InstanceLost { id: InstanceId },
}

impl MembershipEvent {
    pub fn id(self) -> InstanceId {
        match self {
            MembershipEvent::InstanceJoined { id }
            | MembershipEvent::InstanceDraining { id }
            | MembershipEvent::InstanceLost { id } => id,
        }
    }
}

/// Read-only, substrate-agnostic snapshot of cluster load at decision
/// time. Instances are addressed by their table index (`InstanceId.0`).
pub trait ClusterView {
    /// Number of instances in the cluster (fixed for a view's lifetime).
    fn n_instances(&self) -> usize;

    /// Visit `(input_len, remaining_tokens)` of every queued prefill on
    /// `inst`, in queue order — the public queue view the TTFT predictor
    /// consumes (Insight 1). Internal iteration keeps the trait
    /// dyn-compatible without boxing an iterator per call.
    fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32));

    /// Total queued prefill tokens still to process on `inst`.
    fn queued_prefill_tokens(&self, inst: usize) -> u64 {
        let mut total = 0u64;
        self.for_each_queued_prefill(inst, &mut |_, remaining| total += remaining as u64);
        total
    }

    /// Total KV tokens of running + admitted decode requests — the
    /// paper's "running tokens" decode-load metric (§5.3).
    fn running_tokens(&self, inst: usize) -> u64;

    /// KV capacity of `inst` in tokens (memory bound for admission).
    fn max_kv_tokens(&self, inst: usize) -> u64;

    /// Recent average token generation interval on `inst` (§5.3/§5.5
    /// TPOT proxy). NaN when there is no recent evidence.
    fn avg_token_interval(&self, inst: usize) -> f64;

    /// Does `inst` still hold prefill work (queued or in progress)?
    fn has_prefill_work(&self, inst: usize) -> bool;

    /// Does `inst` still hold decode work (running or parked)?
    fn has_decode_work(&self, inst: usize) -> bool;

    /// No work of either phase — harvest candidate (§5.5 condition 3).
    fn is_idle(&self, inst: usize) -> bool {
        !self.has_prefill_work(inst) && !self.has_decode_work(inst)
    }

    /// Cluster-membership state of the slot (PR 3). Defaults to `Active`
    /// so fixed-membership views (and simple test doubles) need not
    /// implement it; elastic substrates override.
    fn liveness(&self, _inst: usize) -> Liveness {
        Liveness::Active
    }
}

/// Startup profiling access (paper §5.3): how a policy learns each
/// instance's prefill curve and Max Running Tokens before serving. The
/// simulator answers from cost models; the live server answers from
/// timed probe prompts — the policy cannot tell the difference.
pub trait ProfileSource {
    /// Number of instances that will be profiled.
    fn n_instances(&self) -> usize;

    /// Fit the TTFT quadratic for instance `i` (heterogeneous clusters
    /// profile each instance separately, §8).
    fn fit_predictor(&self, i: usize) -> TtftPredictor;

    /// Profiled Max Running Tokens of instance `i`: the largest decode
    /// batch token count that still meets `tpot_slo`, capped by memory.
    fn max_running_tokens(&self, i: usize, tpot_slo: f64) -> u64;
}

/// Pre-measured profile table — what the live server builds from real
/// probe timings at startup, and what cross-substrate tests use to hand
/// two policies byte-identical starting knowledge.
pub struct FixedProfile {
    pub predictors: Vec<TtftPredictor>,
    pub max_running_tokens: Vec<u64>,
}

impl ProfileSource for FixedProfile {
    fn n_instances(&self) -> usize {
        self.predictors.len()
    }

    fn fit_predictor(&self, i: usize) -> TtftPredictor {
        self.predictors[i].clone()
    }

    fn max_running_tokens(&self, i: usize, _tpot_slo: f64) -> u64 {
        self.max_running_tokens[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal hand-rolled view: checks the provided (default) methods.
    struct TwoInstances;

    impl ClusterView for TwoInstances {
        fn n_instances(&self) -> usize {
            2
        }
        fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32)) {
            if inst == 0 {
                f(1000, 600);
                f(500, 500);
            }
        }
        fn running_tokens(&self, inst: usize) -> u64 {
            if inst == 0 {
                0
            } else {
                77
            }
        }
        fn max_kv_tokens(&self, _inst: usize) -> u64 {
            100
        }
        fn avg_token_interval(&self, _inst: usize) -> f64 {
            f64::NAN
        }
        fn has_prefill_work(&self, inst: usize) -> bool {
            inst == 0
        }
        fn has_decode_work(&self, inst: usize) -> bool {
            inst == 1
        }
    }

    #[test]
    fn default_accessors_derive_from_primitives() {
        let v = TwoInstances;
        assert_eq!(v.queued_prefill_tokens(0), 1100);
        assert_eq!(v.queued_prefill_tokens(1), 0);
        assert!(!v.is_idle(0), "queued prefill is work");
        assert!(!v.is_idle(1), "decode is work");
    }

    #[test]
    fn fixed_profile_answers_per_instance() {
        let p = FixedProfile {
            predictors: vec![
                TtftPredictor::from_coefficients([0.0, 1e-4, 0.0], 2048, 0.0),
                TtftPredictor::from_coefficients([0.0, 2e-4, 0.0], 2048, 0.0),
            ],
            max_running_tokens: vec![10, 20],
        };
        assert_eq!(ProfileSource::n_instances(&p), 2);
        assert_eq!(p.max_running_tokens(1, 0.1), 20);
        let fast = p.fit_predictor(0).prefill_seconds(1000);
        let slow = p.fit_predictor(1).prefill_seconds(1000);
        assert!(slow > fast);
    }
}
