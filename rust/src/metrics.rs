//! Serving metrics: TTFT/TPOT distributions, SLO attainment, and the
//! max-sustainable-rate search the paper's headline numbers come from.

use crate::request::{RequestRecord, SloClass};
use crate::util::quantile::{BucketQuantile, P2Quantile};
use crate::util::stats;

/// Per-class slice of a report (PR 8). Exact integer folds only — no
/// per-class percentiles — so the streaming sink reproduces these fields
/// bit-identically to `from_records`.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassReport {
    pub class: SloClass,
    pub n_requests: usize,
    pub n_finished: usize,
    pub n_failed: usize,
    /// Fraction of this class's requests meeting *its own* scaled SLO
    /// pair (failed count against; an empty class is vacuously 1.0).
    pub slo_attainment: f64,
    /// Output tokens of this class's SLO-meeting requests per second.
    pub goodput_tokens: f64,
}

/// Shared per-class accumulator: one set of integer folds backs both
/// `SloReport::from_records` and [`StreamingSlo`], so their `per_class`
/// slices agree bit for bit by construction.
#[derive(Debug, Clone, Default)]
struct ClassFold {
    n: [usize; 3],
    finished: [usize; 3],
    failed: [usize; 3],
    ok: [usize; 3],
    good_tokens: [u64; 3],
}

impl ClassFold {
    fn add(&mut self, other: &ClassFold) {
        for i in 0..3 {
            self.n[i] += other.n[i];
            self.finished[i] += other.finished[i];
            self.failed[i] += other.failed[i];
            self.ok[i] += other.ok[i];
            self.good_tokens[i] += other.good_tokens[i];
        }
    }

    /// `span` must already be floored (`max(1e-9)`) by the caller.
    fn reports(&self, span: f64) -> Vec<ClassReport> {
        SloClass::ALL
            .iter()
            .map(|&class| {
                let i = class.index();
                ClassReport {
                    class,
                    n_requests: self.n[i],
                    n_finished: self.finished[i],
                    n_failed: self.failed[i],
                    slo_attainment: if self.n[i] == 0 {
                        1.0
                    } else {
                        self.ok[i] as f64 / self.n[i] as f64
                    },
                    goodput_tokens: self.good_tokens[i] as f64 / span,
                }
            })
            .collect()
    }
}

/// Aggregated metrics over one run (one trace × one system × one rate).
#[derive(Debug, Clone)]
pub struct SloReport {
    pub n_requests: usize,
    pub n_finished: usize,
    pub n_failed: usize,
    /// Fraction of all requests meeting both SLOs (failed count against).
    pub slo_attainment: f64,
    pub ttft_attainment: f64,
    pub tpot_attainment: f64,
    pub p50_ttft: f64,
    pub p90_ttft: f64,
    pub p99_ttft: f64,
    pub p50_tpot: f64,
    pub p90_tpot: f64,
    pub p99_tpot: f64,
    /// Output tokens per second of simulated/wall time.
    pub token_throughput: f64,
    /// Goodput: output tokens of SLO-meeting requests per second.
    pub goodput_tokens: f64,
    /// Per-class breakdown (PR 8), one entry per [`SloClass::ALL`] member
    /// in that order. Empty in hand-built test fixtures.
    pub per_class: Vec<ClassReport>,
}

impl SloReport {
    /// The zero-request report (PR 8 satellite): every attainment is
    /// vacuously 1.0 (no request missed its SLO) and every percentile is
    /// 0.0 — previously an empty run read as 0% attainment with NaN
    /// percentiles, which made `max_sustainable_rate` treat "no traffic"
    /// as "unsustainable" and poisoned downstream comparisons.
    fn empty(span_seconds: f64) -> SloReport {
        let span = span_seconds.max(1e-9);
        SloReport {
            n_requests: 0,
            n_finished: 0,
            n_failed: 0,
            slo_attainment: 1.0,
            ttft_attainment: 1.0,
            tpot_attainment: 1.0,
            p50_ttft: 0.0,
            p90_ttft: 0.0,
            p99_ttft: 0.0,
            p50_tpot: 0.0,
            p90_tpot: 0.0,
            p99_tpot: 0.0,
            token_throughput: 0.0,
            goodput_tokens: 0.0,
            per_class: ClassFold::default().reports(span),
        }
    }

    pub fn from_records(
        records: &[RequestRecord],
        ttft_slo: f64,
        tpot_slo: f64,
        span_seconds: f64,
    ) -> SloReport {
        let n = records.len();
        if n == 0 {
            return SloReport::empty(span_seconds);
        }
        let mut ttfts = Vec::new();
        let mut tpots = Vec::new();
        let mut ok = 0usize;
        let mut ttft_ok = 0usize;
        let mut tpot_ok = 0usize;
        let mut finished = 0usize;
        let mut failed = 0usize;
        let mut tokens = 0u64;
        let mut good_tokens = 0u64;
        let mut cls = ClassFold::default();
        for r in records {
            let ci = r.class.index();
            cls.n[ci] += 1;
            // Every request is judged against *its own class's* targets
            // (PR 8). Standard's targets are the base pair untouched, so
            // an all-Standard run folds bit-identically to the old
            // class-blind arithmetic.
            let t_slo = r.class.ttft_slo(ttft_slo);
            let p_slo = r.class.tpot_slo(tpot_slo);
            if r.finished() {
                finished += 1;
                cls.finished[ci] += 1;
                // output_len, not token_times.len(): a finished record
                // emitted exactly output_len tokens (sim invariant), and
                // streaming records never populate token_times — counting
                // the declared length makes both modes agree by
                // construction (PR 7 satellite; regression test below).
                tokens += r.output_len as u64;
                let (a, b) = (r.ttft().unwrap(), r.tpot().unwrap());
                ttfts.push(a);
                tpots.push(b);
                if a <= t_slo {
                    ttft_ok += 1;
                }
                if b <= p_slo {
                    tpot_ok += 1;
                }
                if a <= t_slo && b <= p_slo {
                    ok += 1;
                    good_tokens += r.output_len as u64;
                    cls.ok[ci] += 1;
                    cls.good_tokens[ci] += r.output_len as u64;
                }
            } else {
                failed += 1;
                cls.failed[ci] += 1;
            }
        }
        let span = span_seconds.max(1e-9);
        // One sort per metric vector, then interpolate each percentile
        // over the sorted data — `stats::percentile` would clone + sort
        // per call (6 sorts per summary, and rate sweeps build thousands
        // of summaries). Same comparator (total_cmp), same numbers.
        ttfts.sort_by(|a, b| a.total_cmp(b));
        tpots.sort_by(|a, b| a.total_cmp(b));
        SloReport {
            n_requests: n,
            n_finished: finished,
            n_failed: failed,
            slo_attainment: ok as f64 / n.max(1) as f64,
            ttft_attainment: ttft_ok as f64 / n.max(1) as f64,
            tpot_attainment: tpot_ok as f64 / n.max(1) as f64,
            p50_ttft: stats::percentile_sorted(&ttfts, 50.0),
            p90_ttft: stats::percentile_sorted(&ttfts, 90.0),
            p99_ttft: stats::percentile_sorted(&ttfts, 99.0),
            p50_tpot: stats::percentile_sorted(&tpots, 50.0),
            p90_tpot: stats::percentile_sorted(&tpots, 90.0),
            p99_tpot: stats::percentile_sorted(&tpots, 99.0),
            token_throughput: tokens as f64 / span,
            goodput_tokens: good_tokens as f64 / span,
            per_class: cls.reports(span),
        }
    }

    /// Attainment of one class by label-free lookup (PR 8 convenience;
    /// callers hold the class, not its index).
    pub fn class_attainment(&self, class: SloClass) -> f64 {
        self.per_class
            .iter()
            .find(|c| c.class == class)
            .map(|c| c.slo_attainment)
            .unwrap_or(1.0)
    }

    /// The paper's success criterion: ≥90% of requests meet both SLOs.
    pub fn meets_target(&self, target: f64) -> bool {
        self.slo_attainment >= target
    }
}

/// The percentiles a [`StreamingSlo`] tracks (matching [`SloReport`]).
const STREAM_PS: [f64; 3] = [50.0, 90.0, 99.0];

/// Which quantile sketch backs a [`StreamingSlo`].
enum LatencySketch {
    /// One P² estimator per tracked percentile: O(1) memory, no merge.
    P2([P2Quantile; 3]),
    /// Log-bucket histogram: slightly coarser, but merges exactly — the
    /// sharded `parallel_map` reduction uses this variant.
    Bucket(BucketQuantile),
}

impl LatencySketch {
    fn p2() -> LatencySketch {
        LatencySketch::P2([
            P2Quantile::new(STREAM_PS[0]),
            P2Quantile::new(STREAM_PS[1]),
            P2Quantile::new(STREAM_PS[2]),
        ])
    }

    fn bucket() -> LatencySketch {
        LatencySketch::Bucket(BucketQuantile::latency_default())
    }

    fn push(&mut self, x: f64) {
        match self {
            LatencySketch::P2(qs) => {
                for q in qs.iter_mut() {
                    q.push(x);
                }
            }
            LatencySketch::Bucket(b) => b.push(x),
        }
    }

    /// Estimate of `STREAM_PS[i]`.
    fn estimate(&self, i: usize) -> f64 {
        match self {
            LatencySketch::P2(qs) => qs[i].estimate(),
            LatencySketch::Bucket(b) => b.estimate(STREAM_PS[i]),
        }
    }

    fn merge(&mut self, other: &LatencySketch) {
        match (self, other) {
            (LatencySketch::Bucket(a), LatencySketch::Bucket(b)) => a.merge(b),
            _ => panic!("only bucket-mode StreamingSlo sinks merge (P2 markers are not mergeable)"),
        }
    }
}

/// Constant-memory SLO sink (PR 7): fed one record at request completion,
/// it folds counts, token sums and attainment *exactly* (bit-identical to
/// [`SloReport::from_records`]) and the TTFT/TPOT percentiles through
/// quantile sketches (estimates; the sorted `from_records` path remains
/// the oracle, with tolerance-banded agreement tests). This is what lets
/// `max_sustainable_rate` sweeps drop the O(trace) record vector.
pub struct StreamingSlo {
    ttft_slo: f64,
    tpot_slo: f64,
    n: usize,
    finished: usize,
    failed: usize,
    ok: usize,
    ttft_ok: usize,
    tpot_ok: usize,
    tokens: u64,
    good_tokens: u64,
    cls: ClassFold,
    ttft_q: LatencySketch,
    tpot_q: LatencySketch,
}

impl StreamingSlo {
    /// Default sink: P² estimators (smallest memory, sharpest estimates).
    pub fn new(ttft_slo: f64, tpot_slo: f64) -> StreamingSlo {
        StreamingSlo::mk(ttft_slo, tpot_slo, LatencySketch::p2)
    }

    /// Mergeable sink: fixed log-bucket histograms, for sharded sweeps
    /// whose per-shard sinks are folded with [`StreamingSlo::merge`].
    pub fn new_mergeable(ttft_slo: f64, tpot_slo: f64) -> StreamingSlo {
        StreamingSlo::mk(ttft_slo, tpot_slo, LatencySketch::bucket)
    }

    fn mk(ttft_slo: f64, tpot_slo: f64, sketch: fn() -> LatencySketch) -> StreamingSlo {
        StreamingSlo {
            ttft_slo,
            tpot_slo,
            n: 0,
            finished: 0,
            failed: 0,
            ok: 0,
            ttft_ok: 0,
            tpot_ok: 0,
            tokens: 0,
            good_tokens: 0,
            cls: ClassFold::default(),
            ttft_q: sketch(),
            tpot_q: sketch(),
        }
    }

    /// Fold one completed (finished *or* failed) record. Must be called
    /// exactly once per request — same contract as a record's single slot
    /// in the `from_records` input.
    pub fn observe(&mut self, r: &RequestRecord) {
        self.n += 1;
        let ci = r.class.index();
        self.cls.n[ci] += 1;
        // Same class-scaled judgment as `from_records` — identical
        // expressions, so the exact fields stay bit-identical.
        let t_slo = r.class.ttft_slo(self.ttft_slo);
        let p_slo = r.class.tpot_slo(self.tpot_slo);
        if r.finished() {
            self.finished += 1;
            self.cls.finished[ci] += 1;
            self.tokens += r.output_len as u64;
            let (a, b) = (r.ttft().unwrap(), r.tpot().unwrap());
            self.ttft_q.push(a);
            self.tpot_q.push(b);
            if a <= t_slo {
                self.ttft_ok += 1;
            }
            if b <= p_slo {
                self.tpot_ok += 1;
            }
            if a <= t_slo && b <= p_slo {
                self.ok += 1;
                self.good_tokens += r.output_len as u64;
                self.cls.ok[ci] += 1;
                self.cls.good_tokens[ci] += r.output_len as u64;
            }
        } else {
            self.failed += 1;
            self.cls.failed[ci] += 1;
        }
    }

    /// Requests observed so far.
    pub fn observed(&self) -> usize {
        self.n
    }

    /// Fold another sink into this one (bucket mode only). Counts add
    /// exactly; sketches merge exactly and associatively.
    pub fn merge(&mut self, other: &StreamingSlo) {
        assert!(
            self.ttft_slo == other.ttft_slo && self.tpot_slo == other.tpot_slo,
            "merging sinks with different SLOs"
        );
        self.n += other.n;
        self.finished += other.finished;
        self.failed += other.failed;
        self.ok += other.ok;
        self.ttft_ok += other.ttft_ok;
        self.tpot_ok += other.tpot_ok;
        self.tokens += other.tokens;
        self.good_tokens += other.good_tokens;
        self.cls.add(&other.cls);
        self.ttft_q.merge(&other.ttft_q);
        self.tpot_q.merge(&other.tpot_q);
    }

    /// Summarize. Counts, attainment, throughput and goodput are exact
    /// (same arithmetic as `from_records`); percentiles are sketch
    /// estimates.
    pub fn report(&self, span_seconds: f64) -> SloReport {
        if self.n == 0 {
            return SloReport::empty(span_seconds);
        }
        let span = span_seconds.max(1e-9);
        SloReport {
            n_requests: self.n,
            n_finished: self.finished,
            n_failed: self.failed,
            slo_attainment: self.ok as f64 / self.n.max(1) as f64,
            ttft_attainment: self.ttft_ok as f64 / self.n.max(1) as f64,
            tpot_attainment: self.tpot_ok as f64 / self.n.max(1) as f64,
            p50_ttft: self.ttft_q.estimate(0),
            p90_ttft: self.ttft_q.estimate(1),
            p99_ttft: self.ttft_q.estimate(2),
            p50_tpot: self.tpot_q.estimate(0),
            p90_tpot: self.tpot_q.estimate(1),
            p99_tpot: self.tpot_q.estimate(2),
            token_throughput: self.tokens as f64 / span,
            goodput_tokens: self.good_tokens as f64 / span,
            per_class: self.cls.reports(span),
        }
    }
}

/// Find the maximum request rate at which `eval(rate).slo_attainment >=
/// target`, by doubling then bisection — the "maximum sustainable request
/// rate" reported across Fig. 7/8.
pub fn max_sustainable_rate(
    mut eval: impl FnMut(f64) -> SloReport,
    base_rate: f64,
    target: f64,
    tolerance: f64,
) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = base_rate.max(1e-3);
    // Grow until failure (cap the doubling to avoid infinite loops).
    let mut grew = 0;
    while eval(hi).meets_target(target) {
        lo = hi;
        hi *= 2.0;
        grew += 1;
        if grew > 16 {
            return lo; // absurdly high — report what we proved
        }
    }
    // Bisect (lo, hi]: `lo` is the highest *proven-sustainable* rate
    // (0.0 when even the base rate fails — every assignment to `lo` comes
    // from a passing eval), `hi` a proven-failing rate.
    //
    // The stopping rule needs an absolute floor in addition to the
    // relative one — but only for the unsatisfiable case: with `lo == 0`
    // the old `hi - lo > tol * hi` condition could never converge
    // relative to itself (`hi - lo` IS `hi`), so an unsatisfiable target
    // burned ~1000 halvings down through the subnormals before `hi`
    // underflowed to zero — one wasted full simulation per halving. The
    // floor pins "nothing is sustainable" to "less than tol × the first
    // failing rate", i.e. a handful of evals. Once `lo > 0` the floor is
    // deliberately NOT used: it is anchored to the *initial* (larger) hi,
    // so letting it fire there would double the quantization error
    // versus the documented tolerance. The iteration cap bounds eval
    // count even for degenerate tolerances (NaN tolerance, NaN
    // attainment): each eval can be a multi-second simulation, so
    // runaway refinement is a real cost, not a nicety.
    let tol = tolerance.max(1e-6);
    let abs_floor = tol * hi;
    for _ in 0..64 {
        if hi - lo <= tol * hi || (lo == 0.0 && hi <= abs_floor) {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if eval(mid).meets_target(target) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Request, RequestRecord, RequestState, SloClass};

    fn rec_class(arrival: f64, times: &[f64], class: SloClass) -> RequestRecord {
        let req =
            Request::new(0, arrival, 10, times.len().max(1) as u32).with_class(class);
        let mut r = RequestRecord::new(&req);
        for &t in times {
            r.push_token(t);
        }
        r.state = if times.is_empty() {
            RequestState::Failed
        } else {
            RequestState::Finished
        };
        r
    }

    fn rec(arrival: f64, times: &[f64]) -> RequestRecord {
        rec_class(arrival, times, SloClass::Standard)
    }

    #[test]
    fn attainment_counts_failures_against() {
        let records = vec![
            rec(0.0, &[0.5, 0.6, 0.7]), // ttft .5 tpot .1
            rec(0.0, &[5.0, 5.1]),      // ttft 5 violates
            rec(0.0, &[]),              // failed
        ];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        assert_eq!(rep.n_failed, 1);
        assert!((rep.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!((rep.ttft_attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!((rep.tpot_attainment - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_and_goodput() {
        let records = vec![rec(0.0, &[0.5, 0.6]), rec(0.0, &[9.0, 9.1])];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        assert!((rep.token_throughput - 0.4).abs() < 1e-12); // 4 tokens/10s
        assert!((rep.goodput_tokens - 0.2).abs() < 1e-12); // only first req
    }

    #[test]
    fn percentiles_computed() {
        let records: Vec<_> = (0..100)
            .map(|i| rec(0.0, &[i as f64 / 100.0, i as f64 / 100.0 + 0.01]))
            .collect();
        let rep = SloReport::from_records(&records, 10.0, 10.0, 1.0);
        assert!(rep.p90_ttft > rep.p50_ttft);
        assert!(rep.p99_ttft > rep.p90_ttft);
    }

    #[test]
    fn sort_once_percentiles_match_per_call_sorting() {
        // Regression for the PR-4 satellite: SloReport sorts each metric
        // vector once and interpolates with percentile_sorted; the
        // numbers must be identical to the old clone-and-sort-per-
        // percentile stats::percentile path.
        let records: Vec<_> = (0..137)
            .map(|i| {
                let t0 = ((i * 37) % 100) as f64 / 50.0 + 0.01;
                rec(0.0, &[t0, t0 + 0.03, t0 + 0.09])
            })
            .collect();
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        let ttfts: Vec<f64> = records.iter().map(|r| r.ttft().unwrap()).collect();
        let tpots: Vec<f64> = records.iter().map(|r| r.tpot().unwrap()).collect();
        for (got, want) in [
            (rep.p50_ttft, crate::util::stats::percentile(&ttfts, 50.0)),
            (rep.p90_ttft, crate::util::stats::percentile(&ttfts, 90.0)),
            (rep.p99_ttft, crate::util::stats::percentile(&ttfts, 99.0)),
            (rep.p50_tpot, crate::util::stats::percentile(&tpots, 50.0)),
            (rep.p90_tpot, crate::util::stats::percentile(&tpots, 90.0)),
            (rep.p99_tpot, crate::util::stats::percentile(&tpots, 99.0)),
        ] {
            assert_eq!(got.to_bits(), want.to_bits(), "{got} != {want}");
        }
    }

    /// PR 7 satellite regression: token counts now come from `output_len`
    /// for finished records. The sim pushes exactly `output_len` tokens
    /// before marking a record finished, so the old `token_times.len()`
    /// accounting must agree bit-for-bit — on retained *and* streaming
    /// records (whose `token_times` is empty).
    #[test]
    fn token_counts_match_token_times_len_for_finished() {
        let records = vec![
            rec(0.0, &[0.5, 0.6, 0.7]),
            rec(0.0, &[5.0, 5.1]),
            rec(0.5, &[0.9]),
            rec(0.0, &[]), // failed: contributes no tokens either way
        ];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        let by_len: u64 = records
            .iter()
            .filter(|r| r.finished())
            .map(|r| r.token_times.len() as u64)
            .sum();
        assert_eq!(
            rep.token_throughput.to_bits(),
            (by_len as f64 / 10.0).to_bits()
        );
        // Streaming twins: identical report despite empty token_times.
        let streaming: Vec<RequestRecord> = records
            .iter()
            .map(|r| {
                let req = Request::new(0, r.arrival, r.input_len, r.output_len);
                let mut s = RequestRecord::new_streaming(&req);
                for &t in &r.token_times {
                    s.push_token(t);
                }
                s.state = r.state;
                s
            })
            .collect();
        let rep2 = SloReport::from_records(&streaming, 1.0, 0.2, 10.0);
        assert_eq!(rep.token_throughput.to_bits(), rep2.token_throughput.to_bits());
        assert_eq!(rep.goodput_tokens.to_bits(), rep2.goodput_tokens.to_bits());
        assert_eq!(rep.slo_attainment.to_bits(), rep2.slo_attainment.to_bits());
    }

    /// PR 7: the streaming sink's exact fields are bit-identical to
    /// `from_records`; its percentiles agree within the sketch bands.
    #[test]
    fn streaming_slo_agrees_with_from_records() {
        let mut rng = crate::util::rng::Rng::new(77);
        let records: Vec<RequestRecord> = (0..5_000)
            .map(|i| {
                if rng.f64() < 0.05 {
                    return rec(i as f64 * 0.01, &[]); // failed
                }
                let t0 = i as f64 * 0.01 + 0.2 + rng.f64();
                let gap = 0.02 + 0.2 * rng.f64();
                let times: Vec<f64> = (0..8).map(|k| t0 + k as f64 * gap).collect();
                rec(i as f64 * 0.01, &times)
            })
            .collect();
        let span = 60.0;
        let (ttft_slo, tpot_slo) = (1.0, 0.15);
        let oracle = SloReport::from_records(&records, ttft_slo, tpot_slo, span);
        for mergeable in [false, true] {
            let mut sink = if mergeable {
                StreamingSlo::new_mergeable(ttft_slo, tpot_slo)
            } else {
                StreamingSlo::new(ttft_slo, tpot_slo)
            };
            for r in &records {
                sink.observe(r);
            }
            let got = sink.report(span);
            // Exact fields: bit-identical.
            assert_eq!(got.n_requests, oracle.n_requests);
            assert_eq!(got.n_finished, oracle.n_finished);
            assert_eq!(got.n_failed, oracle.n_failed);
            for (a, b) in [
                (got.slo_attainment, oracle.slo_attainment),
                (got.ttft_attainment, oracle.ttft_attainment),
                (got.tpot_attainment, oracle.tpot_attainment),
                (got.token_throughput, oracle.token_throughput),
                (got.goodput_tokens, oracle.goodput_tokens),
            ] {
                assert_eq!(a.to_bits(), b.to_bits(), "exact field drifted");
            }
            assert_eq!(got.per_class, oracle.per_class, "per-class folds drifted");
            // Estimated percentiles: within 10% of the sorted oracle.
            for (est, exact, what) in [
                (got.p50_ttft, oracle.p50_ttft, "p50_ttft"),
                (got.p90_ttft, oracle.p90_ttft, "p90_ttft"),
                (got.p99_ttft, oracle.p99_ttft, "p99_ttft"),
                (got.p50_tpot, oracle.p50_tpot, "p50_tpot"),
                (got.p90_tpot, oracle.p90_tpot, "p90_tpot"),
                (got.p99_tpot, oracle.p99_tpot, "p99_tpot"),
            ] {
                assert!(
                    (est - exact).abs() <= 0.10 * exact.abs() + 1e-9,
                    "{what} (mergeable={mergeable}): est {est} vs exact {exact}"
                );
            }
        }
    }

    /// Bucket-mode sinks merge exactly: sharded fold == single pass.
    #[test]
    fn streaming_slo_merge_matches_single_pass() {
        let shards: Vec<Vec<RequestRecord>> = (0..3)
            .map(|s| {
                (0..200)
                    .map(|i| {
                        let t0 = 0.1 + (s * 200 + i) as f64 * 0.003;
                        rec(t0 - 0.1, &[t0, t0 + 0.05, t0 + 0.1])
                    })
                    .collect()
            })
            .collect();
        let sink_of = |rs: &[RequestRecord]| {
            let mut s = StreamingSlo::new_mergeable(1.0, 0.2);
            for r in rs {
                s.observe(r);
            }
            s
        };
        let mut merged = sink_of(&shards[0]);
        merged.merge(&sink_of(&shards[1]));
        merged.merge(&sink_of(&shards[2]));
        let all: Vec<RequestRecord> = shards.iter().flatten().cloned().collect();
        let single = sink_of(&all);
        let (a, b) = (merged.report(10.0), single.report(10.0));
        for (x, y) in [
            (a.p50_ttft, b.p50_ttft),
            (a.p90_ttft, b.p90_ttft),
            (a.p99_ttft, b.p99_ttft),
            (a.p50_tpot, b.p50_tpot),
            (a.p90_tpot, b.p90_tpot),
            (a.p99_tpot, b.p99_tpot),
            (a.slo_attainment, b.slo_attainment),
            (a.token_throughput, b.token_throughput),
        ] {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.n_requests, b.n_requests);
        assert_eq!(a.per_class, b.per_class);
    }

    /// PR 8 satellite: a zero-request run is vacuously green — every
    /// attainment 1.0, every percentile 0.0 — identically from
    /// `from_records` and the streaming sink. (Previously: 0% attainment
    /// + NaN percentiles, which read "no traffic" as "unsustainable".)
    #[test]
    fn empty_run_is_vacuously_green() {
        let rep = SloReport::from_records(&[], 1.0, 0.2, 10.0);
        assert_eq!(rep.n_requests, 0);
        assert_eq!(rep.slo_attainment, 1.0);
        assert_eq!(rep.ttft_attainment, 1.0);
        assert_eq!(rep.tpot_attainment, 1.0);
        assert_eq!(rep.p50_ttft, 0.0);
        assert_eq!(rep.p99_tpot, 0.0);
        assert!(rep.meets_target(0.9), "no traffic is not an SLO violation");
        for c in &rep.per_class {
            assert_eq!(c.n_requests, 0);
            assert_eq!(c.slo_attainment, 1.0);
        }
        let srep = StreamingSlo::new(1.0, 0.2).report(10.0);
        assert_eq!(srep.slo_attainment.to_bits(), rep.slo_attainment.to_bits());
        assert_eq!(srep.p50_ttft.to_bits(), rep.p50_ttft.to_bits());
        assert_eq!(srep.per_class, rep.per_class);
    }

    /// PR 8: every request is judged against its own class's scaled SLO
    /// pair, and the per-class slices split accordingly — with identical
    /// numbers from the streaming sink.
    #[test]
    fn per_class_judged_against_own_targets() {
        // Base SLOs 1.0 / 0.2; each record has TTFT 0.7 and TPOT 0.1.
        // Standard passes (0.7 <= 1.0), Batch passes (0.7 <= 4.0),
        // Interactive misses its tightened 0.5 target.
        let times = [0.7, 0.8, 0.9];
        let records = vec![
            rec_class(0.0, &times, SloClass::Interactive),
            rec_class(0.0, &times, SloClass::Standard),
            rec_class(0.0, &times, SloClass::Batch),
        ];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        assert!((rep.slo_attainment - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(rep.per_class.len(), 3);
        assert_eq!(rep.class_attainment(SloClass::Interactive), 0.0);
        assert_eq!(rep.class_attainment(SloClass::Standard), 1.0);
        assert_eq!(rep.class_attainment(SloClass::Batch), 1.0);
        // Goodput counts only the passing classes' tokens: 6 of 9 in 10 s.
        assert!((rep.goodput_tokens - 0.6).abs() < 1e-12);
        let mut sink = StreamingSlo::new(1.0, 0.2);
        for r in &records {
            sink.observe(r);
        }
        let srep = sink.report(10.0);
        assert_eq!(srep.per_class, rep.per_class);
        assert_eq!(srep.slo_attainment.to_bits(), rep.slo_attainment.to_bits());
        assert_eq!(srep.goodput_tokens.to_bits(), rep.goodput_tokens.to_bits());
    }

    /// A degenerate report whose only meaningful field is attainment.
    fn flat(att: f64) -> SloReport {
        SloReport {
            n_requests: 1,
            n_finished: 1,
            n_failed: 0,
            slo_attainment: att,
            ttft_attainment: att,
            tpot_attainment: att,
            p50_ttft: 0.0,
            p90_ttft: 0.0,
            p99_ttft: 0.0,
            p50_tpot: 0.0,
            p90_tpot: 0.0,
            p99_tpot: 0.0,
            token_throughput: 0.0,
            goodput_tokens: 0.0,
            per_class: Vec::new(),
        }
    }

    #[test]
    fn max_rate_never_passing_terminates_in_bounded_evals() {
        // Regression (PR 5): with an unsatisfiable target the bracket low
        // end stays at 0, and the old relative-only stopping rule halved
        // `hi` ~1000 times down through the subnormals before exiting.
        // Each eval is a full simulation in real use — the search must
        // give up after a handful.
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(0.0)
            },
            1.0,
            0.9,
            0.01,
        );
        assert_eq!(r, 0.0, "nothing sustainable must report 0");
        assert!(calls < 40, "unsatisfiable target burned {calls} evals");
    }

    #[test]
    fn max_rate_always_passing_capped_by_doubling_guard() {
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(1.0)
            },
            1.0,
            0.9,
            0.01,
        );
        // 17 doublings from the base rate, then report what was proved.
        assert_eq!(r, 65_536.0);
        assert!(calls <= 18, "always-passing eval ran {calls} times");
    }

    #[test]
    fn max_rate_non_monotone_returns_a_proven_rate() {
        // Attainment passes below 7, fails on [7, 10), passes again on
        // [10, 12) — e.g. a burst-alignment artifact. Bisection cannot
        // promise the global optimum, but it must terminate and whatever
        // it returns must be a rate an eval actually proved sustainable.
        let passes = |rate: f64| rate <= 7.0 || (10.0..12.0).contains(&rate);
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |rate| {
                calls += 1;
                flat(if passes(rate) { 1.0 } else { 0.0 })
            },
            1.0,
            0.9,
            0.01,
        );
        assert!(calls < 64, "non-monotone eval ran {calls} times");
        assert!(passes(r), "returned rate {r} was never proven sustainable");
        assert!((6.5..=12.0).contains(&r), "r={r} escaped the feasible region");
    }

    #[test]
    fn max_rate_nan_attainment_treated_as_failure() {
        // A NaN attainment (empty trace, 0/0) must behave like a failing
        // eval: no panic, no spin, result 0.
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(f64::NAN)
            },
            1.0,
            0.9,
            0.01,
        );
        assert_eq!(r, 0.0);
        assert!(calls < 40, "NaN attainment burned {calls} evals");
    }

    #[test]
    fn max_rate_zero_attainment_with_nan_percentiles() {
        // The shape a failed run actually produces: 0 attainment and NaN
        // percentiles (no finished requests to take a percentile of).
        let mut rep = flat(0.0);
        rep.p50_ttft = f64::NAN;
        rep.p99_tpot = f64::NAN;
        let r = max_sustainable_rate(|_| rep.clone(), 2.5, 0.9, 0.05);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn max_rate_finds_threshold() {
        // Synthetic system: attainment = 1 while rate <= 7, else 0.
        let eval = |rate: f64| {
            let ok = rate <= 7.0;
            SloReport {
                n_requests: 1,
                n_finished: 1,
                n_failed: 0,
                slo_attainment: if ok { 1.0 } else { 0.0 },
                ttft_attainment: 1.0,
                tpot_attainment: 1.0,
                p50_ttft: 0.0,
                p90_ttft: 0.0,
                p99_ttft: 0.0,
                p50_tpot: 0.0,
                p90_tpot: 0.0,
                p99_tpot: 0.0,
                token_throughput: 0.0,
                goodput_tokens: 0.0,
                per_class: Vec::new(),
            }
        };
        let r = max_sustainable_rate(eval, 1.0, 0.9, 0.01);
        assert!((r - 7.0).abs() < 0.2, "r={r}");
    }

    #[test]
    fn max_rate_zero_when_base_fails() {
        let eval = |_rate: f64| SloReport {
            n_requests: 1,
            n_finished: 0,
            n_failed: 1,
            slo_attainment: 0.0,
            ttft_attainment: 0.0,
            tpot_attainment: 0.0,
            p50_ttft: f64::NAN,
            p90_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            p50_tpot: f64::NAN,
            p90_tpot: f64::NAN,
            p99_tpot: f64::NAN,
            token_throughput: 0.0,
            goodput_tokens: 0.0,
            per_class: Vec::new(),
        };
        let r = max_sustainable_rate(eval, 1.0, 0.9, 0.01);
        assert!(r < 0.05, "r={r}");
    }
}
