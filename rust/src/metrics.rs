//! Serving metrics: TTFT/TPOT distributions, SLO attainment, and the
//! max-sustainable-rate search the paper's headline numbers come from.

use crate::request::RequestRecord;
use crate::util::stats;

/// Aggregated metrics over one run (one trace × one system × one rate).
#[derive(Debug, Clone)]
pub struct SloReport {
    pub n_requests: usize,
    pub n_finished: usize,
    pub n_failed: usize,
    /// Fraction of all requests meeting both SLOs (failed count against).
    pub slo_attainment: f64,
    pub ttft_attainment: f64,
    pub tpot_attainment: f64,
    pub p50_ttft: f64,
    pub p90_ttft: f64,
    pub p99_ttft: f64,
    pub p50_tpot: f64,
    pub p90_tpot: f64,
    pub p99_tpot: f64,
    /// Output tokens per second of simulated/wall time.
    pub token_throughput: f64,
    /// Goodput: output tokens of SLO-meeting requests per second.
    pub goodput_tokens: f64,
}

impl SloReport {
    pub fn from_records(
        records: &[RequestRecord],
        ttft_slo: f64,
        tpot_slo: f64,
        span_seconds: f64,
    ) -> SloReport {
        let n = records.len();
        let mut ttfts = Vec::new();
        let mut tpots = Vec::new();
        let mut ok = 0usize;
        let mut ttft_ok = 0usize;
        let mut tpot_ok = 0usize;
        let mut finished = 0usize;
        let mut failed = 0usize;
        let mut tokens = 0u64;
        let mut good_tokens = 0u64;
        for r in records {
            if r.finished() {
                finished += 1;
                tokens += r.token_times.len() as u64;
                let (a, b) = (r.ttft().unwrap(), r.tpot().unwrap());
                ttfts.push(a);
                tpots.push(b);
                if a <= ttft_slo {
                    ttft_ok += 1;
                }
                if b <= tpot_slo {
                    tpot_ok += 1;
                }
                if a <= ttft_slo && b <= tpot_slo {
                    ok += 1;
                    good_tokens += r.token_times.len() as u64;
                }
            } else {
                failed += 1;
            }
        }
        let span = span_seconds.max(1e-9);
        // One sort per metric vector, then interpolate each percentile
        // over the sorted data — `stats::percentile` would clone + sort
        // per call (6 sorts per summary, and rate sweeps build thousands
        // of summaries). Same comparator (total_cmp), same numbers.
        ttfts.sort_by(|a, b| a.total_cmp(b));
        tpots.sort_by(|a, b| a.total_cmp(b));
        SloReport {
            n_requests: n,
            n_finished: finished,
            n_failed: failed,
            slo_attainment: ok as f64 / n.max(1) as f64,
            ttft_attainment: ttft_ok as f64 / n.max(1) as f64,
            tpot_attainment: tpot_ok as f64 / n.max(1) as f64,
            p50_ttft: stats::percentile_sorted(&ttfts, 50.0),
            p90_ttft: stats::percentile_sorted(&ttfts, 90.0),
            p99_ttft: stats::percentile_sorted(&ttfts, 99.0),
            p50_tpot: stats::percentile_sorted(&tpots, 50.0),
            p90_tpot: stats::percentile_sorted(&tpots, 90.0),
            p99_tpot: stats::percentile_sorted(&tpots, 99.0),
            token_throughput: tokens as f64 / span,
            goodput_tokens: good_tokens as f64 / span,
        }
    }

    /// The paper's success criterion: ≥90% of requests meet both SLOs.
    pub fn meets_target(&self, target: f64) -> bool {
        self.slo_attainment >= target
    }
}

/// Find the maximum request rate at which `eval(rate).slo_attainment >=
/// target`, by doubling then bisection — the "maximum sustainable request
/// rate" reported across Fig. 7/8.
pub fn max_sustainable_rate(
    mut eval: impl FnMut(f64) -> SloReport,
    base_rate: f64,
    target: f64,
    tolerance: f64,
) -> f64 {
    let mut lo = 0.0f64;
    let mut hi = base_rate.max(1e-3);
    // Grow until failure (cap the doubling to avoid infinite loops).
    let mut grew = 0;
    while eval(hi).meets_target(target) {
        lo = hi;
        hi *= 2.0;
        grew += 1;
        if grew > 16 {
            return lo; // absurdly high — report what we proved
        }
    }
    // Bisect (lo, hi]: `lo` is the highest *proven-sustainable* rate
    // (0.0 when even the base rate fails — every assignment to `lo` comes
    // from a passing eval), `hi` a proven-failing rate.
    //
    // The stopping rule needs an absolute floor in addition to the
    // relative one — but only for the unsatisfiable case: with `lo == 0`
    // the old `hi - lo > tol * hi` condition could never converge
    // relative to itself (`hi - lo` IS `hi`), so an unsatisfiable target
    // burned ~1000 halvings down through the subnormals before `hi`
    // underflowed to zero — one wasted full simulation per halving. The
    // floor pins "nothing is sustainable" to "less than tol × the first
    // failing rate", i.e. a handful of evals. Once `lo > 0` the floor is
    // deliberately NOT used: it is anchored to the *initial* (larger) hi,
    // so letting it fire there would double the quantization error
    // versus the documented tolerance. The iteration cap bounds eval
    // count even for degenerate tolerances (NaN tolerance, NaN
    // attainment): each eval can be a multi-second simulation, so
    // runaway refinement is a real cost, not a nicety.
    let tol = tolerance.max(1e-6);
    let abs_floor = tol * hi;
    for _ in 0..64 {
        if hi - lo <= tol * hi || (lo == 0.0 && hi <= abs_floor) {
            break;
        }
        let mid = 0.5 * (lo + hi);
        if eval(mid).meets_target(target) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Request, RequestRecord, RequestState};

    fn rec(arrival: f64, times: &[f64]) -> RequestRecord {
        let req = Request::new(0, arrival, 10, times.len().max(1) as u32);
        let mut r = RequestRecord::new(&req);
        if !times.is_empty() {
            r.first_token = Some(times[0]);
            r.token_times = times.to_vec();
            r.state = RequestState::Finished;
        } else {
            r.state = RequestState::Failed;
        }
        r
    }

    #[test]
    fn attainment_counts_failures_against() {
        let records = vec![
            rec(0.0, &[0.5, 0.6, 0.7]), // ttft .5 tpot .1
            rec(0.0, &[5.0, 5.1]),      // ttft 5 violates
            rec(0.0, &[]),              // failed
        ];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        assert_eq!(rep.n_failed, 1);
        assert!((rep.slo_attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!((rep.ttft_attainment - 1.0 / 3.0).abs() < 1e-12);
        assert!((rep.tpot_attainment - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_and_goodput() {
        let records = vec![rec(0.0, &[0.5, 0.6]), rec(0.0, &[9.0, 9.1])];
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        assert!((rep.token_throughput - 0.4).abs() < 1e-12); // 4 tokens/10s
        assert!((rep.goodput_tokens - 0.2).abs() < 1e-12); // only first req
    }

    #[test]
    fn percentiles_computed() {
        let records: Vec<_> = (0..100)
            .map(|i| rec(0.0, &[i as f64 / 100.0, i as f64 / 100.0 + 0.01]))
            .collect();
        let rep = SloReport::from_records(&records, 10.0, 10.0, 1.0);
        assert!(rep.p90_ttft > rep.p50_ttft);
        assert!(rep.p99_ttft > rep.p90_ttft);
    }

    #[test]
    fn sort_once_percentiles_match_per_call_sorting() {
        // Regression for the PR-4 satellite: SloReport sorts each metric
        // vector once and interpolates with percentile_sorted; the
        // numbers must be identical to the old clone-and-sort-per-
        // percentile stats::percentile path.
        let records: Vec<_> = (0..137)
            .map(|i| {
                let t0 = ((i * 37) % 100) as f64 / 50.0 + 0.01;
                rec(0.0, &[t0, t0 + 0.03, t0 + 0.09])
            })
            .collect();
        let rep = SloReport::from_records(&records, 1.0, 0.2, 10.0);
        let ttfts: Vec<f64> = records.iter().map(|r| r.ttft().unwrap()).collect();
        let tpots: Vec<f64> = records.iter().map(|r| r.tpot().unwrap()).collect();
        for (got, want) in [
            (rep.p50_ttft, crate::util::stats::percentile(&ttfts, 50.0)),
            (rep.p90_ttft, crate::util::stats::percentile(&ttfts, 90.0)),
            (rep.p99_ttft, crate::util::stats::percentile(&ttfts, 99.0)),
            (rep.p50_tpot, crate::util::stats::percentile(&tpots, 50.0)),
            (rep.p90_tpot, crate::util::stats::percentile(&tpots, 90.0)),
            (rep.p99_tpot, crate::util::stats::percentile(&tpots, 99.0)),
        ] {
            assert_eq!(got.to_bits(), want.to_bits(), "{got} != {want}");
        }
    }

    /// A degenerate report whose only meaningful field is attainment.
    fn flat(att: f64) -> SloReport {
        SloReport {
            n_requests: 1,
            n_finished: 1,
            n_failed: 0,
            slo_attainment: att,
            ttft_attainment: att,
            tpot_attainment: att,
            p50_ttft: 0.0,
            p90_ttft: 0.0,
            p99_ttft: 0.0,
            p50_tpot: 0.0,
            p90_tpot: 0.0,
            p99_tpot: 0.0,
            token_throughput: 0.0,
            goodput_tokens: 0.0,
        }
    }

    #[test]
    fn max_rate_never_passing_terminates_in_bounded_evals() {
        // Regression (PR 5): with an unsatisfiable target the bracket low
        // end stays at 0, and the old relative-only stopping rule halved
        // `hi` ~1000 times down through the subnormals before exiting.
        // Each eval is a full simulation in real use — the search must
        // give up after a handful.
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(0.0)
            },
            1.0,
            0.9,
            0.01,
        );
        assert_eq!(r, 0.0, "nothing sustainable must report 0");
        assert!(calls < 40, "unsatisfiable target burned {calls} evals");
    }

    #[test]
    fn max_rate_always_passing_capped_by_doubling_guard() {
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(1.0)
            },
            1.0,
            0.9,
            0.01,
        );
        // 17 doublings from the base rate, then report what was proved.
        assert_eq!(r, 65_536.0);
        assert!(calls <= 18, "always-passing eval ran {calls} times");
    }

    #[test]
    fn max_rate_non_monotone_returns_a_proven_rate() {
        // Attainment passes below 7, fails on [7, 10), passes again on
        // [10, 12) — e.g. a burst-alignment artifact. Bisection cannot
        // promise the global optimum, but it must terminate and whatever
        // it returns must be a rate an eval actually proved sustainable.
        let passes = |rate: f64| rate <= 7.0 || (10.0..12.0).contains(&rate);
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |rate| {
                calls += 1;
                flat(if passes(rate) { 1.0 } else { 0.0 })
            },
            1.0,
            0.9,
            0.01,
        );
        assert!(calls < 64, "non-monotone eval ran {calls} times");
        assert!(passes(r), "returned rate {r} was never proven sustainable");
        assert!((6.5..=12.0).contains(&r), "r={r} escaped the feasible region");
    }

    #[test]
    fn max_rate_nan_attainment_treated_as_failure() {
        // A NaN attainment (empty trace, 0/0) must behave like a failing
        // eval: no panic, no spin, result 0.
        let mut calls = 0u32;
        let r = max_sustainable_rate(
            |_| {
                calls += 1;
                flat(f64::NAN)
            },
            1.0,
            0.9,
            0.01,
        );
        assert_eq!(r, 0.0);
        assert!(calls < 40, "NaN attainment burned {calls} evals");
    }

    #[test]
    fn max_rate_zero_attainment_with_nan_percentiles() {
        // The shape a failed run actually produces: 0 attainment and NaN
        // percentiles (no finished requests to take a percentile of).
        let mut rep = flat(0.0);
        rep.p50_ttft = f64::NAN;
        rep.p99_tpot = f64::NAN;
        let r = max_sustainable_rate(|_| rep.clone(), 2.5, 0.9, 0.05);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn max_rate_finds_threshold() {
        // Synthetic system: attainment = 1 while rate <= 7, else 0.
        let eval = |rate: f64| {
            let ok = rate <= 7.0;
            SloReport {
                n_requests: 1,
                n_finished: 1,
                n_failed: 0,
                slo_attainment: if ok { 1.0 } else { 0.0 },
                ttft_attainment: 1.0,
                tpot_attainment: 1.0,
                p50_ttft: 0.0,
                p90_ttft: 0.0,
                p99_ttft: 0.0,
                p50_tpot: 0.0,
                p90_tpot: 0.0,
                p99_tpot: 0.0,
                token_throughput: 0.0,
                goodput_tokens: 0.0,
            }
        };
        let r = max_sustainable_rate(eval, 1.0, 0.9, 0.01);
        assert!((r - 7.0).abs() < 0.2, "r={r}");
    }

    #[test]
    fn max_rate_zero_when_base_fails() {
        let eval = |_rate: f64| SloReport {
            n_requests: 1,
            n_finished: 0,
            n_failed: 1,
            slo_attainment: 0.0,
            ttft_attainment: 0.0,
            tpot_attainment: 0.0,
            p50_ttft: f64::NAN,
            p90_ttft: f64::NAN,
            p99_ttft: f64::NAN,
            p50_tpot: f64::NAN,
            p90_tpot: f64::NAN,
            p99_tpot: f64::NAN,
            token_throughput: 0.0,
            goodput_tokens: 0.0,
        };
        let r = max_sustainable_rate(eval, 1.0, 0.9, 0.01);
        assert!(r < 0.05, "r={r}");
    }
}
