//! Figure/table regeneration harness: one entry point per table and
//! figure in the paper's evaluation (§3 Fig. 1/2/4, §7 Table 1,
//! Fig. 7/8/9). Each emits a human-readable table to stdout and a JSON
//! file under `out_dir` for plotting. See DESIGN.md §6 for the index and
//! EXPERIMENTS.md for paper-vs-measured discussion.

use std::fmt::Write as _;
use std::path::Path;

use crate::costmodel::CostModel;
use crate::json::Json;
use crate::metrics::{max_sustainable_rate, SloReport};
use crate::scenarios::{build, System};
use crate::trace::catalog::{self, Workload};
use crate::trace::Trace;
use crate::util::stats;
use crate::util::threads::{default_workers, parallel_map};

/// Shared harness options.
#[derive(Debug, Clone)]
pub struct FigOpts {
    pub seed: u64,
    /// Clip each trace to this many seconds before sweeping (keeps the
    /// fig7/8/9 sweeps tractable; the paper replays full traces on 8×H800).
    pub clip_seconds: f64,
    pub gpus: usize,
    pub out_dir: String,
    pub workers: usize,
    /// SLO attainment target (paper uses 90%).
    pub target: f64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            seed: 1,
            clip_seconds: 300.0,
            gpus: 8,
            out_dir: "results".into(),
            workers: default_workers(),
            target: 0.9,
        }
    }
}

fn write_json(opts: &FigOpts, name: &str, v: &Json) {
    let dir = Path::new(&opts.out_dir);
    if std::fs::create_dir_all(dir).is_ok() {
        let path = dir.join(name);
        if let Err(e) = std::fs::write(&path, v.encode()) {
            eprintln!("warn: cannot write {}: {e}", path.display());
        } else {
            println!("  -> {}", path.display());
        }
    }
}

/// One simulation run for a sweep point. `base` is the shared hardware
/// cost model — constructed once per figure, not once per run, so the
/// (hundreds of) sweep jobs only pay refcount bumps inside `build`.
fn run_once(
    sys: System,
    base: &CostModel,
    trace: &Trace,
    w: &Workload,
    gpus: usize,
    rate: f64,
    timeline: bool,
) -> (SloReport, crate::sim::SimResult) {
    let t = trace.with_rate(rate);
    let cl = build(sys, gpus, base, w.ttft_slo, w.tpot_slo, timeline);
    let res = cl.run(&t);
    let rep = SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, t.duration());
    (rep, res)
}

// ---------------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------------

/// Table 1: workloads and SLO settings (validates generator statistics
/// against the published trace characteristics at the same time).
pub fn table1(opts: &FigOpts) {
    println!("Table 1 — workloads and SLO settings");
    println!(
        "{:<15} {:>9} {:>7} {:>7} | {:>10} {:>10} {:>8} {:>8}",
        "trace", "#req", "TTFT", "TPOT", "med_in", "med_out", "io_r", "min_cv"
    );
    let mut rows = Vec::new();
    for w in catalog::table1() {
        let t = w.generate(opts.seed);
        let s = t.stats();
        println!(
            "{:<15} {:>9} {:>6}s {:>6}s | {:>10.0} {:>10.0} {:>8.2} {:>8.2}",
            w.name(),
            t.len(),
            w.ttft_slo,
            w.tpot_slo,
            s.median_input,
            s.median_output,
            s.io_correlation,
            s.minute_input_cv
        );
        rows.push(Json::obj(vec![
            ("trace", Json::Str(w.name().into())),
            ("n_requests", Json::Num(t.len() as f64)),
            ("ttft_slo", Json::Num(w.ttft_slo)),
            ("tpot_slo", Json::Num(w.tpot_slo)),
            ("median_input", Json::Num(s.median_input)),
            ("median_output", Json::Num(s.median_output)),
            ("io_correlation", Json::Num(s.io_correlation)),
            ("minute_input_cv", Json::Num(s.minute_input_cv)),
        ]));
    }
    write_json(opts, "table1.json", &Json::Arr(rows));
}

// ---------------------------------------------------------------------------
// Figure 1 — per-minute input/output load
// ---------------------------------------------------------------------------

pub fn fig1(opts: &FigOpts) {
    println!("Figure 1 — total request input/output length per minute");
    let mut out = Vec::new();
    for w in catalog::table1() {
        let t = w.generate(opts.seed);
        let pm = t.per_minute_load();
        let inputs: Vec<f64> = pm.iter().map(|m| m.input_tokens as f64).collect();
        let cv = stats::coeff_of_variation(&inputs);
        let max = inputs.iter().cloned().fold(0.0, f64::max);
        let min = inputs
            .iter()
            .cloned()
            .filter(|&x| x > 0.0)
            .fold(f64::INFINITY, f64::min);
        println!(
            "  {:<15} minutes={:<3} input cv={:.2} peak/trough={:.0}x",
            w.name(),
            pm.len(),
            cv,
            max / min.max(1.0)
        );
        let series: Vec<Json> = pm
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("minute", Json::Num(m.minute as f64)),
                    ("input_tokens", Json::Num(m.input_tokens as f64)),
                    ("output_tokens", Json::Num(m.output_tokens as f64)),
                    ("requests", Json::Num(m.requests as f64)),
                ])
            })
            .collect();
        out.push(Json::obj(vec![
            ("trace", Json::Str(w.name().into())),
            ("minutes", Json::Arr(series)),
        ]));
    }
    write_json(opts, "fig1.json", &Json::Arr(out));
}

// ---------------------------------------------------------------------------
// Figure 2 — input/output length CDFs
// ---------------------------------------------------------------------------

pub fn fig2(opts: &FigOpts) {
    println!("Figure 2 — input and output length CDFs");
    let probes = [1.0, 5.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0];
    let mut out = Vec::new();
    for w in catalog::table1() {
        let t = w.generate(opts.seed);
        let inputs: Vec<f64> = t.requests.iter().map(|r| r.input_len as f64).collect();
        let outputs: Vec<f64> = t.requests.iter().map(|r| r.output_len as f64).collect();
        let irow: Vec<f64> = probes.iter().map(|&p| stats::percentile(&inputs, p)).collect();
        let orow: Vec<f64> = probes.iter().map(|&p| stats::percentile(&outputs, p)).collect();
        println!("  {:<15} input  p50={:>8.0} p99={:>8.0} max={:>8.0}", w.name(), irow[4], irow[8], irow[9]);
        println!("  {:<15} output p50={:>8.0} p99={:>8.0} max={:>8.0}", "", orow[4], orow[8], orow[9]);
        out.push(Json::obj(vec![
            ("trace", Json::Str(w.name().into())),
            ("percentiles", Json::arr_f64(&probes)),
            ("input", Json::arr_f64(&irow)),
            ("output", Json::arr_f64(&orow)),
        ]));
    }
    write_json(opts, "fig2.json", &Json::Arr(out));
}

// ---------------------------------------------------------------------------
// Figure 4 — prefill vs decode load over time (static 4P/4D)
// ---------------------------------------------------------------------------

/// Replays the rising-load clip of Azure Conversation (paper: minutes
/// 20–40) on a static 4P+4D minimal-load cluster and reports the number of
/// requests being processed by prefill vs decode instances over time,
/// showing the temporal misalignment of Insight 5.
pub fn fig4(opts: &FigOpts) {
    println!("Figure 4 — prefill/decode load over time (static 4P+4D)");
    let w = catalog::by_name("azure_conv").unwrap();
    let full = w.generate(opts.seed);
    let clip = full.window(20.0 * 60.0, 40.0 * 60.0);
    let base = CostModel::h800_llama8b();
    let rate = clip.rate() * 4.0;
    let (_, res) = run_once(System::MinimalLoad, &base, &clip, &w, opts.gpus, rate, true);
    let half = opts.gpus / 2;
    let mut rows = Vec::new();
    let mut peak_p = (0.0, 0usize);
    let mut peak_d = (0.0, 0usize);
    for snap in &res.timeline {
        let p: usize = snap.per_instance[..half].iter().map(|x| x.0 + x.1).sum();
        let d: usize = snap.per_instance[half..].iter().map(|x| x.0 + x.1).sum();
        if p > peak_p.1 {
            peak_p = (snap.time, p);
        }
        if d > peak_d.1 {
            peak_d = (snap.time, d);
        }
        rows.push(Json::obj(vec![
            ("time", Json::Num(snap.time)),
            ("prefill_requests", Json::Num(p as f64)),
            ("decode_requests", Json::Num(d as f64)),
        ]));
    }
    println!(
        "  prefill peak {} reqs at t={:.0}s; decode peak {} reqs at t={:.0}s (lag {:+.0}s)",
        peak_p.1,
        peak_p.0,
        peak_d.1,
        peak_d.0,
        peak_d.0 - peak_p.0
    );
    write_json(opts, "fig4.json", &Json::Arr(rows));
}

// ---------------------------------------------------------------------------
// Figure 7 — end-to-end: SLO attainment / P90 TTFT / P90 TPOT vs rate
// ---------------------------------------------------------------------------

/// Rate multipliers swept per (trace, system) for the Fig. 7 curves.
const FIG7_MULTS: [f64; 8] = [1.0, 2.0, 4.0, 8.0, 16.0, 24.0, 32.0, 48.0];

/// Systems in Fig. 7 (the paper's four: Arrow + three baselines).
const FIG7_SYSTEMS: [System; 4] = [
    System::Arrow,
    System::VllmColocated,
    System::VllmDisaggregated,
    System::DistServe,
];

pub fn fig7(opts: &FigOpts) {
    println!(
        "Figure 7 — SLO attainment / P90 TTFT / P90 TPOT vs request rate ({} GPUs)",
        opts.gpus
    );
    let mut out = Vec::new();
    let base_cost = CostModel::h800_llama8b();
    for w in catalog::table1() {
        let trace = w.generate(opts.seed).clip_seconds(opts.clip_seconds);
        let base = trace.rate();
        println!("\n  [{}] base rate {:.2} req/s, {} requests", w.name(), base, trace.len());
        println!(
            "  {:<13} {}",
            "system",
            FIG7_MULTS
                .iter()
                .map(|m| format!("{:>7.1}", base * m))
                .collect::<Vec<_>>()
                .join(" ")
        );

        let jobs: Vec<(System, f64)> = FIG7_SYSTEMS
            .iter()
            .flat_map(|&s| FIG7_MULTS.iter().map(move |&m| (s, base * m)))
            .collect();
        let reports = parallel_map(jobs.clone(), opts.workers, |&(sys, rate)| {
            run_once(sys, &base_cost, &trace, &w, opts.gpus, rate, false).0
        });

        let mut max_rates = Vec::new();
        for (si, &sys) in FIG7_SYSTEMS.iter().enumerate() {
            let slice = &reports[si * FIG7_MULTS.len()..(si + 1) * FIG7_MULTS.len()];
            let att_row: String = slice
                .iter()
                .map(|r| format!("{:>7.3}", r.slo_attainment))
                .collect::<Vec<_>>()
                .join(" ");
            println!("  {:<13} {}  (attainment)", sys.label(), att_row);
            let rows: Vec<Json> = slice
                .iter()
                .zip(FIG7_MULTS.iter())
                .map(|(r, m)| {
                    Json::obj(vec![
                        ("rate", Json::Num(base * m)),
                        ("slo_attainment", Json::Num(r.slo_attainment)),
                        ("p90_ttft", Json::Num(r.p90_ttft)),
                        ("p90_tpot", Json::Num(r.p90_tpot)),
                        ("failed", Json::Num(r.n_failed as f64)),
                    ])
                })
                .collect();
            // Max sustainable rate via bisection (headline metric).
            let max_rate = max_sustainable_rate(
                |rate| run_once(sys, &base_cost, &trace, &w, opts.gpus, rate, false).0,
                base,
                opts.target,
                0.05,
            );
            max_rates.push((sys, max_rate));
            out.push(Json::obj(vec![
                ("trace", Json::Str(w.name().into())),
                ("system", Json::Str(sys.label().into())),
                ("sweep", Json::Arr(rows)),
                ("max_sustainable_rate", Json::Num(max_rate)),
            ]));
        }
        let arrow_rate = max_rates
            .iter()
            .find(|(s, _)| *s == System::Arrow)
            .unwrap()
            .1;
        print!("  max rate @{:.0}% SLO:", opts.target * 100.0);
        for (sys, r) in &max_rates {
            print!("  {}={:.1}", sys.label(), r);
            if *sys != System::Arrow && *r > 0.0 {
                print!(" ({:.2}x)", arrow_rate / r);
            }
        }
        println!();
    }
    write_json(opts, "fig7.json", &Json::Arr(out));
}

// ---------------------------------------------------------------------------
// Figure 8 — ablation: SLO-aware vs Minimal Load vs Round Robin
// ---------------------------------------------------------------------------

const FIG8_SYSTEMS: [System; 3] = [System::Arrow, System::MinimalLoad, System::RoundRobin];

pub fn fig8(opts: &FigOpts) {
    println!("Figure 8 — scheduling-strategy ablation (SLO-aware / Minimal Load / Round Robin)");
    let mut out = Vec::new();
    let base_cost = CostModel::h800_llama8b();
    for name in ["azure_code", "azure_conv"] {
        let w = catalog::by_name(name).unwrap();
        let trace = w.generate(opts.seed).clip_seconds(opts.clip_seconds);
        let base = trace.rate();
        println!("\n  [{}] base rate {:.2} req/s", name, base);
        let jobs: Vec<System> = FIG8_SYSTEMS.to_vec();
        let rates = parallel_map(jobs, opts.workers, |&sys| {
            max_sustainable_rate(
                |rate| run_once(sys, &base_cost, &trace, &w, opts.gpus, rate, false).0,
                base,
                opts.target,
                0.05,
            )
        });
        let ml = rates[1];
        for (sys, r) in FIG8_SYSTEMS.iter().zip(&rates) {
            print!("    {:<13} max rate {:.1} req/s", sys.label(), r);
            if *sys == System::Arrow && ml > 0.0 {
                print!("  ({:.2}x over minimal-load)", r / ml);
            }
            println!();
            out.push(Json::obj(vec![
                ("trace", Json::Str(name.into())),
                ("system", Json::Str(sys.label().into())),
                ("max_sustainable_rate", Json::Num(*r)),
            ]));
        }
    }
    write_json(opts, "fig8.json", &Json::Arr(out));
}

// ---------------------------------------------------------------------------
// Figure 9 — scalability with GPU count
// ---------------------------------------------------------------------------

const FIG9_GPUS: [usize; 3] = [4, 8, 16];

pub fn fig9(opts: &FigOpts) {
    println!("Figure 9 — scalability: max sustainable rate vs GPU count (azure_code)");
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(opts.seed).clip_seconds(opts.clip_seconds);
    let base = trace.rate();
    let base_cost = CostModel::h800_llama8b();
    let mut out = Vec::new();
    let jobs: Vec<(System, usize)> = [System::Arrow, System::MinimalLoad]
        .iter()
        .flat_map(|&s| FIG9_GPUS.iter().map(move |&g| (s, g)))
        .collect();
    let rates = parallel_map(jobs.clone(), opts.workers, |&(sys, gpus)| {
        max_sustainable_rate(
            |rate| run_once(sys, &base_cost, &trace, &w, gpus, rate, false).0,
            base,
            opts.target,
            0.05,
        )
    });
    for ((sys, gpus), r) in jobs.iter().zip(&rates) {
        println!("    {:<13} {:>2} GPUs: max rate {:.1} req/s", sys.label(), gpus, r);
        out.push(Json::obj(vec![
            ("system", Json::Str(sys.label().into())),
            ("gpus", Json::Num(*gpus as f64)),
            ("max_sustainable_rate", Json::Num(*r)),
        ]));
    }
    // Linearity check for Arrow (paper: "nearly linear improvements").
    let arrow: Vec<f64> = jobs
        .iter()
        .zip(&rates)
        .filter(|((s, _), _)| *s == System::Arrow)
        .map(|(_, r)| *r)
        .collect();
    if arrow.len() == 3 && arrow[0] > 0.0 {
        println!(
            "    arrow scaling 4->8->16 GPUs: 1.0x -> {:.2}x -> {:.2}x",
            arrow[1] / arrow[0],
            arrow[2] / arrow[0]
        );
    }
    write_json(opts, "fig9.json", &Json::Arr(out));
}

// ---------------------------------------------------------------------------
// Paper-claims conformance (PR 5) — the `arrow claims` subcommand
// ---------------------------------------------------------------------------

/// Run the paper-claims conformance sweep under the normalized cost
/// model, print the verdict table, and write `claims.json` next to the
/// figure outputs. Returns whether every claim held — the CLI exits
/// non-zero otherwise, which is how ci.sh gates it.
pub fn claims(opts: &FigOpts, smoke: bool) -> bool {
    let mut cfg = if smoke {
        crate::harness::ClaimsConfig::smoke()
    } else {
        crate::harness::ClaimsConfig::full()
    };
    cfg.seed = opts.seed;
    cfg.gpus = opts.gpus;
    cfg.workers = opts.workers;
    cfg.target = opts.target;
    if !smoke {
        // Smoke keeps its own (capped) clip; full follows --clip.
        cfg.clip_seconds = opts.clip_seconds;
    }
    let report = crate::harness::run_claims(&cfg);
    print!("{}", report.summary());
    write_json(opts, "claims.json", &report.to_json());
    report.all_hold()
}

// ---------------------------------------------------------------------------
// Chaos conformance (PR 6) — the `arrow chaos` subcommand
// ---------------------------------------------------------------------------

/// Run the seeded fault-plan robustness sweep under the normalized cost
/// model, print the invariant table, and write `chaos.json` next to the
/// figure outputs. Returns whether every chaos invariant held — the CLI
/// exits non-zero otherwise, which is how ci.sh gates it.
pub fn chaos(opts: &FigOpts, smoke: bool) -> bool {
    let mut cfg = if smoke {
        crate::harness::chaos::ChaosConfig::smoke()
    } else {
        crate::harness::chaos::ChaosConfig::full()
    };
    cfg.seed = opts.seed;
    cfg.gpus = opts.gpus;
    cfg.workers = opts.workers;
    if !smoke {
        // Smoke keeps its own (capped) clip; full follows --clip.
        cfg.clip_seconds = opts.clip_seconds;
    }
    let report = crate::harness::chaos::run_chaos(&cfg);
    print!("{}", report.summary());
    write_json(opts, "chaos.json", &report.to_json());
    report.all_hold()
}

/// Run everything (the `figures all` subcommand).
pub fn all(opts: &FigOpts) {
    table1(opts);
    fig1(opts);
    fig2(opts);
    fig4(opts);
    fig7(opts);
    fig8(opts);
    fig9(opts);
}

/// Summarize a single replay (the `replay` subcommand).
pub fn replay(system: System, workload: &str, rate_mult: f64, opts: &FigOpts) -> String {
    let w = catalog::by_name(workload).unwrap_or_else(|| {
        eprintln!("unknown workload '{workload}', using smoke");
        catalog::by_name("smoke").unwrap()
    });
    let trace = w.generate(opts.seed).clip_seconds(opts.clip_seconds);
    let rate = trace.rate() * rate_mult;
    let t0 = std::time::Instant::now();
    let base = CostModel::h800_llama8b();
    let (rep, res) = run_once(system, &base, &trace, &w, opts.gpus, rate, false);
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{} on {} @ {:.2} req/s ({} GPUs): attainment={:.3} p90_ttft={:.3}s p90_tpot={:.4}s \
         finished={}/{} failed={} flips={} events={} wall={:.2}s",
        system.label(),
        w.name(),
        rate,
        opts.gpus,
        rep.slo_attainment,
        rep.p90_ttft,
        rep.p90_tpot,
        rep.n_finished,
        rep.n_requests,
        rep.n_failed,
        res.total_flips,
        res.events_processed,
        t0.elapsed().as_secs_f64()
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_opts() -> FigOpts {
        FigOpts {
            seed: 2,
            clip_seconds: 60.0,
            gpus: 4,
            out_dir: std::env::temp_dir()
                .join("arrow_fig_test")
                .to_string_lossy()
                .into_owned(),
            workers: 2,
            target: 0.9,
        }
    }

    #[test]
    fn table1_and_fig12_run() {
        let o = quick_opts();
        table1(&o);
        fig1(&o);
        fig2(&o);
        for f in ["table1.json", "fig1.json", "fig2.json"] {
            let p = Path::new(&o.out_dir).join(f);
            let text = std::fs::read_to_string(&p).unwrap();
            assert!(Json::parse(&text).is_ok(), "{f} must be valid JSON");
        }
    }

    #[test]
    fn replay_produces_summary() {
        let o = quick_opts();
        let s = replay(System::MinimalLoad, "smoke", 1.0, &o);
        assert!(s.contains("minimal-load"));
        assert!(s.contains("attainment="));
    }
}
