//! Statistics helpers: percentiles, CV, Pearson correlation, least-squares
//! quadratic fitting (used by the TTFT predictor and the figure harness).

/// Percentile with linear interpolation (matches numpy's default).
/// `p` in [0, 100]. Returns NaN on empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp: NaN samples sort to the top instead of panicking; they
    // then only distort the percentiles they actually land on.
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Non-allocating percentile via selection instead of a full sort:
/// `select_nth_unstable_by` partitions around the lower interpolation
/// rank in O(n), then the upper neighbour (when the rank is fractional)
/// is the minimum of the upper partition. Same convention as
/// [`percentile`] — linear interpolation, `total_cmp` order, so NaN
/// samples rank last and never panic. Reorders `xs` (callers on the hot
/// path own scratch buffers anyway); returns NaN on empty input.
pub fn percentile_in_place(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let n = xs.len();
    if n == 1 {
        return xs[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let frac = rank - lo as f64;
    let (_, lo_v, upper) = xs.select_nth_unstable_by(lo, f64::total_cmp);
    let lo_v = *lo_v;
    if frac == 0.0 {
        return lo_v;
    }
    // rank < n-1 here, so the upper partition is non-empty.
    let hi_v = upper
        .iter()
        .copied()
        .min_by(|a, b| a.total_cmp(b))
        .expect("fractional rank implies a non-empty upper partition");
    lo_v * (1.0 - frac) + hi_v * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (sigma/mu) — the paper's burstiness metric
/// (Azure Code cv=0.80, BurstGPT cv=1.11, Mooncake cv=0.16; §3.1).
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 || m.is_nan() {
        return f64::NAN;
    }
    std_dev(xs) / m
}

/// Pearson correlation coefficient — the paper's input/output length
/// predictability metric (Azure Code r=0.95, Azure Conversation r=0.29).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return f64::NAN;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return f64::NAN;
    }
    sxy / (sxx * syy).sqrt()
}

/// Empirical CDF points (sorted values, cumulative fraction) — Figure 2.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    v.into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n as f64))
        .collect()
}

/// Least-squares fit of y = c0 + c1*x + c2*x^2 (TTFT-vs-input-length
/// profiling curve, paper §5.3). Solves the 3x3 normal equations by
/// Gaussian elimination. Returns [c0, c1, c2].
pub fn quadratic_fit(xs: &[f64], ys: &[f64]) -> [f64; 3] {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 3, "quadratic_fit needs >= 3 points");
    // Normal equations A^T A c = A^T y with A rows [1, x, x^2].
    let mut m = [[0.0f64; 4]; 3]; // augmented
    for (&x, &y) in xs.iter().zip(ys) {
        let row = [1.0, x, x * x];
        for i in 0..3 {
            for j in 0..3 {
                m[i][j] += row[i] * row[j];
            }
            m[i][3] += row[i] * y;
        }
    }
    gauss_solve3(&mut m)
}

/// Least-squares linear fit y = c0 + c1*x. Returns [c0, c1].
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> [f64; 2] {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "linear_fit needs >= 2 points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let c1 = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    [my - c1 * mx, c1]
}

fn gauss_solve3(m: &mut [[f64; 4]; 3]) -> [f64; 3] {
    for col in 0..3 {
        // Partial pivot.
        let piv = (col..3)
            .max_by(|&a, &b| m[a][col].abs().total_cmp(&m[b][col].abs()))
            .unwrap();
        m.swap(col, piv);
        let d = m[col][col];
        if d.is_nan() {
            // NaN-poisoned samples (e.g. a broken profiling probe):
            // propagate NaN coefficients instead of tripping the singular
            // assert below — predictor consumers order NaN predictions
            // safely via total_cmp.
            return [f64::NAN; 3];
        }
        assert!(d.abs() > 1e-12, "singular system in quadratic_fit");
        for j in col..4 {
            m[col][j] /= d;
        }
        for row in 0..3 {
            if row != col {
                let f = m[row][col];
                for j in col..4 {
                    m[row][j] -= f * m[col][j];
                }
            }
        }
    }
    [m[0][3], m[1][3], m[2][3]]
}

/// Online mean/max/min/count accumulator for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Sliding-window average over the most recent `cap` samples — the
/// instance monitor's "recent average token generation interval" (§5.3).
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    buf: Vec<f64>,
    cap: usize,
    head: usize,
    full: bool,
    sum: f64,
}

impl SlidingWindow {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        SlidingWindow {
            buf: vec![0.0; cap],
            cap,
            head: 0,
            full: false,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.full {
            self.sum -= self.buf[self.head];
        }
        self.buf[self.head] = x;
        self.sum += x;
        self.head = (self.head + 1) % self.cap;
        if self.head == 0 {
            self.full = true;
        }
    }

    pub fn len(&self) -> usize {
        if self.full {
            self.cap
        } else {
            self.head
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mean(&self) -> f64 {
        let n = self.len();
        if n == 0 {
            f64::NAN
        } else {
            self.sum / n as f64
        }
    }

    pub fn clear(&mut self) {
        self.head = 0;
        self.full = false;
        self.sum = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_matches_numpy_convention() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_nan() {
        assert!(percentile(&[], 50.0).is_nan());
    }

    /// Regression for the latent `partial_cmp().unwrap()` panics (PR-2
    /// satellite): a NaN-bearing sample set must flow through the whole
    /// stats layer without panicking. NaN sorts last under `total_cmp`,
    /// so low/mid percentiles of mostly-clean data stay meaningful.
    #[test]
    fn nan_samples_never_panic_stats() {
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 2.5).abs() < 1e-12, "NaN sorted last, p50={p50}");
        assert!(percentile(&xs, 100.0).is_nan(), "NaN lands at the max");
        // ecdf sorts with the same comparator — no panic, 4 points out.
        assert_eq!(ecdf(&xs).len(), 4);
        // quadratic_fit survives a NaN sample (result degenerates to NaN
        // coefficients rather than panicking in the pivot search).
        let fit_xs = [0.0, 1.0, 2.0, 3.0];
        let fit_ys = [1.0, f64::NAN, 5.0, 7.0];
        let c = quadratic_fit(&fit_xs, &fit_ys);
        assert!(c.iter().all(|v| v.is_nan()), "poisoned fit: {c:?}");
    }

    /// The metrics-layer consumer of the same fix: a request record with
    /// a NaN token timestamp reports a gap instead of panicking.
    #[test]
    fn nan_token_time_does_not_panic_max_gap() {
        use crate::request::{Request, RequestRecord};
        let req = Request::new(1, 0.0, 10, 3);
        let mut rec = RequestRecord::new(&req);
        rec.push_token(1.0);
        rec.push_token(f64::NAN);
        rec.push_token(2.0);
        let _ = rec.max_token_gap(); // must not panic
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 90.0), 7.0);
    }

    #[test]
    fn percentile_in_place_matches_sort_based() {
        let mut r = crate::util::rng::Rng::new(9);
        for n in [1usize, 2, 3, 7, 64, 501] {
            let xs: Vec<f64> = (0..n).map(|_| r.normal() * 10.0).collect();
            for p in [0.0, 12.5, 50.0, 90.0, 99.0, 100.0] {
                let mut scratch = xs.clone();
                let got = percentile_in_place(&mut scratch, p);
                let want = percentile(&xs, p);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "n={n} p={p}: {got} != {want}"
                );
            }
        }
        assert!(percentile_in_place(&mut [], 50.0).is_nan());
        assert_eq!(percentile_in_place(&mut [7.0], 90.0), 7.0);
    }

    #[test]
    fn percentile_in_place_nan_convention_matches_total_cmp() {
        // NaN ranks last (total_cmp), exactly like the sorting path: mid
        // percentiles of mostly-clean data stay meaningful, the max is
        // poisoned.
        let xs = [2.0, f64::NAN, 1.0, 3.0];
        let mut scratch = xs;
        let p50 = percentile_in_place(&mut scratch, 50.0);
        assert!((p50 - 2.5).abs() < 1e-12, "p50={p50}");
        let mut scratch = xs;
        assert!(percentile_in_place(&mut scratch, 100.0).is_nan());
        // All-NaN input: every percentile is NaN, never a panic.
        let mut all_nan = [f64::NAN; 3];
        assert!(percentile_in_place(&mut all_nan, 50.0).is_nan());
    }

    #[test]
    fn cv_constant_zero() {
        assert!((coeff_of_variation(&[3.0, 3.0, 3.0]) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let mut r = crate::util::rng::Rng::new(1);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let ys: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        assert!(pearson(&xs, &ys).abs() < 0.02);
    }

    #[test]
    fn quadratic_fit_exact() {
        // y = 2 + 3x + 0.5x^2
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x + 0.5 * x * x).collect();
        let c = quadratic_fit(&xs, &ys);
        assert!((c[0] - 2.0).abs() < 1e-8, "{c:?}");
        assert!((c[1] - 3.0).abs() < 1e-8, "{c:?}");
        assert!((c[2] - 0.5).abs() < 1e-8, "{c:?}");
    }

    #[test]
    fn quadratic_fit_noisy_recovers() {
        let mut r = crate::util::rng::Rng::new(2);
        let xs: Vec<f64> = (1..200).map(|i| i as f64 * 10.0).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 5.0 + 0.2 * x + 1e-3 * x * x + r.normal() * 0.5)
            .collect();
        let c = quadratic_fit(&xs, &ys);
        assert!((c[2] - 1e-3).abs() < 1e-4, "{c:?}");
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let c = linear_fit(&xs, &ys);
        assert!((c[0] - 1.0).abs() < 1e-10 && (c[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn ecdf_monotone() {
        let pts = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].0, 1.0);
        assert!((pts[2].1 - 1.0).abs() < 1e-12);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0 && w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn sliding_window_wraps() {
        let mut w = SlidingWindow::new(3);
        assert!(w.is_empty());
        w.push(1.0);
        w.push(2.0);
        assert!((w.mean() - 1.5).abs() < 1e-12);
        w.push(3.0);
        w.push(10.0); // evicts 1.0
        assert_eq!(w.len(), 3);
        assert!((w.mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn accum_tracks_extremes() {
        let mut a = Accum::new();
        for x in [3.0, -1.0, 7.0] {
            a.push(x);
        }
        assert_eq!(a.n, 3);
        assert_eq!(a.min, -1.0);
        assert_eq!(a.max, 7.0);
        assert!((a.mean() - 3.0).abs() < 1e-12);
    }
}
