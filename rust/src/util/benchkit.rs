//! Tiny benchmark harness (substrate for the unavailable `criterion`
//! crate), used by the `[[bench]] harness = false` targets.
//!
//! Method: warmup, then timed batches until `min_time` elapses; reports
//! mean / p50 / p90 / p99 per-iteration wall time plus throughput. A
//! `black_box` shim prevents the optimizer from deleting the measured work.

use std::hint;
use std::time::{Duration, Instant};

use super::stats;

/// Prevent dead-code elimination of benchmark results.
#[inline]
pub fn black_box<T>(x: T) -> T {
    hint::black_box(x)
}

/// Parse an `f64` knob from the environment (bench gate thresholds),
/// falling back to `default` when unset or unparseable.
pub fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// One benchmark's timing summary (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {}  p50 {}  p90 {}  p99 {}  ({:.1}/s)",
            self.name,
            self.iters,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p90_s),
            fmt_dur(self.p99_s),
            self.per_sec()
        )
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Benchmark runner with fixed warmup + measurement windows.
pub struct Bencher {
    pub warmup: Duration,
    pub min_time: Duration,
    pub max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            min_time: Duration::from_secs(1),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            min_time: Duration::from_millis(300),
            max_iters: 100_000,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; each call is one measured iteration.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.min_time && (samples.len() as u64) < self.max_iters {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        // total_cmp, not partial_cmp().unwrap(): timing samples are
        // finite in practice, but the reporter must never panic.
        samples.sort_by(|a, b| a.total_cmp(b));
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_s: stats::mean(&samples),
            p50_s: stats::percentile_sorted(&samples, 50.0),
            p90_s: stats::percentile_sorted(&samples, 90.0),
            p99_s: stats::percentile_sorted(&samples, 99.0),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(20),
            max_iters: 10_000,
            results: Vec::new(),
        };
        let r = b.bench("noop-sum", || (0..100u64).sum::<u64>()).clone();
        assert!(r.iters > 0);
        assert!(r.mean_s >= 0.0);
        assert!(r.p50_s <= r.p99_s);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(2.0).ends_with('s'));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("us"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }
}
