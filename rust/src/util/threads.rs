//! Tiny parallel-map over OS threads (substrate: no rayon/tokio offline).
//!
//! Used by the figure harness to run independent (system × rate × trace)
//! simulations concurrently. Work-stealing via a shared atomic index keeps
//! workers busy regardless of per-job variance.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f` to every element of `items` across `workers` threads,
/// preserving input order in the output.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<R>>> = Mutex::new((0..n).map(|_| None).collect());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });

    out.into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("worker missed a slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(items.clone(), 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<u64> = parallel_map(Vec::<u64>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_works() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn uneven_work_completes() {
        let out = parallel_map((0..32).collect::<Vec<u64>>(), 4, |&x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x
        });
        assert_eq!(out.len(), 32);
    }
}
