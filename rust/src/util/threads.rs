//! Tiny parallel-map over OS threads (substrate: no rayon/tokio offline).
//!
//! Used by the figure harness to run independent (system × rate × trace)
//! simulations concurrently. Work-stealing via a shared atomic index keeps
//! workers busy regardless of per-job variance; results are accumulated in
//! per-worker buffers and merged once per worker — the previous
//! per-item `Mutex<Vec<Option<R>>>` serialized every completion through
//! one lock, which showed up once simulations got fast enough.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use by default.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f` to every element of `items` across `workers` threads,
/// preserving input order in the output.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    // Each worker drains the shared index into a private (index, result)
    // buffer and appends it to `chunks` exactly once, at exit: lock
    // contention is O(workers), not O(items).
    let chunks: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(&items[i])));
                }
                if !local.is_empty() {
                    chunks.lock().unwrap().extend(local);
                }
            });
        }
    });

    let mut pairs = chunks.into_inner().unwrap();
    assert_eq!(pairs.len(), n, "worker missed a slot");
    pairs.sort_unstable_by_key(|&(i, _)| i);
    pairs.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(items.clone(), 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input() {
        let out: Vec<u64> = parallel_map(Vec::<u64>::new(), 4, |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_works() {
        let out = parallel_map(vec![1, 2, 3], 1, |&x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn uneven_work_completes() {
        let out = parallel_map((0..32).collect::<Vec<u64>>(), 4, |&x| {
            if x % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            x
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn more_workers_than_items_is_fine() {
        let out = parallel_map(vec![5, 6], 64, |&x| x);
        assert_eq!(out, vec![5, 6]);
    }

    #[test]
    fn order_preserved_under_reverse_completion() {
        // Early items sleep longest: completion order is the reverse of
        // the input order, which the index merge must undo.
        let out = parallel_map((0..16u64).collect::<Vec<_>>(), 8, |&x| {
            std::thread::sleep(std::time::Duration::from_millis(16 - x));
            x
        });
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }
}
