//! Shared substrates: PRNG, statistics, property testing, bench harness.
//!
//! These stand in for the `rand`, `criterion` and `proptest` crates, which
//! are unavailable in the offline registry (DESIGN.md §2).

pub mod benchkit;
pub mod prop;
pub mod quantile;
pub mod rng;
pub mod stats;
pub mod threads;
