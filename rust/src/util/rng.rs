//! Deterministic PRNG + sampling distributions (substrate for the
//! unavailable `rand` crate).
//!
//! `Rng` is xoshiro256** seeded via SplitMix64 — fast, high-quality, and
//! fully reproducible: every workload generator, simulator and property
//! test takes an explicit seed so benchmark rows are replayable bit-for-bit.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (for per-instance / per-request rngs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] (inclusive). Panics if lo > hi.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "int_range: {lo} > {hi}");
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index: empty range");
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Pareto (Lomax-style, heavy tail): xm * U^(-1/alpha).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm * self.f64().max(1e-300).powf(-1.0 / alpha)
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang (k >= 1 fast path,
    /// boost for k < 1). Used for burst inter-arrival clustering.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to `weights` (must be non-negative,
    /// not all zero).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: zero total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn int_range_bounds_inclusive() {
        let mut r = Rng::new(3);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let x = r.int_range(2, 5);
            assert!((2..=5).contains(&x));
            saw_lo |= x == 2;
            saw_hi |= x == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let m = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.lognormal(1.0, 2.0) > 0.0);
        }
    }

    #[test]
    fn pareto_floor() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            assert!(r.pareto(3.0, 1.5) >= 3.0);
        }
    }

    #[test]
    fn gamma_mean() {
        let mut r = Rng::new(23);
        let n = 100_000;
        let m = (0..n).map(|_| r.gamma(2.0, 3.0)).sum::<f64>() / n as f64;
        assert!((m - 6.0).abs() < 0.15, "mean={m}"); // k*theta = 6
    }

    #[test]
    fn gamma_small_shape() {
        let mut r = Rng::new(29);
        let n = 100_000;
        let m = (0..n).map(|_| r.gamma(0.5, 2.0)).sum::<f64>() / n as f64;
        assert!((m - 1.0).abs() < 0.1, "mean={m}");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(31);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_uncorrelated() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xa: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
