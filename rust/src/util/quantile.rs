//! Online quantile estimators for the streaming sweep path (PR 7).
//!
//! Two sketches back `metrics::StreamingSlo`:
//!
//! * [`P2Quantile`] — the P² algorithm (Jain & Chlamtac, CACM 1985): five
//!   markers per tracked percentile, O(1) memory, no merge support. Exact
//!   (bit-identical to [`percentile_sorted`]) below five samples, an
//!   estimator above.
//! * [`BucketQuantile`] — log-spaced fixed buckets with an exact,
//!   associative merge (counts add), for the sharded `parallel_map` path.
//!   Bounded *relative* error: a representative value is within a factor
//!   of `ratio()` of every sample in its bucket.
//!
//! Both follow the repo-wide NaN convention (`util/stats.rs`): NaN samples
//! rank last under `total_cmp`, so an estimate whose rank falls inside the
//! NaN tail is NaN and lower ranks stay meaningful. The sorted path
//! ([`percentile_sorted`]) remains the oracle everywhere; these are
//! estimators with tolerance-banded agreement tests.

use crate::util::stats::percentile_sorted;

/// Shared NaN-tail rank logic: with `finite` non-NaN samples and `nan`
/// NaN samples, the sorted oracle places NaNs last; percentile `p` of the
/// combined set is NaN exactly when the interpolation touches index
/// `>= finite`, i.e. when the (fractional) rank exceeds `finite - 1`.
/// Returns the rank among the finite prefix, or None when poisoned.
fn finite_rank(p: f64, finite: u64, nan: u64) -> Option<f64> {
    let total = finite + nan;
    if total == 0 || finite == 0 {
        return None;
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (total - 1) as f64;
    if nan > 0 && rank > (finite - 1) as f64 {
        return None;
    }
    Some(rank.min((finite - 1) as f64))
}

/// P² single-quantile estimator: five markers whose heights approximate
/// the min, p/2, p, (100+p)/2 and max percentiles. Constant memory, one
/// comparison pass per sample. Does **not** merge — use
/// [`BucketQuantile`] for sharded aggregation.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    /// Target percentile in [0, 100].
    p: f64,
    /// Marker heights h_0..h_4.
    h: [f64; 5],
    /// Actual marker positions (1-based ranks).
    pos: [f64; 5],
    /// Desired marker positions.
    want: [f64; 5],
    /// Per-sample desired-position increments.
    dpos: [f64; 5],
    /// Exact buffer for the first five finite samples (sorted).
    small: Vec<f64>,
    /// Finite samples observed.
    n: u64,
    /// NaN samples observed (tracked for the sort-last convention).
    nan: u64,
}

impl P2Quantile {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
        let q = p / 100.0;
        P2Quantile {
            p,
            h: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            want: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            dpos: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            small: Vec::with_capacity(5),
            n: 0,
            nan: 0,
        }
    }

    /// Finite samples observed so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// NaN samples observed so far.
    pub fn nan_count(&self) -> u64 {
        self.nan
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            self.nan += 1;
            return;
        }
        self.n += 1;
        if self.n <= 5 {
            self.small.push(x);
            self.small.sort_by(|a, b| a.total_cmp(b));
            if self.n == 5 {
                for (i, &v) in self.small.iter().enumerate() {
                    self.h[i] = v;
                }
            }
            return;
        }
        // Locate the cell k with h[k] <= x < h[k+1], extending the
        // extreme markers when x falls outside them.
        let k = if x < self.h[0] {
            self.h[0] = x;
            0
        } else if x >= self.h[4] {
            self.h[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.h[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.want[i] += self.dpos[i];
        }
        // Nudge the interior markers toward their desired positions.
        for i in 1..4 {
            let d = self.want[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = if d >= 1.0 { 1.0 } else { -1.0 };
                let hp = self.parabolic(i, s);
                self.h[i] = if self.h[i - 1] < hp && hp < self.h[i + 1] {
                    hp
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
    }

    /// Piecewise-parabolic (P²) height update for interior marker `i`.
    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (h, p) = (&self.h, &self.pos);
        h[i]
            + s / (p[i + 1] - p[i - 1])
                * ((p[i] - p[i - 1] + s) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
                    + (p[i + 1] - p[i] - s) * (h[i] - h[i - 1]) / (p[i] - p[i - 1]))
    }

    /// Linear fallback when the parabolic prediction leaves the bracket.
    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.h[i] + s * (self.h[j] - self.h[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate of the tracked percentile. Exact (bit-identical
    /// to the sorted oracle) below five finite samples; NaN when the
    /// combined-set rank lands in the NaN tail or nothing was observed.
    pub fn estimate(&self) -> f64 {
        match finite_rank(self.p, self.n, self.nan) {
            None => f64::NAN,
            Some(_) if self.n <= 5 => percentile_sorted(&self.small, self.p),
            Some(_) => self.h[2],
        }
    }
}

/// Log-spaced histogram sketch over `(0, +inf)` with underflow/overflow
/// bins. Merge is exact and associative (bucket counts add), so sharded
/// sweeps can fold per-shard sketches in any grouping and get
/// bit-identical estimates.
#[derive(Debug, Clone)]
pub struct BucketQuantile {
    lo: f64,
    hi: f64,
    ratio: f64,
    /// `[underflow, bucket_0 .. bucket_{nb-1}, overflow]`.
    counts: Vec<u64>,
    n: u64,
    nan: u64,
    min_seen: f64,
    max_seen: f64,
}

impl BucketQuantile {
    /// `nb` log-spaced buckets covering `[lo, hi)`; values below `lo`
    /// (including zero and negatives) land in the underflow bin, values
    /// `>= hi` in the overflow bin.
    pub fn new(lo: f64, hi: f64, nb: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && nb > 0, "bad bucket config");
        BucketQuantile {
            lo,
            hi,
            ratio: (hi / lo).powf(1.0 / nb as f64),
            counts: vec![0; nb + 2],
            n: 0,
            nan: 0,
            min_seen: f64::INFINITY,
            max_seen: f64::NEG_INFINITY,
        }
    }

    /// Default latency sketch: 0.1 ms .. 10 000 s in 512 buckets, i.e.
    /// a per-bucket width ratio of ~1.037 (≈ 1.8% representative error).
    pub fn latency_default() -> Self {
        BucketQuantile::new(1e-4, 1e4, 512)
    }

    /// Per-bucket edge ratio — a representative is within this factor of
    /// every in-range sample sharing its bucket.
    pub fn ratio(&self) -> f64 {
        self.ratio
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn nan_count(&self) -> u64 {
        self.nan
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            self.nan += 1;
            return;
        }
        self.n += 1;
        self.min_seen = self.min_seen.min(x);
        self.max_seen = self.max_seen.max(x);
        let nb = self.counts.len() - 2;
        let idx = if x < self.lo {
            0
        } else if x >= self.hi {
            nb + 1
        } else {
            // floor(log_ratio(x / lo)), clamped against FP edge rounding.
            let b = ((x / self.lo).ln() / self.ratio.ln()).floor() as usize;
            1 + b.min(nb - 1)
        };
        self.counts[idx] += 1;
    }

    /// Exact merge: same-config sketches add counts. Associative and
    /// commutative, so any shard fold order yields bit-identical state.
    pub fn merge(&mut self, other: &BucketQuantile) {
        assert!(
            self.lo == other.lo && self.hi == other.hi && self.counts.len() == other.counts.len(),
            "merging differently-configured bucket sketches"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.nan += other.nan;
        self.min_seen = self.min_seen.min(other.min_seen);
        self.max_seen = self.max_seen.max(other.max_seen);
    }

    /// Percentile estimate: the representative value (geometric bucket
    /// midpoint, clamped to the observed range) of the bucket holding the
    /// rounded oracle rank. NaN under the same tail convention as
    /// [`P2Quantile::estimate`].
    pub fn estimate(&self, p: f64) -> f64 {
        let rank = match finite_rank(p, self.n, self.nan) {
            None => return f64::NAN,
            Some(r) => r,
        };
        let k = (rank.round() as u64).min(self.n - 1);
        let mut cum = 0u64;
        let nb = self.counts.len() - 2;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > k {
                let rep = if idx == 0 {
                    self.min_seen
                } else if idx == nb + 1 {
                    self.max_seen
                } else {
                    let edge = self.lo * self.ratio.powi(idx as i32 - 1);
                    edge * self.ratio.sqrt()
                };
                return rep.clamp(self.min_seen, self.max_seen);
            }
        }
        // Unreachable: k < n and the counts sum to n.
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile;

    /// Rank-band oracle check: the estimate must fall inside the value
    /// band spanned by percentiles `p - band .. p + band` of the sorted
    /// data, widened by `rel` relative slack on each side.
    fn assert_in_rank_band(est: f64, sorted: &[f64], p: f64, band: f64, rel: f64) {
        let lo = percentile_sorted(sorted, (p - band).max(0.0));
        let hi = percentile_sorted(sorted, (p + band).min(100.0));
        let (lo, hi) = (lo - rel * lo.abs() - 1e-12, hi + rel * hi.abs() + 1e-12);
        assert!(
            est >= lo && est <= hi,
            "p{p}: estimate {est} outside band [{lo}, {hi}]"
        );
    }

    fn sorted(xs: &[f64]) -> Vec<f64> {
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        v
    }

    #[test]
    fn p2_small_n_is_exact() {
        for n in 1..=5usize {
            let mut r = Rng::new(7 + n as u64);
            let xs: Vec<f64> = (0..n).map(|_| r.normal() * 3.0).collect();
            for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
                let mut q = P2Quantile::new(p);
                for &x in &xs {
                    q.push(x);
                }
                assert_eq!(
                    q.estimate().to_bits(),
                    percentile(&xs, p).to_bits(),
                    "n={n} p={p}"
                );
            }
        }
    }

    #[test]
    fn p2_uniform_within_band() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.f64()).collect();
        let s = sorted(&xs);
        for p in [50.0, 90.0, 99.0] {
            let mut q = P2Quantile::new(p);
            for &x in &xs {
                q.push(x);
            }
            assert_in_rank_band(q.estimate(), &s, p, 1.0, 0.01);
        }
    }

    #[test]
    fn p2_lognormal_skew_within_band() {
        // Heavy right tail — the adversarial case for marker estimators.
        let mut r = Rng::new(12);
        let xs: Vec<f64> = (0..50_000).map(|_| (1.5 * r.normal()).exp()).collect();
        let s = sorted(&xs);
        for p in [50.0, 90.0, 99.0] {
            let mut q = P2Quantile::new(p);
            for &x in &xs {
                q.push(x);
            }
            assert_in_rank_band(q.estimate(), &s, p, 1.5, 0.05);
        }
    }

    #[test]
    fn p2_heavy_ties_converges_to_mode() {
        // 90% of the mass at one value: p50 and p90 sit deep inside the
        // tie block, so the estimate must land (almost) exactly on it.
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..30_000)
            .map(|_| {
                if r.f64() < 0.9 {
                    5.0
                } else if r.bool(0.5) {
                    r.f64()
                } else {
                    10.0 + r.f64()
                }
            })
            .collect();
        for p in [50.0, 90.0] {
            let mut q = P2Quantile::new(p);
            for &x in &xs {
                q.push(x);
            }
            let est = q.estimate();
            assert!((est - 5.0).abs() < 0.1, "p{p}: {est} should be ~5.0");
        }
    }

    #[test]
    fn p2_nan_poisoned_matches_tail_convention() {
        // 30% NaN: the oracle (NaN sorts last) keeps p50 meaningful and
        // poisons p99. The sketch must agree on which is which.
        let mut r = Rng::new(14);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| if r.f64() < 0.3 { f64::NAN } else { r.f64() })
            .collect();
        let s = sorted(&xs);
        let mut q50 = P2Quantile::new(50.0);
        let mut q99 = P2Quantile::new(99.0);
        for &x in &xs {
            q50.push(x);
            q99.push(x);
        }
        assert!(percentile_sorted(&s, 99.0).is_nan(), "oracle p99 poisoned");
        assert!(q99.estimate().is_nan(), "sketch p99 must be poisoned too");
        let est = q50.estimate();
        assert!(est.is_finite(), "p50 stays meaningful: {est}");
        // Oracle p50 of the combined set ranks within the finite prefix;
        // the sketch estimates the finite-sample percentile, so compare
        // against a generous rank band of the finite values.
        let finite = sorted(&xs.iter().copied().filter(|x| !x.is_nan()).collect::<Vec<_>>());
        assert_in_rank_band(est, &finite, 50.0, 3.0, 0.05);
        // All-NaN input: NaN estimate, never a panic.
        let mut q = P2Quantile::new(50.0);
        for _ in 0..10 {
            q.push(f64::NAN);
        }
        assert!(q.estimate().is_nan());
        assert_eq!(q.nan_count(), 10);
    }

    #[test]
    fn bucket_relative_error_bounded() {
        let mut r = Rng::new(21);
        let xs: Vec<f64> = (0..50_000).map(|_| (1.2 * r.normal() - 1.0).exp()).collect();
        let s = sorted(&xs);
        let q = {
            let mut q = BucketQuantile::latency_default();
            for &x in &xs {
                q.push(x);
            }
            q
        };
        for p in [50.0, 90.0, 99.0] {
            let est = q.estimate(p);
            let oracle = percentile_sorted(&s, p);
            // Representative shares a bucket with the oracle rank (up to
            // the 0.5-rank rounding), so it is within one bucket factor.
            let f = q.ratio() * 1.001;
            assert!(
                est >= oracle / f && est <= oracle * f,
                "p{p}: {est} vs oracle {oracle} (factor {f})"
            );
        }
    }

    #[test]
    fn bucket_ties_and_tiny_n_exact() {
        // All samples identical: min==max, so the clamp makes the
        // representative exact regardless of bucket edges.
        let mut q = BucketQuantile::latency_default();
        for _ in 0..1000 {
            q.push(0.25);
        }
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(q.estimate(p).to_bits(), 0.25f64.to_bits(), "p{p}");
        }
        // Single sample.
        let mut q1 = BucketQuantile::latency_default();
        q1.push(3.0);
        assert_eq!(q1.estimate(50.0).to_bits(), 3.0f64.to_bits());
        // Empty sketch.
        assert!(BucketQuantile::latency_default().estimate(50.0).is_nan());
        // Underflow/overflow land on the observed extremes.
        let mut q2 = BucketQuantile::new(1.0, 10.0, 4);
        q2.push(1e-9);
        q2.push(1e9);
        assert_eq!(q2.estimate(0.0), 1e-9);
        assert_eq!(q2.estimate(100.0), 1e9);
    }

    #[test]
    fn bucket_nan_tail_convention() {
        let mut q = BucketQuantile::latency_default();
        for _ in 0..70 {
            q.push(1.0);
        }
        for _ in 0..30 {
            q.push(f64::NAN);
        }
        assert!(q.estimate(50.0).is_finite());
        assert!(q.estimate(99.0).is_nan(), "rank in the NaN tail");
    }

    #[test]
    fn bucket_merge_is_associative_and_order_free() {
        // Three shards, folded in both groupings and compared against a
        // single-pass sketch over the concatenation: every counter and
        // every estimate must be bit-identical — this is what makes the
        // sharded `parallel_map` reduction deterministic.
        let mut r = Rng::new(31);
        let shards: Vec<Vec<f64>> = (0..3)
            .map(|_| {
                (0..5_000)
                    .map(|_| {
                        if r.f64() < 0.02 {
                            f64::NAN
                        } else {
                            (r.normal()).exp()
                        }
                    })
                    .collect()
            })
            .collect();
        let sketch = |xs: &[f64]| {
            let mut q = BucketQuantile::latency_default();
            for &x in xs {
                q.push(x);
            }
            q
        };
        let (a, b, c) = (sketch(&shards[0]), sketch(&shards[1]), sketch(&shards[2]));
        // (a + b) + c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a + (b + c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        // single pass
        let all: Vec<f64> = shards.iter().flatten().copied().collect();
        let single = sketch(&all);
        for other in [&right, &single] {
            assert_eq!(left.counts, other.counts);
            assert_eq!(left.n, other.n);
            assert_eq!(left.nan, other.nan);
            assert_eq!(left.min_seen.to_bits(), other.min_seen.to_bits());
            assert_eq!(left.max_seen.to_bits(), other.max_seen.to_bits());
            for p in [50.0, 90.0, 99.0] {
                assert_eq!(left.estimate(p).to_bits(), other.estimate(p).to_bits());
            }
        }
    }
}
