//! Minimal property-testing harness (substrate for the unavailable
//! `proptest` crate).
//!
//! A property is a closure over a seeded [`Rng`]; `check` runs it across
//! many seeds and, on failure, reports the failing seed so the case is
//! replayable: `cargo test -- --nocapture` prints
//! `property failed: seed=...` and re-running `check_seed(seed, f)`
//! reproduces it deterministically.

use super::rng::Rng;

/// Number of cases `check` runs by default.
pub const DEFAULT_CASES: u64 = 256;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `f` across `cases` deterministic seeds derived from `base_seed`.
/// Panics with the failing seed + message on the first failure.
pub fn check_with(base_seed: u64, cases: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    for i in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed: seed={seed} case={i}: {msg}");
        }
    }
}

/// Run a property with the default number of cases.
pub fn check(base_seed: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    check_with(base_seed, DEFAULT_CASES, f);
}

/// Re-run a single failing seed (for debugging).
pub fn check_seed(seed: u64, f: impl Fn(&mut Rng) -> CaseResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed: seed={seed}: {msg}");
    }
}

/// Assert helper producing a `CaseResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_with(1, 64, |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check_with(2, 64, |rng| {
            let x = rng.int_range(0, 10);
            prop_assert!(x < 10, "hit boundary x={x}");
            Ok(())
        });
    }

    #[test]
    fn seeds_are_deterministic() {
        let collect = |seed| {
            let mut out = Vec::new();
            check_with(seed, 8, |rng| {
                // Property that records what it saw (via side channel).
                let _ = rng.next_u64();
                Ok(())
            });
            // determinism is really validated by Rng tests; here we check
            // check_with is pure w.r.t. its closure
            out.push(seed);
            out
        };
        assert_eq!(collect(5), collect(5));
    }
}
