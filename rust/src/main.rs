//! `arrow` — CLI for the Arrow reproduction.
//!
//! Subcommands:
//!   figures <table1|fig1|fig2|fig4|fig7|fig8|fig9|all>   regenerate paper tables/figures
//!   claims [--smoke]                                       paper-claims conformance sweep
//!   chaos [--smoke]                                        seeded fault-plan robustness sweep
//!   replay --system S --workload W --rate-mult M          one simulated run
//!   serve --artifacts DIR [--port P] [--instances N]      real-mode HTTP serving (PJRT)
//!   calibrate --artifacts DIR                              profile PJRT executables, fit cost model
//!   traces [--out DIR]                                     dump synthetic traces as JSONL
//!   info                                                   version + scenario summary

use arrow::cli;
use arrow::figures::{self, FigOpts};
use arrow::scenarios::System;
use arrow::trace::catalog;

fn usage() -> ! {
    eprintln!(
        "usage: arrow <subcommand> [flags]

subcommands:
  figures <table1|fig1|fig2|fig4|fig7|fig8|fig9|all>
          [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N] [--target FRAC]
  claims  [--smoke] [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N] [--target FRAC]
          (normalized-cost-model conformance sweep; exits non-zero when a
           paper claim fails; ARROW_CLAIMS_SMOKE=1 implies --smoke)
  chaos   [--smoke] [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N]
          (goodput vs seeded fault intensity; exits non-zero when a chaos
           invariant fails — e.g. a silently lost request;
           ARROW_CHAOS_SMOKE=1 implies --smoke)
  replay  --system <arrow|vllm|vllm-disagg|distserve|minimal-load|round-robin>
          --workload <azure_code|azure_conv|burstgpt|mooncake_conv|smoke>
          [--rate-mult M] [--seed N] [--clip SECONDS] [--gpus N]
  serve   [--artifacts DIR] [--port P] [--instances N] [--ttft-slo S] [--tpot-slo S]
          [--max-inflight N] [--deadline SECONDS]
  calibrate [--artifacts DIR]
  traces  [--out DIR] [--seed N]
  info"
    );
    std::process::exit(2)
}

fn fig_opts(p: &cli::ParsedArgs) -> Result<FigOpts, cli::CliError> {
    let mut o = FigOpts::default();
    o.seed = p.u64_or("seed", o.seed)?;
    o.clip_seconds = p.f64_or("clip", o.clip_seconds)?;
    o.gpus = p.usize_or("gpus", o.gpus)?;
    o.out_dir = p.str_or("out", &o.out_dir);
    o.workers = p.usize_or("workers", o.workers)?;
    o.target = p.f64_or("target", o.target)?;
    Ok(o)
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        usage();
    }
    let p = cli::parse(&raw);
    let sub = p.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match sub {
        "figures" => cmd_figures(&p),
        "claims" => cmd_claims(&p),
        "chaos" => cmd_chaos(&p),
        "replay" => cmd_replay(&p),
        "serve" => cmd_serve(&p),
        "calibrate" => cmd_calibrate(&p),
        "traces" => cmd_traces(&p),
        "info" => cmd_info(),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_figures(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target"])?;
    let opts = fig_opts(p)?;
    let which = p.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    match which {
        "table1" => figures::table1(&opts),
        "fig1" => figures::fig1(&opts),
        "fig2" => figures::fig2(&opts),
        "fig4" => figures::fig4(&opts),
        "fig7" => figures::fig7(&opts),
        "fig8" => figures::fig8(&opts),
        "fig9" => figures::fig9(&opts),
        "all" => figures::all(&opts),
        other => {
            return Err(format!("unknown figure '{other}'").into());
        }
    }
    Ok(())
}

fn cmd_claims(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target", "smoke"])?;
    let mut opts = fig_opts(p)?;
    // The claims contract is keyed to its own fixed seed (tests and CI
    // use 42), not the figures default; --seed still overrides.
    opts.seed = p.u64_or("seed", 42)?;
    let smoke = p.has("smoke") || arrow::harness::smoke_env();
    if figures::claims(&opts, smoke) {
        Ok(())
    } else {
        Err(format!(
            "paper-claims conformance FAILED (see verdicts above; \
             {}/claims.json has the full report)",
            opts.out_dir
        )
        .into())
    }
}

fn cmd_chaos(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target", "smoke"])?;
    let mut opts = fig_opts(p)?;
    // Like claims, the chaos contract is keyed to its own fixed seed;
    // --seed still overrides for exploratory sweeps.
    opts.seed = p.u64_or("seed", 42)?;
    let smoke = p.has("smoke") || arrow::harness::chaos::smoke_env();
    if figures::chaos(&opts, smoke) {
        Ok(())
    } else {
        Err(format!(
            "chaos conformance FAILED (see verdicts above; \
             {}/chaos.json has the full report)",
            opts.out_dir
        )
        .into())
    }
}

fn cmd_replay(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["system", "workload", "rate-mult", "seed", "clip", "gpus"])?;
    let sys = System::by_label(&p.str_or("system", "arrow")).ok_or("unknown --system")?;
    let workload = p.str_or("workload", "smoke");
    let mult = p.f64_or("rate-mult", 1.0)?;
    let opts = fig_opts(p)?;
    print!("{}", figures::replay(sys, &workload, mult, &opts));
    Ok(())
}

fn cmd_serve(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&[
        "artifacts",
        "port",
        "instances",
        "ttft-slo",
        "tpot-slo",
        "max-inflight",
        "deadline",
    ])?;
    let cfg = arrow::server::ServeConfig {
        artifacts_dir: p.str_or("artifacts", "artifacts"),
        port: p.u64_or("port", 8080)? as u16,
        instances: p.usize_or("instances", 2)?,
        ttft_slo: p.f64_or("ttft-slo", 2.0)?,
        tpot_slo: p.f64_or("tpot-slo", 0.5)?,
        // Destructive /admin/* membership endpoints stay disabled unless
        // the operator provides a shared secret.
        admin_token: std::env::var("ARROW_ADMIN_TOKEN").ok(),
        // Graceful degradation knobs (PR 6): queue-depth admission and
        // the per-request deadline (old behavior was a fixed 120 s hang).
        max_inflight: p.usize_or("max-inflight", 256)?,
        request_deadline_s: p.f64_or("deadline", 120.0)?,
    };
    arrow::server::serve(cfg)?;
    Ok(())
}

fn cmd_calibrate(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["artifacts"])?;
    let dir = p.str_or("artifacts", "artifacts");
    let report = arrow::runtime::calibrate(&dir)?;
    println!("{report}");
    Ok(())
}

fn cmd_traces(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["out", "seed"])?;
    let out = p.str_or("out", "results/traces");
    let seed = p.u64_or("seed", 1)?;
    std::fs::create_dir_all(&out)?;
    for w in catalog::table1() {
        let t = w.generate(seed);
        let path = std::path::Path::new(&out).join(format!("{}.jsonl", w.name()));
        arrow::trace::io::save_jsonl(&t, &path)?;
        println!("wrote {} ({} requests)", path.display(), t.len());
    }
    Ok(())
}

fn cmd_info() -> Result<(), Box<dyn std::error::Error>> {
    println!(
        "arrow-serve {} — Arrow paper reproduction",
        env!("CARGO_PKG_VERSION")
    );
    println!(
        "systems: {}",
        System::all().map(|s| s.label()).join(", ")
    );
    println!("workloads: {}", catalog::names().join(", "));
    Ok(())
}
