//! `arrow` — CLI for the Arrow reproduction.
//!
//! Subcommands:
//!   figures <table1|fig1|fig2|fig4|fig7|fig8|fig9|all>   regenerate paper tables/figures
//!   claims [--smoke]                                       paper-claims conformance sweep
//!   chaos [--smoke]                                        seeded fault-plan robustness sweep
//!   replay --system S --workload W --rate-mult M          one simulated run
//!   replay <journal> [--verify] [--sim]                    flight-recorder journal replay
//!   replay --record-demo PATH [--seed N]                   record a demo journal offline
//!   loadgen [--rps R] [--duration S] [--self-test]        open-loop soak against /v1/completions
//!   serve --artifacts DIR [--port P] [--instances N]      real-mode HTTP serving (PJRT)
//!   calibrate --artifacts DIR                              profile PJRT executables, fit cost model
//!   traces [--out DIR]                                     dump synthetic traces as JSONL
//!   info                                                   version + scenario summary

use arrow::cli;
use arrow::figures::{self, FigOpts};
use arrow::scenarios::System;
use arrow::trace::catalog;

fn usage() -> ! {
    eprintln!(
        "usage: arrow <subcommand> [flags]

subcommands:
  figures <table1|fig1|fig2|fig4|fig7|fig8|fig9|all>
          [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N] [--target FRAC]
  claims  [--smoke] [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N] [--target FRAC]
          (normalized-cost-model conformance sweep over all eight systems —
           the paper's six plus the PR-10 adversaries deflect/unified;
           exits non-zero when a paper claim fails;
           ARROW_CLAIMS_SMOKE=1 implies --smoke)
  chaos   [--smoke] [--seed N] [--clip SECONDS] [--gpus N] [--out DIR]
          [--workers N]
          (goodput vs seeded fault intensity; exits non-zero when a chaos
           invariant fails — e.g. a silently lost request;
           ARROW_CHAOS_SMOKE=1 implies --smoke)
  replay  --system <arrow|vllm|vllm-disagg|distserve|minimal-load|round-robin|deflect|unified>
          --workload <azure_code|azure_conv|burstgpt|mooncake_conv|smoke>
          [--rate-mult M] [--seed N] [--clip SECONDS] [--gpus N]
  replay  <journal.arwj> [--verify] [--sim] [--max-reported N]
          (flight-recorder mode: re-derive every recorded scheduling
           decision through the journalled policy and compare placements,
           pool states and flip counts byte-for-byte; exits non-zero on
           any divergence. --sim additionally re-derives each decision
           through the simulator substrate as an independent oracle)
  replay  --record-demo PATH [--seed N] [--steps N] [--engines N]
          [--policy <arrow-slo-aware|all-to-one|static-split>]
          [--no-membership]
          (record a deterministic demo journal without a live server —
           the same bytes for the same flags, every run)
  loadgen [--url http://HOST:PORT] [--rps R] [--duration SECONDS]
          [--seed N] [--workers N] [--mix I,S,B] [--ttft-slo S]
          [--tpot-slo S] [--out BENCH_server.json] [--smoke] [--self-test]
          (open-loop Poisson soak against /v1/completions: every sent
           request is accounted ok/shed/deadline/client-err/conn-err —
           exits non-zero on silent loss; --self-test runs against an
           in-process stub server, no live cluster needed)
  serve   [--artifacts DIR] [--port P] [--instances N] [--ttft-slo S] [--tpot-slo S]
          [--max-inflight N] [--deadline SECONDS] [--journal PATH]
  calibrate [--artifacts DIR]
  traces  [--out DIR] [--seed N]
  info"
    );
    std::process::exit(2)
}

fn fig_opts(p: &cli::ParsedArgs) -> Result<FigOpts, cli::CliError> {
    let mut o = FigOpts::default();
    o.seed = p.u64_or("seed", o.seed)?;
    o.clip_seconds = p.f64_or("clip", o.clip_seconds)?;
    o.gpus = p.usize_or("gpus", o.gpus)?;
    o.out_dir = p.str_or("out", &o.out_dir);
    o.workers = p.usize_or("workers", o.workers)?;
    o.target = p.f64_or("target", o.target)?;
    Ok(o)
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        usage();
    }
    let p = cli::parse(&raw);
    let sub = p.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match sub {
        "figures" => cmd_figures(&p),
        "claims" => cmd_claims(&p),
        "chaos" => cmd_chaos(&p),
        "replay" => cmd_replay(&p),
        "loadgen" => cmd_loadgen(&p),
        "serve" => cmd_serve(&p),
        "calibrate" => cmd_calibrate(&p),
        "traces" => cmd_traces(&p),
        "info" => cmd_info(),
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_figures(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target"])?;
    let opts = fig_opts(p)?;
    let which = p.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    match which {
        "table1" => figures::table1(&opts),
        "fig1" => figures::fig1(&opts),
        "fig2" => figures::fig2(&opts),
        "fig4" => figures::fig4(&opts),
        "fig7" => figures::fig7(&opts),
        "fig8" => figures::fig8(&opts),
        "fig9" => figures::fig9(&opts),
        "all" => figures::all(&opts),
        other => {
            return Err(format!("unknown figure '{other}'").into());
        }
    }
    Ok(())
}

fn cmd_claims(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target", "smoke"])?;
    let mut opts = fig_opts(p)?;
    // The claims contract is keyed to its own fixed seed (tests and CI
    // use 42), not the figures default; --seed still overrides.
    opts.seed = p.u64_or("seed", 42)?;
    let smoke = p.has("smoke") || arrow::harness::smoke_env();
    if figures::claims(&opts, smoke) {
        Ok(())
    } else {
        Err(format!(
            "paper-claims conformance FAILED (see verdicts above; \
             {}/claims.json has the full report)",
            opts.out_dir
        )
        .into())
    }
}

fn cmd_chaos(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["seed", "clip", "gpus", "out", "workers", "target", "smoke"])?;
    let mut opts = fig_opts(p)?;
    // Like claims, the chaos contract is keyed to its own fixed seed;
    // --seed still overrides for exploratory sweeps.
    opts.seed = p.u64_or("seed", 42)?;
    let smoke = p.has("smoke") || arrow::harness::chaos::smoke_env();
    if figures::chaos(&opts, smoke) {
        Ok(())
    } else {
        Err(format!(
            "chaos conformance FAILED (see verdicts above; \
             {}/chaos.json has the full report)",
            opts.out_dir
        )
        .into())
    }
}

fn cmd_replay(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    // Three modes share the subcommand: flight-recorder demo recording,
    // flight-recorder journal verification (a positional journal path
    // selects it), and the legacy simulated-run replay.
    if p.has("record-demo") {
        return cmd_replay_record_demo(p);
    }
    if p.positional.get(1).is_some() {
        return cmd_replay_verify(p);
    }
    p.check_known(&["system", "workload", "rate-mult", "seed", "clip", "gpus"])?;
    let sys = System::by_label(&p.str_or("system", "arrow")).ok_or("unknown --system")?;
    let workload = p.str_or("workload", "smoke");
    let mult = p.f64_or("rate-mult", 1.0)?;
    let opts = fig_opts(p)?;
    print!("{}", figures::replay(sys, &workload, mult, &opts));
    Ok(())
}

fn cmd_replay_record_demo(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&[
        "record-demo",
        "seed",
        "steps",
        "engines",
        "policy",
        "no-membership",
    ])?;
    let path = p.str_or("record-demo", "");
    if path.is_empty() || path == "true" {
        return Err("--record-demo needs a journal path (--record-demo out.arwj)".into());
    }
    let mut cfg = arrow::replay::demo::DemoConfig::default();
    cfg.seed = p.u64_or("seed", cfg.seed)?;
    cfg.steps = p.u64_or("steps", cfg.steps)?;
    cfg.engines = p.usize_or("engines", cfg.engines)?;
    cfg.policy = p.str_or("policy", &cfg.policy);
    cfg.membership = !p.has("no-membership");
    let events = arrow::replay::demo::record_demo(std::path::Path::new(&path), &cfg)?;
    println!(
        "recorded {events} decision events to {path} (seed {}, {} engines, policy {})",
        cfg.seed, cfg.engines, cfg.policy
    );
    Ok(())
}

fn cmd_replay_verify(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["verify", "sim", "max-reported"])?;
    let journal = p.positional.get(1).cloned().ok_or("missing journal path")?;
    let opts = arrow::replay::verify::VerifyOptions {
        sim_oracle: p.has("sim"),
        max_reported: p.usize_or("max-reported", 16)?,
    };
    let report =
        arrow::replay::verify::verify_journal(std::path::Path::new(&journal), &opts)?;
    println!(
        "journal {journal}: policy {}, {} records",
        report.policy, report.records
    );
    println!(
        "  server oracle: {} re-derived, {} divergence(s)",
        report.verified, report.divergences
    );
    if opts.sim_oracle {
        println!(
            "  sim oracle:    {} re-derived, {} skipped (sim-unrepresentable)",
            report.sim_verified, report.sim_skipped
        );
    }
    if report.dropped > 0 {
        println!(
            "  {} record(s) dropped under backpressure while recording",
            report.dropped
        );
    }
    if let Some(g) = &report.stopped_at_gap {
        println!("  {g}");
    }
    if let Some(t) = &report.torn {
        println!(
            "  torn tail: journal truncated at byte {} ({}); intact prefix replayed",
            t.offset, t.reason
        );
    }
    for d in &report.detail {
        println!("  DIVERGENCE {d}");
    }
    if report.ok() {
        println!("replay OK: every re-derived decision matches the record");
        Ok(())
    } else {
        Err(format!(
            "replay FAILED: {} divergence(s) between the journal and the \
             re-derived schedule",
            report.divergences
        )
        .into())
    }
}

fn cmd_loadgen(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&[
        "url",
        "rps",
        "duration",
        "seed",
        "workers",
        "mix",
        "ttft-slo",
        "tpot-slo",
        "out",
        "smoke",
        "self-test",
    ])?;
    let mut cfg = arrow::harness::loadgen::LoadgenConfig::default();
    cfg.url = p.str_or("url", &cfg.url);
    cfg.rps = p.f64_or("rps", cfg.rps)?;
    cfg.duration_s = p.f64_or("duration", cfg.duration_s)?;
    cfg.seed = p.u64_or("seed", cfg.seed)?;
    cfg.workers = p.usize_or("workers", cfg.workers)?;
    cfg.ttft_slo = p.f64_or("ttft-slo", cfg.ttft_slo)?;
    cfg.tpot_slo = p.f64_or("tpot-slo", cfg.tpot_slo)?;
    cfg.out = p.flag("out").map(String::from);
    cfg.smoke = p.has("smoke");
    cfg.self_test = p.has("self-test");
    if let Some(mix) = p.flag("mix") {
        let parts: Vec<f64> = mix
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|_| "--mix expects three comma-separated weights, e.g. 0.5,0.4,0.1")?;
        if parts.len() != 3 || parts.iter().any(|w| !w.is_finite() || *w < 0.0) {
            return Err("--mix expects three non-negative weights (interactive,standard,batch)"
                .into());
        }
        cfg.class_mix = [parts[0], parts[1], parts[2]];
    }
    let report = arrow::harness::loadgen::run(&cfg)?;
    print!("{}", report.render());
    if report.ok() {
        Ok(())
    } else {
        Err("loadgen FAILED (see ledger above)".into())
    }
}

fn cmd_serve(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&[
        "artifacts",
        "port",
        "instances",
        "ttft-slo",
        "tpot-slo",
        "max-inflight",
        "deadline",
        "journal",
    ])?;
    let cfg = arrow::server::ServeConfig {
        artifacts_dir: p.str_or("artifacts", "artifacts"),
        port: p.u64_or("port", 8080)? as u16,
        instances: p.usize_or("instances", 2)?,
        ttft_slo: p.f64_or("ttft-slo", 2.0)?,
        tpot_slo: p.f64_or("tpot-slo", 0.5)?,
        // Destructive /admin/* membership endpoints stay disabled unless
        // the operator provides a shared secret.
        admin_token: std::env::var("ARROW_ADMIN_TOKEN").ok(),
        // Graceful degradation knobs (PR 6): queue-depth admission and
        // the per-request deadline (old behavior was a fixed 120 s hang).
        max_inflight: p.usize_or("max-inflight", 256)?,
        request_deadline_s: p.f64_or("deadline", 120.0)?,
        // Flight recorder (PR 9): journal every scheduling decision for
        // deterministic offline replay via `arrow replay <journal>`.
        journal_path: p.flag("journal").map(String::from),
    };
    arrow::server::serve(cfg)?;
    Ok(())
}

fn cmd_calibrate(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["artifacts"])?;
    let dir = p.str_or("artifacts", "artifacts");
    let report = arrow::runtime::calibrate(&dir)?;
    println!("{report}");
    Ok(())
}

fn cmd_traces(p: &cli::ParsedArgs) -> Result<(), Box<dyn std::error::Error>> {
    p.check_known(&["out", "seed"])?;
    let out = p.str_or("out", "results/traces");
    let seed = p.u64_or("seed", 1)?;
    std::fs::create_dir_all(&out)?;
    for w in catalog::table1() {
        let t = w.generate(seed);
        let path = std::path::Path::new(&out).join(format!("{}.jsonl", w.name()));
        arrow::trace::io::save_jsonl(&t, &path)?;
        println!("wrote {} ({} requests)", path.display(), t.len());
    }
    Ok(())
}

fn cmd_info() -> Result<(), Box<dyn std::error::Error>> {
    println!(
        "arrow-serve {} — Arrow paper reproduction",
        env!("CARGO_PKG_VERSION")
    );
    println!(
        "systems: {}",
        System::all().map(|s| s.label()).join(", ")
    );
    println!("workloads: {}", catalog::names().join(", "));
    Ok(())
}
