//! Minimal JSON codec (substrate for the unavailable `serde_json`).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null) with precise error positions. Used for
//! `artifacts/model_config.json` / `weights_manifest.json`, trace files,
//! the HTTP API, and figure output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers for config parsing.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key).as_f64().ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing/invalid number field '{key}'"),
        })
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key).as_u64().ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing/invalid integer field '{key}'"),
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key).as_str().ok_or_else(|| JsonError {
            pos: 0,
            msg: format!("missing/invalid string field '{key}'"),
        })
    }

    // -------------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // -------------------------------------------------------------- encode

    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -------------------------------------------------------------- decode

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN/Inf
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(src).unwrap();
            let re = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, re, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("d"), &Json::Bool(true));
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\cA\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\cA\n");
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 世界");
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert_eq!(e.pos, 6);
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("[1] junk").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers_precise() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(Json::parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(Json::parse("2.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e2").unwrap().as_f64(), Some(100.0));
    }

    #[test]
    fn integer_encoding_no_decimal_point() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
    }

    #[test]
    fn nan_encodes_as_null() {
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
    }

    #[test]
    fn obj_get_missing_is_null() {
        let v = Json::parse("{}").unwrap();
        assert_eq!(v.get("nope"), &Json::Null);
        assert!(v.req_str("nope").is_err());
    }

    #[test]
    fn deterministic_object_order() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap().encode();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap().encode();
        assert_eq!(a, b);
    }

    #[test]
    fn parse_real_manifest_shape() {
        let src = r#"{"dtype":"f32le","total_bytes":8,"tensors":[
            {"name":"embed","shape":[2,1],"offset_bytes":0,"size_bytes":8}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req_str("dtype").unwrap(), "f32le");
        let t = &v.get("tensors").as_arr().unwrap()[0];
        assert_eq!(t.req_u64("offset_bytes").unwrap(), 0);
        assert_eq!(
            t.get("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_u64().unwrap())
                .collect::<Vec<_>>(),
            vec![2, 1]
        );
    }

    // Property: parse(encode(v)) == v for random JSON trees.
    #[test]
    fn prop_roundtrip_random_trees() {
        use crate::util::{prop, rng::Rng};

        fn gen(rng: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { rng.index(4) } else { rng.index(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bool(0.5)),
                2 => Json::Num((rng.int_range(-1_000_000, 1_000_000) as f64)
                    / if rng.bool(0.5) { 1.0 } else { 8.0 }),
                3 => {
                    let n = rng.index(8);
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                char::from_u32(rng.int_range(32, 0x2FFF) as u32)
                                    .unwrap_or('x')
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr((0..rng.index(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.index(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }

        prop::check_with(99, 300, |rng| {
            let v = gen(rng, 3);
            let enc = v.encode();
            let back = Json::parse(&enc)
                .map_err(|e| format!("parse failed: {e} on {enc}"))?;
            crate::prop_assert!(back == v, "roundtrip mismatch: {enc}");
            Ok(())
        });
    }
}
