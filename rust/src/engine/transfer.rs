//! KV-cache migration between instances (paper Fig. 3: q2 + c).
//!
//! The decode instance *pulls* KV from the prefill instance once it has
//! memory for it (paper §5.2 step e). Per-source-instance transfers are
//! serialized FCFS (one NVLink/NIC channel per instance), which produces
//! exactly the unpredictable q2 queueing the paper analyzes in §4.3.
//!
//! `TransferFabric` also models the vLLM-disaggregated baseline's limited
//! KV transfer buffer: when `buffer_cap_tokens` is finite, transfers whose
//! KV exceeds the free buffer wait, and requests that wait longer than
//! `fail_timeout` fail — mirroring the buffer-overflow issue the paper had
//! to work around in vLLM v0.7.3 (§7.1).

use std::collections::VecDeque;
use std::sync::Arc;

use crate::costmodel::CostModel;
use crate::request::{InstanceId, RequestId, Time};

/// A pending KV migration.
#[derive(Debug, Clone, PartialEq)]
pub struct Transfer {
    pub req: RequestId,
    pub from: InstanceId,
    pub to: InstanceId,
    pub kv_tokens: u32,
    /// When the migration was requested (for q2 accounting / timeouts).
    pub requested_at: Time,
}

/// Outcome of `poll`: transfers that can start now, with their duration.
#[derive(Debug, Clone, PartialEq)]
pub struct StartedTransfer {
    pub transfer: Transfer,
    pub completes_at: Time,
}

/// Serialized per-source transfer channels + optional shared buffer cap.
#[derive(Debug)]
pub struct TransferFabric {
    /// Transfer timing — shared (refcounted) with the cluster's instances
    /// so polling never clones a cost model.
    cost: Arc<CostModel>,
    /// Per-source channel busy-until times.
    busy_until: Vec<Time>,
    /// Waiting transfers per source (FCFS).
    queues: Vec<VecDeque<Transfer>>,
    /// Shared in-flight token budget (None = unlimited).
    pub buffer_cap_tokens: Option<u64>,
    in_flight_tokens: u64,
    /// Requests whose transfer waited longer than this fail (None = never).
    pub fail_timeout: Option<f64>,
    /// Per-source link flap horizon (PR 6 fault plane): while
    /// `now < flap_until[src]` the channel out of `src` is down — nothing
    /// starts, and waiting transfers can still time out.
    flap_until: Vec<Time>,
    /// When true (retry mode), `next_wakeup` also wakes at timeout
    /// deadlines and flap-window ends, so a blocked transfer is
    /// guaranteed a poll that fails it into the retry path. Off by
    /// default: legacy scenarios must keep their exact event schedules.
    pub timeout_wakeups: bool,
}

impl TransferFabric {
    pub fn new(n_instances: usize, cost: Arc<CostModel>) -> Self {
        TransferFabric {
            cost,
            busy_until: vec![0.0; n_instances],
            queues: (0..n_instances).map(|_| VecDeque::new()).collect(),
            buffer_cap_tokens: None,
            in_flight_tokens: 0,
            fail_timeout: None,
            flap_until: vec![0.0; n_instances],
            timeout_wakeups: false,
        }
    }

    /// Queue a migration request.
    pub fn request(&mut self, t: Transfer) {
        self.queues[t.from.0].push_back(t);
    }

    /// Take the link out of `src` down until `until` (max-merged with any
    /// flap already in effect). Injected by `FaultKind::TransferFlap`.
    pub fn flap_link(&mut self, src: usize, until: Time) {
        self.flap_until[src] = self.flap_until[src].max(until);
    }

    /// Try to start queued transfers at time `now`. Returns started
    /// transfers (caller schedules their completion events) and failed
    /// transfers (timed out waiting for buffer or a downed link) — the
    /// full `Transfer` comes back so the caller can retry the same route
    /// with backoff instead of giving up.
    pub fn poll(&mut self, now: Time) -> (Vec<StartedTransfer>, Vec<Transfer>) {
        let mut started = Vec::new();
        let mut failed = Vec::new();
        for src in 0..self.queues.len() {
            // Channel free?
            while let Some(head) = self.queues[src].front() {
                if self.busy_until[src] > now {
                    break;
                }
                // Downed link: nothing starts; waiters can still time out
                // into the retry path.
                if self.flap_until[src] > now {
                    if let Some(to) = self.fail_timeout {
                        if now - head.requested_at > to {
                            failed.push(self.queues[src].pop_front().unwrap());
                            continue;
                        }
                    }
                    break;
                }
                // Buffer admission.
                if let Some(cap) = self.buffer_cap_tokens {
                    if self.in_flight_tokens + head.kv_tokens as u64 > cap {
                        if let Some(to) = self.fail_timeout {
                            if now - head.requested_at > to {
                                failed.push(self.queues[src].pop_front().unwrap());
                                continue;
                            }
                        }
                        break;
                    }
                }
                let t = self.queues[src].pop_front().unwrap();
                let dur = self.cost.transfer_time(t.kv_tokens as u64);
                self.busy_until[src] = now + dur;
                self.in_flight_tokens += t.kv_tokens as u64;
                started.push(StartedTransfer {
                    completes_at: now + dur,
                    transfer: t,
                });
            }
        }
        (started, failed)
    }

    /// A transfer finished; release its buffer tokens.
    pub fn complete(&mut self, kv_tokens: u32) {
        self.in_flight_tokens = self.in_flight_tokens.saturating_sub(kv_tokens as u64);
    }

    /// Earliest future time at which a queued transfer could start
    /// (drives re-poll event scheduling). None if nothing queued.
    pub fn next_wakeup(&self) -> Option<Time> {
        let mut t: Option<Time> = None;
        for (src, q) in self.queues.iter().enumerate() {
            if !q.is_empty() {
                let cand = self.busy_until[src];
                t = Some(t.map_or(cand, |x: f64| x.min(cand)));
            }
        }
        t
    }

    /// Retry-mode wakeup (`timeout_wakeups`): earliest time strictly
    /// after `now` at which a queued transfer could start *or* time out —
    /// channel-free, flap-window end, and `fail_timeout` deadlines all
    /// count. This guarantees a transfer stuck behind a downed link or a
    /// full buffer gets a poll that fails it into the retry path, instead
    /// of waiting for an unrelated event.
    pub fn next_wakeup_after(&self, now: Time) -> Option<Time> {
        let mut t: Option<Time> = None;
        let mut consider = |cand: Time, t: &mut Option<Time>| {
            if cand > now {
                *t = Some(t.map_or(cand, |x: f64| x.min(cand)));
            }
        };
        for (src, q) in self.queues.iter().enumerate() {
            if let Some(head) = q.front() {
                consider(self.busy_until[src].max(self.flap_until[src]), &mut t);
                if let Some(to) = self.fail_timeout {
                    // Nudge past the deadline: poll fails on *strictly*
                    // exceeded timeouts, so a wakeup exactly at the
                    // deadline would poll, fail nothing, and re-arm at
                    // the same instant forever.
                    consider(head.requested_at + to + 1e-9, &mut t);
                }
            }
        }
        t
    }

    pub fn queued(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(n: usize) -> TransferFabric {
        TransferFabric::new(n, Arc::new(CostModel::h800_llama8b()))
    }

    fn t(req: u64, from: usize, to: usize, kv: u32, at: f64) -> Transfer {
        Transfer {
            req: RequestId(req),
            from: InstanceId(from),
            to: InstanceId(to),
            kv_tokens: kv,
            requested_at: at,
        }
    }

    #[test]
    fn transfer_starts_immediately_when_free() {
        let mut f = fabric(2);
        f.request(t(1, 0, 1, 1000, 0.0));
        let (started, failed) = f.poll(0.0);
        assert_eq!(started.len(), 1);
        assert!(failed.is_empty());
        assert!(started[0].completes_at > 0.0);
    }

    #[test]
    fn same_source_serializes_fcfs() {
        let mut f = fabric(2);
        f.request(t(1, 0, 1, 1000, 0.0));
        f.request(t(2, 0, 1, 1000, 0.0));
        let (started, _) = f.poll(0.0);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].transfer.req, RequestId(1));
        // Second starts only after the channel frees.
        let free_at = started[0].completes_at;
        let (none, _) = f.poll(free_at - 1e-9);
        assert!(none.is_empty());
        assert_eq!(f.next_wakeup(), Some(free_at));
        f.complete(1000);
        let (second, _) = f.poll(free_at);
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].transfer.req, RequestId(2));
    }

    #[test]
    fn different_sources_parallel() {
        let mut f = fabric(3);
        f.request(t(1, 0, 2, 1000, 0.0));
        f.request(t(2, 1, 2, 1000, 0.0));
        let (started, _) = f.poll(0.0);
        assert_eq!(started.len(), 2);
    }

    #[test]
    fn buffer_cap_blocks_and_timeout_fails() {
        let mut f = fabric(2);
        f.buffer_cap_tokens = Some(1500);
        f.fail_timeout = Some(10.0);
        f.request(t(1, 0, 1, 1000, 0.0));
        let (s1, _) = f.poll(0.0);
        assert_eq!(s1.len(), 1);
        // Second transfer (from the other source so the channel is free)
        // exceeds the shared buffer.
        f.request(t(2, 1, 0, 1000, 0.0));
        let (s2, f2) = f.poll(1.0);
        assert!(s2.is_empty() && f2.is_empty());
        // After the timeout it fails — the full route comes back so the
        // caller can retry it.
        let (s3, f3) = f.poll(12.0);
        assert!(s3.is_empty());
        assert_eq!(f3.len(), 1);
        assert_eq!(f3[0].req, RequestId(2));
        assert_eq!(f3[0].from, InstanceId(1));
        assert_eq!(f3[0].kv_tokens, 1000);
        // Releasing the buffer lets new transfers in.
        f.complete(1000);
        f.request(t(3, 1, 0, 1000, 12.0));
        let (s4, _) = f.poll(12.0);
        assert_eq!(s4.len(), 1);
    }

    #[test]
    fn next_wakeup_none_when_empty() {
        let f = fabric(2);
        assert_eq!(f.next_wakeup(), None);
        assert_eq!(f.next_wakeup_after(0.0), None);
    }

    #[test]
    fn flapped_link_blocks_then_recovers() {
        let mut f = fabric(2);
        f.flap_link(0, 10.0);
        f.request(t(1, 0, 1, 1000, 0.0));
        let (s, fl) = f.poll(5.0);
        assert!(s.is_empty() && fl.is_empty(), "downed link starts nothing");
        // The other source is unaffected.
        f.request(t(2, 1, 0, 1000, 5.0));
        let (s, _) = f.poll(5.0);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].transfer.req, RequestId(2));
        // Once the flap clears, the blocked transfer starts.
        let (s, _) = f.poll(10.0);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].transfer.req, RequestId(1));
        // Flaps max-merge: extending backwards never shortens.
        f.flap_link(0, 20.0);
        f.flap_link(0, 15.0);
        f.request(t(3, 0, 1, 1000, 10.0));
        f.complete(1000);
        f.complete(1000);
        let (s, _) = f.poll(19.0);
        assert!(s.is_empty(), "flap horizon is the max of all flaps");
    }

    #[test]
    fn flapped_link_times_out_waiters_into_retry_path() {
        let mut f = fabric(2);
        f.fail_timeout = Some(3.0);
        f.timeout_wakeups = true;
        f.flap_link(0, 100.0);
        f.request(t(1, 0, 1, 1000, 0.0));
        // Wakeup covers the timeout deadline, not just the (past) channel
        // free time.
        let w = f.next_wakeup_after(0.0).unwrap();
        assert!(w > 3.0 && w < 3.1, "deadline wakeup, got {w}");
        let (s, fl) = f.poll(w);
        assert!(s.is_empty());
        assert_eq!(fl.len(), 1);
        assert_eq!(fl[0].req, RequestId(1));
        assert_eq!(f.next_wakeup_after(w), None, "queue drained");
    }
}
