//! Per-instance serving-engine substrate: task state, the stateless
//! instance with its local scheduler (continuous batching + chunked
//! prefill), and the KV-cache transfer fabric.

pub mod instance;
pub mod task;
pub mod transfer;

pub use instance::{IterationPlan, Produced, SimInstance, DEFAULT_CHUNK_TOKENS};
pub use task::{DecodeTask, PrefillTask};
pub use transfer::{StartedTransfer, Transfer, TransferFabric};
