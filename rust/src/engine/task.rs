//! Sub-request task state held by an instance's local scheduler.
//!
//! A request is split into a prefill task and a decode task (paper §5.2:
//! "each request is split into prefill and decode sub-requests, which can
//! be scheduled independently").

use crate::request::RequestId;

/// A prefill sub-request progressing chunk by chunk (chunked prefill,
/// Sarathi-style — paper §5.4).
#[derive(Debug, Clone)]
pub struct PrefillTask {
    pub id: RequestId,
    pub input_len: u32,
    /// Prompt tokens already prefilled.
    pub done: u32,
    /// Queue priority (PR 8): lower ranks are dequeued first; equal ranks
    /// keep FIFO order. Defaults to 0 — a single-rank queue behaves
    /// exactly like the plain FIFO it used to be.
    pub rank: u8,
}

impl PrefillTask {
    pub fn new(id: RequestId, input_len: u32) -> Self {
        PrefillTask {
            id,
            input_len,
            done: 0,
            rank: 0,
        }
    }

    pub fn remaining(&self) -> u32 {
        self.input_len - self.done
    }

    pub fn finished(&self) -> bool {
        self.done >= self.input_len
    }
}

/// A decode sub-request resident in an instance's batch or wait queue.
#[derive(Debug, Clone)]
pub struct DecodeTask {
    pub id: RequestId,
    /// KV tokens currently held by this request (prompt + generated).
    pub ctx: u32,
    /// Output tokens still to produce (first token was produced by the
    /// prefill phase).
    pub remaining: u32,
}

impl DecodeTask {
    pub fn new(id: RequestId, ctx: u32, remaining: u32) -> Self {
        DecodeTask {
            id,
            ctx,
            remaining,
        }
    }

    pub fn finished(&self) -> bool {
        self.remaining == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_progress() {
        let mut t = PrefillTask::new(RequestId(1), 100);
        assert_eq!(t.remaining(), 100);
        assert!(!t.finished());
        t.done += 60;
        assert_eq!(t.remaining(), 40);
        t.done += 40;
        assert!(t.finished());
    }

    #[test]
    fn decode_progress() {
        let mut t = DecodeTask::new(RequestId(2), 50, 3);
        assert!(!t.finished());
        t.remaining -= 3;
        t.ctx += 3;
        assert!(t.finished());
        assert_eq!(t.ctx, 53);
    }
}
