//! A stateless serving instance: local scheduler + KV accounting.
//!
//! "Stateless" in the paper's sense (§5.2): the instance has no prefill or
//! decode *role* — it processes whatever sub-requests the global scheduler
//! dispatched to it. The local scheduler (paper §5.4) batches decode
//! requests first (decode-priority), then fills the remaining token budget
//! with a chunk of the head prefill request (chunked prefill), so an
//! instance freshly flipped into a new pool starts the new work type on
//! the very next iteration — zero flip wait.
//!
//! Timing is supplied by the caller-visible [`CostModel`]; the simulator
//! schedules an `IterComplete` event at `now + iter.duration` and feeds
//! the completion back into [`SimInstance::finish_iteration`].

use std::collections::VecDeque;
use std::sync::Arc;

use super::task::{DecodeTask, PrefillTask};
use crate::costmodel::CostModel;
use crate::request::{InstanceId, RequestId};
use crate::sched::{Liveness, PrefillQueueMoments};
use crate::util::stats::SlidingWindow;

/// Chunked-prefill token budget per iteration (Sarathi-style default;
/// canonical value lives in the sched layer, which defines the default
/// view contract).
pub const DEFAULT_CHUNK_TOKENS: u32 = crate::sched::DEFAULT_CHUNK_TOKENS;

/// Samples kept in the recent token-interval window (instance monitor).
const INTERVAL_WINDOW: usize = 64;

/// What one iteration will execute (computed by `plan_iteration`).
#[derive(Debug, Clone, PartialEq)]
pub struct IterationPlan {
    /// Decode requests included (all admitted running tasks).
    pub decode_reqs: usize,
    /// Total KV tokens across included decode tasks (after +1 growth).
    pub decode_tokens: u64,
    /// Prefill chunk tokens for the head prefill task (0 = none).
    pub chunk: u32,
    /// Attention context at the end of that chunk.
    pub chunk_ctx: u32,
    /// Iteration wall/simulated duration in seconds.
    pub duration: f64,
}

/// Events an iteration completion produces, for the cluster to act on.
#[derive(Debug, Clone, PartialEq)]
pub enum Produced {
    /// A decode task emitted one token (not its last).
    Token { id: RequestId },
    /// A decode task emitted its final token and left the instance.
    FinalToken { id: RequestId, freed_kv: u64 },
    /// The head prefill task finished: first token available; KV of
    /// `kv_tokens` is resident here awaiting decode placement/migration.
    PrefillDone { id: RequestId, kv_tokens: u32 },
}

/// One stateless instance.
#[derive(Debug)]
pub struct SimInstance {
    pub id: InstanceId,
    /// Shared with the cluster and transfer fabric: homogeneous clusters
    /// hold one `CostModel` behind n+1 refcounts instead of n+1 deep
    /// clones. Use [`SimInstance::cost_mut`] for per-instance overrides
    /// (copy-on-write, unshares only this instance).
    pub cost: Arc<CostModel>,
    /// Token budget for the prefill chunk per iteration.
    pub chunk_tokens: u32,
    /// Optional per-iteration latency budget (seconds). When set and the
    /// batch mixes decode tasks with a prefill chunk, the chunk is shrunk
    /// so the whole iteration fits the budget — an SLO-aware refinement of
    /// Sarathi-style chunking that protects co-resident decodes' TPOT on
    /// P→D / D→P instances. Pure-prefill iterations ignore it.
    pub iter_time_budget: Option<f64>,
    // --- local queues (paper Fig. 5 IV) ---
    prefill_q: VecDeque<PrefillTask>,
    /// Decode tasks currently in the running batch.
    running: Vec<DecodeTask>,
    /// Decode tasks admitted to the instance but parked (batch/memory cap).
    decode_wait: VecDeque<DecodeTask>,
    // --- KV accounting ---
    /// Tokens of KV resident: decode ctx + completed prefill chunks +
    /// parked prefill KV awaiting migration + reserved incoming transfers.
    kv_used: u64,
    /// KV held by finished prefills awaiting migration (subset of kv_used).
    parked_prefill_kv: u64,
    // --- O(1) scheduler aggregates (PR 4: updated at event time, never
    // recomputed on the placement path) ---
    /// Prefill-queue moments, maintained on enqueue / chunk advance /
    /// completion. `chunk_tokens` must therefore be fixed before the
    /// first enqueue — the aggregates (and the fitted predictor) price
    /// iterations with it.
    prefill_moments: PrefillQueueMoments,
    /// Σ ctx over running + waiting decode tasks (the paper's "running
    /// tokens" metric, §5.3), maintained on enqueue/adopt/token/finish.
    running_tokens_agg: u64,
    // --- monitor statistics (paper Fig. 5 VI) ---
    /// Recent per-token generation intervals (seconds).
    intervals: SlidingWindow,
    /// Time of the last produced decode token (for interval measurement).
    last_token_time: Option<f64>,
    /// Whether an iteration is currently in flight.
    pub busy: bool,
    /// Monotone counter of iterations executed (perf/debug).
    pub iterations: u64,
    /// Cluster-membership state (PR 3 elastic membership). The event
    /// loop owns transitions; the instance itself behaves identically in
    /// every state — "stateless" extends to liveness: a draining
    /// instance keeps executing whatever it still holds.
    pub life: Liveness,
}

impl SimInstance {
    pub fn new(id: InstanceId, cost: impl Into<Arc<CostModel>>) -> Self {
        SimInstance {
            id,
            cost: cost.into(),
            chunk_tokens: DEFAULT_CHUNK_TOKENS,
            iter_time_budget: None,
            prefill_q: VecDeque::new(),
            running: Vec::new(),
            decode_wait: VecDeque::new(),
            kv_used: 0,
            parked_prefill_kv: 0,
            prefill_moments: PrefillQueueMoments::default(),
            running_tokens_agg: 0,
            intervals: SlidingWindow::new(INTERVAL_WINDOW),
            last_token_time: None,
            busy: false,
            iterations: 0,
            life: Liveness::Active,
        }
    }

    /// Mutable access to this instance's cost model (copy-on-write: if
    /// the model is shared with other instances it is cloned once, so the
    /// override stays local to this instance).
    pub fn cost_mut(&mut self) -> &mut CostModel {
        Arc::make_mut(&mut self.cost)
    }

    // ------------------------------------------------------------ queries

    pub fn kv_used(&self) -> u64 {
        self.kv_used
    }

    pub fn kv_free(&self) -> u64 {
        self.cost.max_kv_tokens.saturating_sub(self.kv_used)
    }

    /// Total KV tokens of running + waiting decode requests — the paper's
    /// "running tokens" decode-load metric (§5.3). O(1): the aggregate is
    /// maintained at enqueue/adopt/token/finish time; the full fold stays
    /// as the debug-mode oracle.
    pub fn running_tokens(&self) -> u64 {
        debug_assert_eq!(
            self.running_tokens_agg,
            self.running.iter().map(|t| t.ctx as u64).sum::<u64>()
                + self.decode_wait.iter().map(|t| t.ctx as u64).sum::<u64>(),
            "running-tokens aggregate drifted from the task lists"
        );
        self.running_tokens_agg
    }

    /// O(1) prefill-queue moments (PR 4), maintained at event time. The
    /// walk-derived oracle guards the aggregate in debug builds.
    pub fn prefill_queue_moments(&self) -> PrefillQueueMoments {
        #[cfg(debug_assertions)]
        {
            let mut oracle = PrefillQueueMoments::default();
            for (l, r) in self.prefill_queue_iter() {
                oracle.add_task(l, r, self.chunk_tokens);
            }
            debug_assert_eq!(
                self.prefill_moments, oracle,
                "prefill moments drifted from the queue"
            );
        }
        self.prefill_moments
    }

    pub fn decode_req_count(&self) -> usize {
        self.running.len() + self.decode_wait.len()
    }

    pub fn prefill_req_count(&self) -> usize {
        self.prefill_q.len()
    }

    pub fn has_prefill_work(&self) -> bool {
        !self.prefill_q.is_empty()
    }

    pub fn has_decode_work(&self) -> bool {
        !self.running.is_empty() || !self.decode_wait.is_empty()
    }

    pub fn is_idle(&self) -> bool {
        !self.has_prefill_work() && !self.has_decode_work()
    }

    /// (input_len, remaining) of each queued prefill — what the global
    /// scheduler's TTFT predictor consumes (Insight 1).
    ///
    /// Allocates; scheduler hot paths should use
    /// [`SimInstance::prefill_queue_iter`] instead.
    pub fn prefill_queue_view(&self) -> Vec<(u32, u32)> {
        self.prefill_queue_iter().collect()
    }

    /// Allocation-free iterator over the queued prefills' public view.
    pub fn prefill_queue_iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.prefill_q.iter().map(|t| (t.input_len, t.remaining()))
    }

    /// Ground-truth remaining prefill work in seconds (cost-model view;
    /// the *scheduler* must use its fitted predictor instead).
    pub fn prefill_backlog_seconds(&self) -> f64 {
        let mut total = 0.0;
        for t in &self.prefill_q {
            let mut done = t.done;
            while done < t.input_len {
                let c = self.chunk_tokens.min(t.input_len - done);
                total += self.cost.prefill_chunk_time(c, done + c) + self.cost.iter_overhead;
                done += c;
            }
        }
        total
    }

    /// Recent average token generation interval (paper §5.3/§5.5 TPOT
    /// proxy). NaN when the window is empty.
    pub fn avg_token_interval(&self) -> f64 {
        self.intervals.mean()
    }

    /// Seed the monitor with one observed token interval — the replay
    /// oracle's hook (PR 9) for reconstructing a recorded instance whose
    /// `avg_token_interval` was `v`: a single-sample window's mean is
    /// `v / 1.0`, bitwise `v`. Non-finite values (NaN = no evidence) are
    /// represented by leaving the window empty, whose mean is NaN.
    pub fn seed_token_interval(&mut self, v: f64) {
        if v.is_finite() {
            self.intervals.push(v);
        }
    }

    // ------------------------------------------------------------- intake

    /// Accept a prefill sub-request. Caller must have verified capacity.
    pub fn enqueue_prefill(&mut self, id: RequestId, input_len: u32) {
        self.prefill_moments
            .add_task(input_len, input_len, self.chunk_tokens);
        self.prefill_q.push_back(PrefillTask::new(id, input_len));
    }

    /// Rank-aware prefill intake (PR 8): the task is inserted before the
    /// first queued task with a *strictly greater* rank, so lower ranks
    /// (tighter SLO classes) run earlier while equal ranks keep FIFO
    /// order — a single-rank stream produces exactly the push_back queue,
    /// bit for bit. The in-progress head is never displaced: chunked
    /// prefill only ever advances `front()`, and an iteration may be in
    /// flight against it (`busy`), so insertion starts behind a head that
    /// has progress or a pending plan. The queue moments are
    /// position-independent, so ranked insertion leaves them untouched.
    pub fn enqueue_prefill_ranked(&mut self, id: RequestId, input_len: u32, rank: u8) {
        self.prefill_moments
            .add_task(input_len, input_len, self.chunk_tokens);
        let mut task = PrefillTask::new(id, input_len);
        task.rank = rank;
        let protected_head = !self.prefill_q.is_empty()
            && (self.busy || self.prefill_q.front().is_some_and(|t| t.done > 0));
        let start = usize::from(protected_head);
        let pos = (start..self.prefill_q.len())
            .find(|&i| self.prefill_q[i].rank > rank)
            .unwrap_or(self.prefill_q.len());
        self.prefill_q.insert(pos, task);
    }

    /// Reserve KV for an incoming migration (q2 admission check).
    /// Returns false if the instance lacks memory — caller keeps the
    /// request in the transfer wait queue.
    pub fn try_reserve_kv(&mut self, tokens: u64) -> bool {
        if self.kv_free() >= tokens {
            self.kv_used += tokens;
            true
        } else {
            false
        }
    }

    /// Release a reservation (e.g. failed request).
    pub fn release_kv(&mut self, tokens: u64) {
        debug_assert!(self.kv_used >= tokens, "KV underflow");
        self.kv_used = self.kv_used.saturating_sub(tokens);
    }

    /// Accept a decode sub-request whose KV is already resident/reserved.
    pub fn enqueue_decode(&mut self, id: RequestId, ctx: u32, remaining: u32) {
        self.running_tokens_agg += ctx as u64;
        self.decode_wait.push_back(DecodeTask::new(id, ctx, remaining));
    }

    /// Local handoff: the prefill that ran here also decodes here
    /// (no migration; KV simply changes accounting bucket — paper §5.3
    /// "eliminate the overhead of KV Cache transmission").
    pub fn adopt_local_decode(&mut self, id: RequestId, ctx: u32, remaining: u32) {
        debug_assert!(self.parked_prefill_kv >= ctx as u64);
        self.parked_prefill_kv -= ctx as u64;
        self.running_tokens_agg += ctx as u64;
        self.decode_wait.push_back(DecodeTask::new(id, ctx, remaining));
    }

    /// Migration finished: drop the parked prefill KV from this (source)
    /// instance.
    pub fn migration_out_done(&mut self, tokens: u32) {
        debug_assert!(self.parked_prefill_kv >= tokens as u64);
        self.parked_prefill_kv -= tokens as u64;
        self.release_kv(tokens as u64);
    }

    // ---------------------------------------------------------- iteration

    /// Plan the next iteration. Returns None if there is no work.
    ///
    /// Local policy (paper §5.4): decode first — admit waiting decode
    /// tasks while the batch-size cap and memory hold — then one chunk of
    /// the head prefill request if budget remains.
    pub fn plan_iteration(&mut self) -> Option<IterationPlan> {
        let free = self.kv_free();

        // Every running task must grow by one token this iteration; if
        // memory cannot absorb that, preempt the newest tasks back to the
        // wait queue (vLLM-style preemption under memory pressure).
        while self.running.len() as u64 > free {
            let t = self.running.pop().expect("running > free > 0");
            self.decode_wait.push_front(t);
        }
        let mut growth = self.running.len() as u64;

        // Admit waiting decode tasks while the batch cap and memory hold.
        while !self.decode_wait.is_empty()
            && self.running.len() < self.cost.max_batch
            && growth + 1 <= free
        {
            let t = self.decode_wait.pop_front().unwrap();
            self.running.push(t);
            growth += 1;
        }

        let decode_reqs = self.running.len();
        let decode_tokens: u64 = self
            .running
            .iter()
            .map(|t| t.ctx as u64 + 1)
            .sum();

        // One chunk of the head prefill task with whatever memory remains.
        let mem_budget = free - growth;
        let (chunk, chunk_ctx) = match self.prefill_q.front() {
            Some(t) if mem_budget > 0 => {
                let mut c = self
                    .chunk_tokens
                    .min(t.remaining())
                    .min(mem_budget.min(u32::MAX as u64) as u32);
                // SLO-aware chunk cap: keep mixed iterations under the
                // latency budget so decode TPOT survives the interference.
                if decode_reqs > 0 {
                    if let Some(budget) = self.iter_time_budget {
                        let decode_t =
                            self.cost.decode_iter_time(decode_reqs, decode_tokens);
                        let spare = budget - decode_t;
                        let per_tok = self.cost.prefill_per_token
                            + self.cost.prefill_quad * t.done as f64;
                        let cap = if spare <= 0.0 {
                            64 // progress floor: never fully starve prefill
                        } else {
                            ((spare / per_tok.max(1e-12)) as u32).max(64)
                        };
                        c = c.min(cap);
                    }
                }
                (c, t.done + c)
            }
            _ => (0, 0),
        };

        if decode_reqs == 0 && chunk == 0 {
            return None;
        }

        let duration = if chunk > 0 {
            self.cost
                .mixed_iter_time(decode_reqs, decode_tokens, chunk, chunk_ctx)
        } else {
            self.cost.decode_iter_time(decode_reqs, decode_tokens)
        };

        // Commit KV growth now so concurrent reservations see it.
        self.kv_used += decode_reqs as u64; // +1 token per decode req
        self.kv_used += chunk as u64;

        self.busy = true;
        Some(IterationPlan {
            decode_reqs,
            decode_tokens,
            chunk,
            chunk_ctx,
            duration,
        })
    }

    /// Apply the effects of a completed iteration at time `now`.
    ///
    /// Convenience wrapper over [`SimInstance::finish_iteration_into`]
    /// that allocates a fresh buffer — tests and one-off callers only; the
    /// simulator event loop reuses a single buffer across iterations.
    pub fn finish_iteration(&mut self, plan: &IterationPlan, now: f64) -> Vec<Produced> {
        let mut out = Vec::new();
        self.finish_iteration_into(plan, now, &mut out);
        out
    }

    /// Apply the effects of a completed iteration at time `now`, appending
    /// the produced events to `out` (cleared first). Allocation-free on
    /// the steady state: the running batch is compacted in place
    /// (order-preserving, so preemption order — and therefore the whole
    /// schedule — is byte-identical to the drain-and-rebuild formulation).
    pub fn finish_iteration_into(
        &mut self,
        plan: &IterationPlan,
        now: f64,
        out: &mut Vec<Produced>,
    ) {
        out.clear();
        self.busy = false;
        self.iterations += 1;

        // Decode: every running task emits one token.
        if plan.decode_reqs > 0 {
            if let Some(prev) = self.last_token_time {
                self.intervals.push(now - prev);
            }
            self.last_token_time = Some(now);
        }
        let kv_used = &mut self.kv_used;
        let running_tokens_agg = &mut self.running_tokens_agg;
        self.running.retain_mut(|t| {
            t.ctx += 1;
            *running_tokens_agg += 1;
            t.remaining -= 1;
            if t.finished() {
                let freed = t.ctx as u64;
                *kv_used = kv_used.saturating_sub(freed);
                *running_tokens_agg -= freed;
                out.push(Produced::FinalToken { id: t.id, freed_kv: freed });
                false
            } else {
                out.push(Produced::Token { id: t.id });
                true
            }
        });

        // Prefill: head task advances by the chunk (moments updated in
        // lockstep — the O(1) aggregates never drift from the queue).
        if plan.chunk > 0 {
            let chunk_tokens = self.chunk_tokens;
            let head = self.prefill_q.front_mut().expect("chunk without head");
            let input_len = head.input_len;
            let old_remaining = head.remaining();
            head.done += plan.chunk;
            let new_remaining = head.remaining();
            let finished = head.finished();
            self.prefill_moments
                .advance_head(input_len, old_remaining, new_remaining, chunk_tokens);
            if finished {
                let t = self.prefill_q.pop_front().unwrap();
                self.prefill_moments.pop_finished_head();
                self.parked_prefill_kv += t.input_len as u64;
                out.push(Produced::PrefillDone {
                    id: t.id,
                    kv_tokens: t.input_len,
                });
            }
        }
    }

    /// A rejoining instance is a fresh process: no token-interval
    /// evidence carries over. Without this, the gap across the downtime
    /// would register as one huge "interval" and fake a TPOT violation
    /// right after a graceful restart.
    pub fn reset_monitor(&mut self) {
        self.intervals.clear();
        self.last_token_time = None;
    }

    /// Failure teardown (elastic membership): record every request still
    /// resident on this instance — queued or partially prefilled, running
    /// or parked for decode — so the cluster can re-queue them, then drop
    /// all local state. The KV of these requests is gone with the
    /// instance; callers restart them from scratch.
    pub fn drain_request_ids(&mut self, out: &mut Vec<RequestId>) {
        out.extend(self.prefill_q.iter().map(|t| t.id));
        out.extend(self.running.iter().map(|t| t.id));
        out.extend(self.decode_wait.iter().map(|t| t.id));
        self.clear();
    }

    /// Abandon all queued work (used by failure-injection tests).
    pub fn clear(&mut self) {
        self.prefill_q.clear();
        self.running.clear();
        self.decode_wait.clear();
        self.kv_used = 0;
        self.parked_prefill_kv = 0;
        self.prefill_moments = PrefillQueueMoments::default();
        self.running_tokens_agg = 0;
        self.reset_monitor();
        self.busy = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> SimInstance {
        SimInstance::new(InstanceId(0), CostModel::h800_llama8b())
    }

    #[test]
    fn idle_instance_plans_nothing() {
        let mut i = inst();
        assert!(i.plan_iteration().is_none());
        assert!(i.is_idle());
    }

    #[test]
    fn prefill_progresses_in_chunks_and_completes() {
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 5000);
        let mut produced = Vec::new();
        let mut now = 0.0;
        let mut iters = 0;
        while let Some(plan) = i.plan_iteration() {
            assert!(plan.chunk > 0);
            now += plan.duration;
            produced.extend(i.finish_iteration(&plan, now));
            iters += 1;
            assert!(iters < 100, "no progress");
        }
        assert_eq!(iters, 3); // 2048 + 2048 + 904
        assert!(matches!(
            produced.last(),
            Some(Produced::PrefillDone { kv_tokens: 5000, .. })
        ));
        // KV parked, not freed.
        assert_eq!(i.kv_used(), 5000);
    }

    #[test]
    fn decode_emits_tokens_until_done() {
        let mut i = inst();
        assert!(i.try_reserve_kv(10));
        i.enqueue_decode(RequestId(2), 10, 3);
        let mut now = 0.0;
        let mut finals = 0;
        let mut tokens = 0;
        while let Some(plan) = i.plan_iteration() {
            now += plan.duration;
            for p in i.finish_iteration(&plan, now) {
                match p {
                    Produced::Token { .. } => tokens += 1,
                    Produced::FinalToken { freed_kv, .. } => {
                        finals += 1;
                        assert_eq!(freed_kv, 13); // 10 + 3 generated
                    }
                    _ => panic!("unexpected prefill event"),
                }
            }
        }
        assert_eq!(tokens, 2);
        assert_eq!(finals, 1);
        assert_eq!(i.kv_used(), 0);
    }

    #[test]
    fn decode_priority_over_prefill_in_mixed_batch() {
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 4096);
        assert!(i.try_reserve_kv(100));
        i.enqueue_decode(RequestId(2), 100, 5);
        let plan = i.plan_iteration().unwrap();
        assert_eq!(plan.decode_reqs, 1);
        assert!(plan.chunk > 0, "chunked prefill joins the same batch");
        // Mixed iteration slower than pure decode.
        let pure = i.cost.decode_iter_time(1, plan.decode_tokens);
        assert!(plan.duration > pure);
    }

    #[test]
    fn batch_cap_parks_excess_decodes() {
        let mut i = inst();
        i.cost_mut().max_batch = 2;
        for r in 0..4 {
            assert!(i.try_reserve_kv(10));
            i.enqueue_decode(RequestId(r), 10, 5);
        }
        let plan = i.plan_iteration().unwrap();
        assert_eq!(plan.decode_reqs, 2);
        assert_eq!(i.decode_req_count(), 4);
    }

    #[test]
    fn kv_reservation_rejects_over_capacity() {
        let mut i = inst();
        let cap = i.cost.max_kv_tokens;
        assert!(i.try_reserve_kv(cap));
        assert!(!i.try_reserve_kv(1));
        i.release_kv(cap);
        assert!(i.try_reserve_kv(1));
    }

    #[test]
    fn local_adoption_skips_transfer() {
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 100);
        let plan = i.plan_iteration().unwrap();
        let out = i.finish_iteration(&plan, 1.0);
        assert!(matches!(out[0], Produced::PrefillDone { .. }));
        assert_eq!(i.kv_used(), 100);
        i.adopt_local_decode(RequestId(1), 100, 3);
        assert_eq!(i.kv_used(), 100); // no double counting
        assert!(i.has_decode_work());
    }

    #[test]
    fn migration_out_frees_kv() {
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 100);
        let plan = i.plan_iteration().unwrap();
        i.finish_iteration(&plan, 1.0);
        i.migration_out_done(100);
        assert_eq!(i.kv_used(), 0);
    }

    #[test]
    fn token_intervals_tracked() {
        let mut i = inst();
        assert!(i.try_reserve_kv(10));
        i.enqueue_decode(RequestId(1), 10, 4);
        let mut now = 0.0;
        while let Some(plan) = i.plan_iteration() {
            now += plan.duration;
            i.finish_iteration(&plan, now);
        }
        let avg = i.avg_token_interval();
        assert!(avg > 0.0 && avg < 1.0, "avg={avg}");
    }

    #[test]
    fn backlog_seconds_counts_all_queued() {
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 2048);
        let one = i.prefill_backlog_seconds();
        i.enqueue_prefill(RequestId(2), 2048);
        let two = i.prefill_backlog_seconds();
        assert!(two > 1.9 * one, "one={one} two={two}");
    }

    #[test]
    fn aggregates_track_queue_and_decode_state() {
        // The debug-mode oracles inside running_tokens() /
        // prefill_queue_moments() make these calls self-checking; this
        // test drives every mutation path through them.
        let mut i = inst();
        i.enqueue_prefill(RequestId(1), 5000);
        i.enqueue_prefill(RequestId(2), 300);
        assert!(i.try_reserve_kv(120));
        i.enqueue_decode(RequestId(3), 100, 3);
        assert_eq!(i.running_tokens(), 100);
        let m = i.prefill_queue_moments();
        assert_eq!((m.count, m.sum_remaining), (2, 5300));
        let mut now = 0.0;
        while let Some(plan) = i.plan_iteration() {
            now += plan.duration;
            for p in i.finish_iteration(&plan, now) {
                if let Produced::PrefillDone { id, kv_tokens } = p {
                    i.migration_out_done(kv_tokens);
                    let _ = id;
                }
            }
            // Oracles re-verified after every iteration.
            let _ = i.running_tokens();
            let _ = i.prefill_queue_moments();
        }
        assert_eq!(i.prefill_queue_moments(), crate::sched::PrefillQueueMoments::default());
        assert_eq!(i.running_tokens(), 0);
        i.enqueue_prefill(RequestId(9), 777);
        i.clear();
        assert_eq!(i.prefill_queue_moments(), crate::sched::PrefillQueueMoments::default());
    }

    #[test]
    fn ranked_enqueue_orders_by_rank_fifo_within() {
        let mut i = inst();
        i.enqueue_prefill_ranked(RequestId(1), 100, 1);
        i.enqueue_prefill_ranked(RequestId(2), 100, 2);
        i.enqueue_prefill_ranked(RequestId(3), 100, 1);
        i.enqueue_prefill_ranked(RequestId(4), 100, 0);
        i.enqueue_prefill_ranked(RequestId(5), 100, 2);
        let order: Vec<u64> = i.prefill_q.iter().map(|t| t.id.0).collect();
        // rank 0 first; FIFO among equal ranks (1 before 3, 2 before 5).
        assert_eq!(order, vec![4, 1, 3, 2, 5]);
        // Moments identical to plain enqueues (position-independent).
        let mut plain = inst();
        for id in 1..=5 {
            plain.enqueue_prefill(RequestId(id), 100);
        }
        assert_eq!(i.prefill_queue_moments(), plain.prefill_queue_moments());
    }

    #[test]
    fn single_rank_stream_matches_plain_fifo() {
        // PR 8 bit-stability: all-Standard traffic arrives with one rank;
        // the ranked path must build exactly the push_back queue.
        let mut ranked = inst();
        let mut plain = inst();
        for id in 0..6u64 {
            ranked.enqueue_prefill_ranked(RequestId(id), 64 * (id as u32 + 1), 1);
            plain.enqueue_prefill(RequestId(id), 64 * (id as u32 + 1));
        }
        let a: Vec<_> = ranked.prefill_q.iter().map(|t| (t.id, t.input_len)).collect();
        let b: Vec<_> = plain.prefill_q.iter().map(|t| (t.id, t.input_len)).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn ranked_enqueue_never_displaces_in_progress_head() {
        let mut i = inst();
        i.enqueue_prefill_ranked(RequestId(1), 5000, 2);
        // One chunk in flight: a higher-priority arrival lands *behind*
        // the head while the iteration is pending...
        let plan = i.plan_iteration().unwrap();
        i.enqueue_prefill_ranked(RequestId(2), 100, 0);
        assert_eq!(i.prefill_q.front().unwrap().id, RequestId(1));
        i.finish_iteration(&plan, 0.1);
        // ...and behind a partially-done head between iterations too.
        assert!(i.prefill_q.front().unwrap().done > 0);
        i.enqueue_prefill_ranked(RequestId(3), 100, 0);
        let order: Vec<u64> = i.prefill_q.iter().map(|t| t.id.0).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn prop_kv_never_exceeds_capacity_or_goes_negative() {
        use crate::util::{prop, rng::Rng};
        prop::check_with(77, 64, |rng: &mut Rng| {
            let mut i = inst();
            i.cost_mut().max_kv_tokens = 10_000;
            i.cost_mut().max_batch = 8;
            let mut now = 0.0;
            let mut next_id = 0u64;
            for _ in 0..rng.index(60) + 10 {
                match rng.index(3) {
                    0 => {
                        let len = rng.int_range(1, 3000) as u32;
                        if (len as u64) <= i.kv_free() {
                            i.enqueue_prefill(RequestId(next_id), len);
                            next_id += 1;
                        }
                    }
                    1 => {
                        let ctx = rng.int_range(1, 2000) as u64;
                        if i.try_reserve_kv(ctx) {
                            i.enqueue_decode(
                                RequestId(next_id),
                                ctx as u32,
                                rng.int_range(1, 50) as u32,
                            );
                            next_id += 1;
                        }
                    }
                    _ => {
                        if let Some(plan) = i.plan_iteration() {
                            now += plan.duration;
                            for p in i.finish_iteration(&plan, now) {
                                if let Produced::PrefillDone { id, kv_tokens } = p {
                                    // Alternate local adopt / migrate out.
                                    if rng.bool(0.5) {
                                        i.adopt_local_decode(id, kv_tokens, 2);
                                    } else {
                                        i.migration_out_done(kv_tokens);
                                    }
                                }
                            }
                        }
                    }
                }
                crate::prop_assert!(
                    i.kv_used() <= i.cost.max_kv_tokens,
                    "kv_used {} > cap {}",
                    i.kv_used(),
                    i.cost.max_kv_tokens
                );
            }
            Ok(())
        });
    }
}
