//! Arrow: adaptive scheduling for Prefill–Decode disaggregated LLM
//! inference — a three-layer (Rust + JAX + Pallas, AOT via PJRT)
//! reproduction of the paper. See DESIGN.md for architecture notes and
//! the paper→repo substitutions; EXPERIMENTS.md for reproduced results.
//!
//! Layer map:
//! * [`sched`] — the substrate-agnostic scheduling core: the [`sched::Policy`]
//!   trait and the [`sched::ClusterView`] snapshot interface every policy
//!   consumes (the simulator and the live server implement adapters).
//! * [`coordinator`] — the paper's contribution: stateless instances,
//!   elastic pools, SLO-aware request + instance scheduling.
//! * [`engine`], [`costmodel`], [`sim`] — the serving substrate and the
//!   calibrated discrete-event cluster simulator.
//! * [`runtime`] — PJRT loader executing the AOT artifacts emitted by
//!   `python/compile/aot.py` (L2 JAX model + L1 Pallas kernels).
//! * [`baselines`], [`scenarios`], [`metrics`] — evaluation harness.
//! * [`harness`] — paper-claims conformance: the normalized-cost-model
//!   sweep that turns the paper's cross-system orderings into
//!   machine-checkable verdicts (`arrow claims`, `tests/claims.rs`).

pub mod baselines;
pub mod coordinator;
pub mod costmodel;
pub mod engine;
pub mod fault;
pub mod harness;
pub mod json;
pub mod metrics;
pub mod request;
pub mod scenarios;
pub mod sched;
pub mod sim;
pub mod trace;
pub mod util;

pub mod cli;
pub mod figures;
pub mod http;
pub mod replay;
pub mod runtime;
pub mod server;
