//! Calibrated latency cost model for simulated instances.
//!
//! The paper's analysis (§3.1, §4) rests on two scaling laws that prior
//! work established and Arrow's scheduler exploits:
//!
//! * prefill computation scales ~quadratically with input length
//!   (linear compute term + quadratic attention term), and
//! * decode iteration time scales linearly with the total number of
//!   tokens in the batch.
//!
//! `CostModel` encodes exactly those laws. In simulated mode it supplies
//! per-iteration latencies; coefficients come either from an analytic
//! H800/Llama-8B preset (paper's testbed, DESIGN.md §3) or from fitting
//! timings of the real PJRT executables (`calibrate_from_samples`, used by
//! `arrow calibrate`). The quadratic TTFT fit in `coordinator::predictor`
//! is the *scheduler's* learned view of the same curve — keeping the two
//! separate mirrors the real system (profiler vs. ground truth).

use crate::util::stats;

/// Per-instance latency model (all times in seconds, lengths in tokens).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Fixed per-iteration overhead (kernel launches, scheduler step).
    pub iter_overhead: f64,
    /// Prefill compute seconds per prompt token (linear term).
    pub prefill_per_token: f64,
    /// Prefill attention seconds per (token × context-token) — the
    /// quadratic term.
    pub prefill_quad: f64,
    /// Decode seconds per token resident in the batch (KV bandwidth term).
    pub decode_per_token: f64,
    /// Decode seconds per request in the batch (per-sequence overhead).
    pub decode_per_req: f64,
    /// KV transfer: fixed latency per migration.
    pub transfer_latency: f64,
    /// KV transfer: seconds per KV byte (1/bandwidth).
    pub transfer_per_byte: f64,
    /// KV cache bytes per token (model-dependent).
    pub kv_bytes_per_token: u64,
    /// KV capacity of the instance, in tokens.
    pub max_kv_tokens: u64,
    /// Max decode requests per batch.
    pub max_batch: usize,
}

impl CostModel {
    /// Analytic preset for the paper's testbed: one H800 GPU serving a
    /// Llama-3.1-8B shard. Derivation in DESIGN.md §3:
    /// compute ≈ 2·8e9 FLOPs/token at ~50% of 700 TFLOPs (bf16), KV read
    /// at ~3.35 TB/s, 16 GB weights, ~60 GB free for KV at ~131 KB/token.
    pub fn h800_llama8b() -> CostModel {
        CostModel {
            iter_overhead: 0.004,
            prefill_per_token: 4.5e-5,
            // Attention FLOPs per token-pair: 2 (QK^T + PV) × 2 FLOP ×
            // d_model(4096) × 32 layers ≈ 5.2e5, over ~350 TFLOPs usable.
            prefill_quad: 1.5e-9,
            decode_per_token: 4.0e-8,
            decode_per_req: 1.0e-4,
            transfer_latency: 1.0e-3,
            transfer_per_byte: 1.0 / 400.0e9, // NVLink 400 GB/s
            kv_bytes_per_token: 131_072,
            max_kv_tokens: 400_000,
            max_batch: 256,
        }
    }

    /// Dimensionless conformance preset (PR 5). Fixed round-number
    /// coefficients that preserve the paper's *analytical latency shapes*
    /// — prefill superlinear in prompt length (linear + quadratic
    /// attention term), decode linear in batch tokens, KV transfer linear
    /// in KV size — without encoding any particular GPU's calibration.
    ///
    /// This is the cost model the paper-claims conformance tier
    /// (`harness`, `tests/claims.rs`, `tests/metamorphic.rs`) runs under:
    /// cross-system margins measured on it are properties of the
    /// *scheduler*, so recalibrating [`CostModel::h800_llama8b`] against
    /// real hardware (`arrow calibrate`) must never move a claims test.
    /// The magnitudes deliberately sit in the same regime as the H800
    /// preset so the Table-1 workloads exercise the same saturation
    /// dynamics; the values themselves are a frozen contract — change
    /// them and every claims digest/margin must be re-derived.
    pub fn normalized() -> CostModel {
        CostModel {
            iter_overhead: 4.0e-3,
            prefill_per_token: 5.0e-5,
            prefill_quad: 2.0e-9,
            decode_per_token: 5.0e-8,
            decode_per_req: 1.0e-4,
            transfer_latency: 1.0e-3,
            transfer_per_byte: 2.5e-12,
            kv_bytes_per_token: 131_072,
            max_kv_tokens: 400_000,
            max_batch: 256,
        }
    }

    /// Multiply every *time* coefficient by `k` (token, byte, and batch
    /// capacities are dimensionless and stay put). For power-of-two `k`
    /// the scaling is bit-exact in IEEE-754, which the metamorphic
    /// cost-scale-invariance tier relies on: dilating the cost model, the
    /// arrival times, the SLOs, and the monitor period by the same `k`
    /// must reproduce the identical placement schedule.
    pub fn scaled(&self, k: f64) -> CostModel {
        assert!(k > 0.0 && k.is_finite(), "time scale must be positive/finite");
        CostModel {
            iter_overhead: self.iter_overhead * k,
            prefill_per_token: self.prefill_per_token * k,
            prefill_quad: self.prefill_quad * k,
            decode_per_token: self.decode_per_token * k,
            decode_per_req: self.decode_per_req * k,
            transfer_latency: self.transfer_latency * k,
            transfer_per_byte: self.transfer_per_byte * k,
            ..self.clone()
        }
    }

    /// Scale the model for an instance spanning `tp` GPUs with the given
    /// parallel efficiency (compute & bandwidth scale up; capacity too).
    pub fn with_tensor_parallel(&self, tp: usize, efficiency: f64) -> CostModel {
        assert!(tp >= 1 && efficiency > 0.0 && efficiency <= 1.0);
        let speed = tp as f64 * efficiency;
        CostModel {
            iter_overhead: self.iter_overhead,
            prefill_per_token: self.prefill_per_token / speed,
            prefill_quad: self.prefill_quad / speed,
            decode_per_token: self.decode_per_token / speed,
            decode_per_req: self.decode_per_req,
            transfer_latency: self.transfer_latency,
            transfer_per_byte: self.transfer_per_byte,
            kv_bytes_per_token: self.kv_bytes_per_token,
            max_kv_tokens: self.max_kv_tokens * tp as u64,
            max_batch: self.max_batch * tp,
        }
    }

    /// Uniform slowdown (models DistServe's unmaintained engine, §7.1).
    pub fn with_efficiency(&self, eff: f64) -> CostModel {
        assert!(eff > 0.0 && eff <= 1.0);
        CostModel {
            prefill_per_token: self.prefill_per_token / eff,
            prefill_quad: self.prefill_quad / eff,
            decode_per_token: self.decode_per_token / eff,
            decode_per_req: self.decode_per_req / eff,
            ..self.clone()
        }
    }

    // ------------------------------------------------------------ queries

    /// Seconds to prefill a chunk of `chunk` tokens whose attention
    /// context (tokens already processed + this chunk) is `ctx`.
    pub fn prefill_chunk_time(&self, chunk: u32, ctx: u32) -> f64 {
        self.prefill_per_token * chunk as f64
            + self.prefill_quad * chunk as f64 * ctx as f64
    }

    /// Seconds for the *whole* prefill of an `len`-token prompt executed
    /// in one piece: linear + quadratic/2 (sum over causal context).
    pub fn prefill_time(&self, len: u32) -> f64 {
        let l = len as f64;
        self.iter_overhead + self.prefill_per_token * l + self.prefill_quad * l * l / 2.0
    }

    /// Seconds for one decode iteration over a batch holding
    /// `batch_tokens` total KV tokens across `batch_reqs` requests.
    pub fn decode_iter_time(&self, batch_reqs: usize, batch_tokens: u64) -> f64 {
        self.iter_overhead
            + self.decode_per_token * batch_tokens as f64
            + self.decode_per_req * batch_reqs as f64
    }

    /// Mixed chunked-prefill iteration: decode batch plus a prefill chunk
    /// (the colocated/chunked-prefill engines batch both, paper §2.1).
    pub fn mixed_iter_time(
        &self,
        batch_reqs: usize,
        batch_tokens: u64,
        chunk: u32,
        chunk_ctx: u32,
    ) -> f64 {
        self.decode_iter_time(batch_reqs, batch_tokens)
            + self.prefill_chunk_time(chunk, chunk_ctx)
    }

    /// Seconds to migrate `kv_tokens` of KV cache between instances.
    pub fn transfer_time(&self, kv_tokens: u64) -> f64 {
        self.transfer_latency
            + self.transfer_per_byte * (kv_tokens * self.kv_bytes_per_token) as f64
    }

    /// The paper's "Max Running Tokens" profiling (§5.3): the largest
    /// total batch token count whose decode iteration still meets the
    /// TPOT SLO, capped by KV memory.
    pub fn max_running_tokens(&self, tpot_slo: f64) -> u64 {
        let budget = tpot_slo - self.iter_overhead
            - self.decode_per_req * self.max_batch as f64;
        if budget <= 0.0 {
            return self.max_kv_tokens.min(1);
        }
        let by_slo = (budget / self.decode_per_token) as u64;
        by_slo.min(self.max_kv_tokens)
    }

    // -------------------------------------------------------- calibration

    /// Fit prefill coefficients from measured (len, seconds) samples and
    /// decode coefficients from (batch_tokens, seconds) samples — used to
    /// calibrate the simulator against the real PJRT executables.
    pub fn calibrate_from_samples(
        &mut self,
        prefill: &[(u32, f64)],
        decode: &[(u64, f64)],
    ) {
        if prefill.len() >= 3 {
            let xs: Vec<f64> = prefill.iter().map(|&(l, _)| l as f64).collect();
            let ys: Vec<f64> = prefill.iter().map(|&(_, t)| t).collect();
            let c = stats::quadratic_fit(&xs, &ys);
            self.iter_overhead = c[0].max(1e-6);
            self.prefill_per_token = c[1].max(0.0);
            self.prefill_quad = (c[2] * 2.0).max(0.0); // prefill_time halves it
        }
        if decode.len() >= 2 {
            let xs: Vec<f64> = decode.iter().map(|&(n, _)| n as f64).collect();
            let ys: Vec<f64> = decode.iter().map(|&(_, t)| t).collect();
            let c = stats::linear_fit(&xs, &ys);
            self.decode_per_token = c[1].max(0.0);
            // Keep iter_overhead from prefill fit if it was set; otherwise
            // use the decode intercept.
            if prefill.len() < 3 {
                self.iter_overhead = c[0].max(1e-6);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_quadratic_growth() {
        let m = CostModel::h800_llama8b();
        let t1 = m.prefill_time(1_000);
        let t10 = m.prefill_time(10_000);
        let t100 = m.prefill_time(100_000);
        // Long-prompt regime grows super-linearly.
        assert!(t10 > 9.0 * t1, "t1={t1} t10={t10}");
        assert!(t100 > 15.0 * t10, "t10={t10} t100={t100}");
    }

    #[test]
    fn decode_linear_in_tokens() {
        let m = CostModel::h800_llama8b();
        let a = m.decode_iter_time(8, 10_000) - m.decode_iter_time(8, 0);
        let b = m.decode_iter_time(8, 20_000) - m.decode_iter_time(8, 10_000);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn chunked_prefill_sums_to_whole() {
        // Sum of chunk times ≈ whole-prompt time (modulo per-iteration
        // overhead, which chunking legitimately multiplies).
        let m = CostModel::h800_llama8b();
        let len = 8_192u32;
        let chunk = 512u32;
        let mut total = 0.0;
        let mut done = 0u32;
        while done < len {
            let c = chunk.min(len - done);
            total += m.prefill_chunk_time(c, done + c);
            done += c;
        }
        let whole = m.prefill_time(len) - m.iter_overhead;
        // The chunked sum uses ctx at chunk end => slightly above the
        // continuous integral; allow 10%.
        assert!(
            (total - whole).abs() / whole < 0.10,
            "chunked={total} whole={whole}"
        );
    }

    #[test]
    fn tensor_parallel_speeds_up_and_scales_memory() {
        let m = CostModel::h800_llama8b();
        let m8 = m.with_tensor_parallel(8, 0.9);
        assert!(m8.prefill_time(4096) < m.prefill_time(4096) / 6.0);
        assert_eq!(m8.max_kv_tokens, m.max_kv_tokens * 8);
        assert!(m8.decode_iter_time(1, 100_000) < m.decode_iter_time(1, 100_000));
    }

    #[test]
    fn efficiency_slows_down() {
        let m = CostModel::h800_llama8b();
        let slow = m.with_efficiency(0.5);
        assert!(slow.prefill_time(1000) > 1.8 * (m.prefill_time(1000) - m.iter_overhead));
    }

    #[test]
    fn transfer_time_scales_with_tokens() {
        let m = CostModel::h800_llama8b();
        let t1 = m.transfer_time(1_000);
        let t2 = m.transfer_time(100_000);
        assert!(t2 > t1);
        // 100k tokens * 131072 B = ~13 GB over 400 GB/s => ~33 ms + lat.
        assert!((0.02..0.1).contains(&t2), "t2={t2}");
    }

    #[test]
    fn max_running_tokens_respects_slo_and_memory() {
        let m = CostModel::h800_llama8b();
        let strict = m.max_running_tokens(0.032); // SLO-bound regime
        let loose = m.max_running_tokens(0.5); // memory-bound regime
        assert!(strict < loose, "strict={strict} loose={loose}");
        assert!(loose <= m.max_kv_tokens);
        // With the preset, a 0.1s TPOT budget allows a big batch.
        assert!(m.max_running_tokens(0.1) > 100_000);
    }

    #[test]
    fn calibration_recovers_known_coefficients() {
        let truth = CostModel::h800_llama8b();
        let prefill: Vec<(u32, f64)> = (1..40)
            .map(|i| {
                let l = i * 512;
                (l, truth.prefill_time(l))
            })
            .collect();
        let decode: Vec<(u64, f64)> = (1..40)
            .map(|i| {
                let n = i as u64 * 2_000;
                (n, truth.decode_iter_time(8, n))
            })
            .collect();
        let mut fit = CostModel::h800_llama8b();
        fit.prefill_per_token = 0.0;
        fit.prefill_quad = 0.0;
        fit.decode_per_token = 0.0;
        fit.calibrate_from_samples(&prefill, &decode);
        assert!(
            (fit.prefill_per_token - truth.prefill_per_token).abs()
                / truth.prefill_per_token
                < 0.05
        );
        assert!((fit.prefill_quad - truth.prefill_quad).abs() / truth.prefill_quad < 0.05);
        assert!(
            (fit.decode_per_token - truth.decode_per_token).abs()
                / truth.decode_per_token
                < 0.05
        );
    }

    #[test]
    fn normalized_preserves_analytical_shapes() {
        // The conformance contract: same latency *shapes* as the paper's
        // analysis, independent of any calibration.
        let m = CostModel::normalized();
        // Prefill superlinear in length.
        assert!(m.prefill_time(10_000) > 9.0 * m.prefill_time(1_000));
        assert!(m.prefill_time(100_000) > 15.0 * m.prefill_time(10_000));
        // Decode linear in batch tokens.
        let a = m.decode_iter_time(8, 10_000) - m.decode_iter_time(8, 0);
        let b = m.decode_iter_time(8, 20_000) - m.decode_iter_time(8, 10_000);
        assert!((a - b).abs() < 1e-12);
        // Transfer linear in KV size.
        let t1 = m.transfer_time(10_000) - m.transfer_time(0);
        let t2 = m.transfer_time(20_000) - m.transfer_time(10_000);
        assert!((t1 - t2).abs() < 1e-9);
        // Max-running-tokens keeps both regimes (SLO-bound vs memory-bound).
        assert!(m.max_running_tokens(0.032) < m.max_running_tokens(0.5));
        assert!(m.max_running_tokens(0.5) <= m.max_kv_tokens);
    }

    #[test]
    fn normalized_is_a_frozen_contract() {
        // Claims margins are derived under these exact values; a drift
        // here must be a deliberate, loud decision (see tests/claims.rs).
        let m = CostModel::normalized();
        assert_eq!(m.iter_overhead.to_bits(), 4.0e-3f64.to_bits());
        assert_eq!(m.prefill_per_token.to_bits(), 5.0e-5f64.to_bits());
        assert_eq!(m.prefill_quad.to_bits(), 2.0e-9f64.to_bits());
        assert_eq!(m.decode_per_token.to_bits(), 5.0e-8f64.to_bits());
        assert_eq!(m.decode_per_req.to_bits(), 1.0e-4f64.to_bits());
        assert_eq!(m.transfer_latency.to_bits(), 1.0e-3f64.to_bits());
        assert_eq!(m.transfer_per_byte.to_bits(), 2.5e-12f64.to_bits());
        assert_eq!(m.kv_bytes_per_token, 131_072);
        assert_eq!(m.max_kv_tokens, 400_000);
        assert_eq!(m.max_batch, 256);
    }

    #[test]
    fn scaled_by_power_of_two_is_bit_exact() {
        let m = CostModel::normalized();
        let d = m.scaled(2.0);
        for len in [1u32, 100, 2_048, 100_000] {
            assert_eq!(
                (2.0 * m.prefill_time(len)).to_bits(),
                d.prefill_time(len).to_bits(),
                "len={len}"
            );
            assert_eq!(
                (2.0 * m.prefill_chunk_time(512, len)).to_bits(),
                d.prefill_chunk_time(512, len).to_bits()
            );
        }
        for (reqs, toks) in [(1usize, 100u64), (64, 50_000), (256, 400_000)] {
            assert_eq!(
                (2.0 * m.decode_iter_time(reqs, toks)).to_bits(),
                d.decode_iter_time(reqs, toks).to_bits()
            );
        }
        assert_eq!(
            (2.0 * m.transfer_time(123_456)).to_bits(),
            d.transfer_time(123_456).to_bits()
        );
        // Dilating the TPOT SLO by the same factor yields the *identical*
        // token budget: the scheduler's discrete decisions cannot tell
        // scaled time from real time.
        for slo in [0.032, 0.1, 0.5] {
            assert_eq!(m.max_running_tokens(slo), d.max_running_tokens(2.0 * slo));
        }
        // Identity scale is the identity, bit for bit.
        let same = m.scaled(1.0);
        assert_eq!(same.prefill_per_token.to_bits(), m.prefill_per_token.to_bits());
        assert_eq!(same.iter_overhead.to_bits(), m.iter_overhead.to_bits());
    }

    #[test]
    fn mixed_iteration_adds_interference() {
        // A decode batch sharing an iteration with a prefill chunk takes
        // longer than either alone — the colocation interference the
        // paper's disaggregation removes (§2.2).
        let m = CostModel::h800_llama8b();
        let d = m.decode_iter_time(16, 50_000);
        let mixed = m.mixed_iter_time(16, 50_000, 2048, 2048);
        assert!(mixed > d + 0.5 * m.prefill_chunk_time(2048, 2048));
    }
}
