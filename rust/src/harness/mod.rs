//! Paper-claims conformance harness (PR 5).
//!
//! Arrow's headline result — up to 2.55× higher sustainable request rates
//! than static Prefill–Decode splits under fluctuating input/output
//! lengths — is a claim about the *scheduler*, not about one GPU. This
//! module makes it machine-checkable on every commit: it sweeps all
//! eight evaluated systems — the paper's six plus the PR-10 scheduling
//! adversaries (`deflect`, `unified`) — across the Table-1 workloads
//! under the dimensionless
//! [`CostModel::normalized`] preset, measures per-system sweeps and
//! maximum sustainable rates ([`crate::metrics::max_sustainable_rate`]),
//! and condenses the paper's qualitative orderings into [`ClaimVerdict`]s
//! with explicit tolerance bands:
//!
//! * **max-rate ordering** — Arrow sustains at least what every static
//!   split sustains, per workload;
//! * **goodput ordering at the stress point** — at the first swept rate
//!   where the best static split misses the attainment target, Arrow's
//!   goodput is at least each split's (the burst/imbalance regime where
//!   adaptivity is supposed to pay);
//! * **degradation shapes** (burst workload) — the colocated system's
//!   P90 TTFT inflates under load while its decode-prioritized TPOT stays
//!   inside the SLO, and Arrow's disaggregated TPOT stays inside the SLO
//!   even past saturation (§7.2's observation).
//!
//! `tests/claims.rs` asserts the verdicts; `arrow claims` emits the full
//! machine-readable report (same JSON conventions as the `BENCH_*.json`
//! emitters: one self-describing object, deterministic key order) and
//! exits non-zero when a claim fails, which is how ci.sh gates it.
//!
//! Everything here is deterministic: fixed seed, fixed grid, simulator
//! runs that are byte-stable across machines. The normalized cost model
//! is the contract that keeps it so — claims must never depend on
//! hardware calibration (ROADMAP "Paper-claims conformance").

use crate::costmodel::CostModel;
use crate::json::Json;
use crate::metrics::{max_sustainable_rate, SloReport, StreamingSlo};
use crate::request::SloClass;
use crate::scenarios::{build, build_arrow_classed, System};
use crate::sim::AdmissionControl;
use crate::trace::catalog::{self, Workload};
use crate::trace::stream::{Scaled, TraceSource};
use crate::trace::synthetic::ClassMix;
use crate::trace::Trace;
use crate::util::threads::{default_workers, parallel_map};

pub mod chaos;
pub mod loadgen;

/// The §7.1/§7.3 baselines that disaggregate with *fixed* roles — the
/// systems the paper's "vs static PD disaggregation" claims range over.
/// The colocated system is deliberately not here: it appears in the
/// degradation-shape claims instead (its TP=n engine is a different
/// resource envelope, not a static split of the same one).
pub const STATIC_SPLITS: [System; 4] = [
    System::VllmDisaggregated,
    System::DistServe,
    System::MinimalLoad,
    System::RoundRobin,
];

/// `ARROW_CLAIMS_SMOKE` (the ci.sh knob): truthy when set to anything
/// but "0"/empty.
pub fn smoke_env() -> bool {
    std::env::var("ARROW_CLAIMS_SMOKE").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Sweep parameters for one conformance run.
#[derive(Debug, Clone)]
pub struct ClaimsConfig {
    pub seed: u64,
    /// Clip each trace to this many seconds before sweeping.
    pub clip_seconds: f64,
    pub gpus: usize,
    /// Rate multipliers (of the clipped trace's base rate) swept per
    /// (workload, system). Must be sorted ascending: stress detection
    /// walks it front to back.
    pub rate_mults: Vec<f64>,
    /// SLO attainment target (the paper's 90%).
    pub target: f64,
    /// Ordering tolerance band: Arrow may fall short of a baseline by
    /// this fraction before a claim is called failed (absorbs simulator
    /// discretization, not scheduling regressions).
    pub tolerance: f64,
    /// Relative tolerance of the max-sustainable-rate bisection; its
    /// quantization error widens the max-rate claim band additively.
    pub rate_search_tolerance: f64,
    pub workers: usize,
    pub smoke: bool,
}

impl ClaimsConfig {
    /// The full grid `arrow claims` runs by default.
    pub fn full() -> ClaimsConfig {
        ClaimsConfig {
            seed: 42,
            clip_seconds: 300.0,
            gpus: 8,
            rate_mults: vec![1.0, 2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0],
            target: 0.9,
            tolerance: 0.05,
            rate_search_tolerance: 0.05,
            workers: default_workers(),
            smoke: false,
        }
    }

    /// CI-budget variant (`ARROW_CLAIMS_SMOKE=1`): short clips, coarse
    /// rate grid, loose bisection — the same claims, evaluated inside the
    /// existing bench-gate time budget.
    ///
    /// The 120s clip + x32 top multiplier are chosen together so the
    /// stress point is found through *sustained* saturation: at x32 the
    /// static splits are ~2x over capacity on azure_code's average rate
    /// alone, so the smoke gate does not depend on whether the clip
    /// happens to contain burst minutes (a 60s clip left the orderings
    /// trivially true on calm clips). The burst-sensitive versions of the
    /// same claims run on the 300s clip in `tests/claims.rs` and the full
    /// grid.
    pub fn smoke() -> ClaimsConfig {
        ClaimsConfig {
            clip_seconds: 120.0,
            rate_mults: vec![2.0, 8.0, 32.0],
            rate_search_tolerance: 0.2,
            smoke: true,
            ..ClaimsConfig::full()
        }
    }

    /// Full or smoke, per the `ARROW_CLAIMS_SMOKE` environment knob.
    pub fn from_env() -> ClaimsConfig {
        if smoke_env() {
            ClaimsConfig::smoke()
        } else {
            ClaimsConfig::full()
        }
    }

    /// Claim band for max-rate orderings: the ordering tolerance widened
    /// by the bisection's own quantization.
    fn rate_band(&self) -> f64 {
        (1.0 - self.tolerance - self.rate_search_tolerance).max(0.0)
    }
}

/// One (rate multiplier, simulated run) sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub rate_mult: f64,
    /// Absolute request rate (req/s) this point ran at.
    pub rate: f64,
    pub report: SloReport,
}

/// One system's measurements on one workload.
#[derive(Debug, Clone)]
pub struct SystemOutcome {
    pub system: System,
    pub sweep: Vec<SweepPoint>,
    /// Maximum request rate sustaining the attainment target (req/s).
    pub max_sustainable: f64,
}

impl SystemOutcome {
    /// Sweep report at multiplier `m` (must be on the configured grid).
    pub fn at_mult(&self, m: f64) -> &SloReport {
        &self
            .sweep
            .iter()
            .find(|p| p.rate_mult == m)
            .unwrap_or_else(|| panic!("rate multiplier {m} not on the sweep grid"))
            .report
    }
}

/// All swept systems' measurements on one Table-1 workload.
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub workload: String,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
    /// Base request rate of the clipped trace (req/s).
    pub base_rate: f64,
    pub n_requests: usize,
    pub systems: Vec<SystemOutcome>,
    /// The claims stress point: the first swept multiplier at which the
    /// *best* static split misses the attainment target — i.e. the
    /// lightest overload regime, where adaptive scheduling is supposed to
    /// separate from static splits. Falls back to the last multiplier
    /// when every split sustains the whole grid.
    pub stress_mult: f64,
}

impl WorkloadOutcome {
    pub fn system(&self, s: System) -> &SystemOutcome {
        self.systems
            .iter()
            .find(|o| o.system == s)
            .unwrap_or_else(|| panic!("system {} not swept", s.label()))
    }
}

/// One paper claim, evaluated: `holds` iff `measured >= bound`.
#[derive(Debug, Clone)]
pub struct ClaimVerdict {
    pub workload: String,
    pub claim: String,
    pub holds: bool,
    pub measured: f64,
    pub bound: f64,
    pub detail: String,
}

/// The full conformance report: measurements plus verdicts.
#[derive(Debug, Clone)]
pub struct ClaimsReport {
    pub cfg: ClaimsConfig,
    /// Which cost model the sweep ran under (always "normalized": claims
    /// are scheduler properties, never calibration properties).
    pub cost_model: &'static str,
    pub outcomes: Vec<WorkloadOutcome>,
    pub verdicts: Vec<ClaimVerdict>,
}

impl ClaimsReport {
    pub fn all_hold(&self) -> bool {
        self.verdicts.iter().all(|v| v.holds)
    }

    pub fn failed(&self) -> Vec<&ClaimVerdict> {
        self.verdicts.iter().filter(|v| !v.holds).collect()
    }

    /// Machine-readable report, `BENCH_*.json`-style: one deterministic
    /// self-describing object.
    pub fn to_json(&self) -> Json {
        let workloads: Vec<Json> = self
            .outcomes
            .iter()
            .map(|o| {
                let systems: Vec<Json> = o
                    .systems
                    .iter()
                    .map(|s| {
                        let sweep: Vec<Json> = s
                            .sweep
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("rate_mult", Json::Num(p.rate_mult)),
                                    ("rate", Json::Num(p.rate)),
                                    ("slo_attainment", Json::Num(p.report.slo_attainment)),
                                    ("goodput_tokens", Json::Num(p.report.goodput_tokens)),
                                    ("token_throughput", Json::Num(p.report.token_throughput)),
                                    ("p90_ttft", Json::Num(p.report.p90_ttft)),
                                    ("p90_tpot", Json::Num(p.report.p90_tpot)),
                                    ("n_finished", Json::Num(p.report.n_finished as f64)),
                                    ("n_failed", Json::Num(p.report.n_failed as f64)),
                                ])
                            })
                            .collect();
                        Json::obj(vec![
                            ("system", Json::Str(s.system.label().into())),
                            ("max_sustainable_rate", Json::Num(s.max_sustainable)),
                            ("sweep", Json::Arr(sweep)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("trace", Json::Str(o.workload.clone())),
                    ("ttft_slo", Json::Num(o.ttft_slo)),
                    ("tpot_slo", Json::Num(o.tpot_slo)),
                    ("base_rate", Json::Num(o.base_rate)),
                    ("n_requests", Json::Num(o.n_requests as f64)),
                    ("stress_mult", Json::Num(o.stress_mult)),
                    ("systems", Json::Arr(systems)),
                ])
            })
            .collect();
        let verdicts: Vec<Json> = self
            .verdicts
            .iter()
            .map(|v| {
                Json::obj(vec![
                    ("workload", Json::Str(v.workload.clone())),
                    ("claim", Json::Str(v.claim.clone())),
                    ("holds", Json::Bool(v.holds)),
                    ("measured", Json::Num(v.measured)),
                    ("bound", Json::Num(v.bound)),
                    ("detail", Json::Str(v.detail.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("report", Json::Str("claims".into())),
            ("cost_model", Json::Str(self.cost_model.into())),
            ("seed", Json::Num(self.cfg.seed as f64)),
            ("clip_seconds", Json::Num(self.cfg.clip_seconds)),
            ("gpus", Json::Num(self.cfg.gpus as f64)),
            ("target", Json::Num(self.cfg.target)),
            ("tolerance", Json::Num(self.cfg.tolerance)),
            ("smoke", Json::Bool(self.cfg.smoke)),
            ("rate_mults", Json::arr_f64(&self.cfg.rate_mults)),
            ("workloads", Json::Arr(workloads)),
            ("claims", Json::Arr(verdicts)),
            ("all_hold", Json::Bool(self.all_hold())),
        ])
    }

    /// Human-readable summary (the `arrow claims` stdout table).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "Paper-claims conformance — {} cost model, {} mode ({} GPUs, seed {}, clip {:.0}s)",
            self.cost_model,
            if self.cfg.smoke { "smoke" } else { "full" },
            self.cfg.gpus,
            self.cfg.seed,
            self.cfg.clip_seconds,
        );
        for o in &self.outcomes {
            let _ = writeln!(
                s,
                "\n[{}] base {:.2} req/s, {} requests, SLO ttft {}s / tpot {}s, stress x{}",
                o.workload, o.base_rate, o.n_requests, o.ttft_slo, o.tpot_slo, o.stress_mult
            );
            let _ = writeln!(
                s,
                "  {:<14} {:>9} {:>11} {:>13} {:>10} {:>10}",
                "system", "max_rate", "att@stress", "goodput@strs", "p90_ttft", "p90_tpot"
            );
            for sys in &o.systems {
                let r = sys.at_mult(o.stress_mult);
                let _ = writeln!(
                    s,
                    "  {:<14} {:>9.2} {:>11.3} {:>13.1} {:>10.3} {:>10.4}",
                    sys.system.label(),
                    sys.max_sustainable,
                    r.slo_attainment,
                    r.goodput_tokens,
                    r.p90_ttft,
                    r.p90_tpot
                );
            }
        }
        let n_ok = self.verdicts.iter().filter(|v| v.holds).count();
        let _ = writeln!(s, "\nclaims: {}/{} hold", n_ok, self.verdicts.len());
        for v in &self.verdicts {
            let _ = writeln!(
                s,
                "  {} [{}] {} — {}",
                if v.holds { "ok  " } else { "FAIL" },
                v.workload,
                v.claim,
                v.detail
            );
        }
        s
    }
}

/// One simulated point: `system` on `trace` rescaled to `rate`, under the
/// workload's SLOs and the given cost model.
///
/// Streaming sweep path (PR 7): arrivals are rescaled on the fly
/// (`Scaled` applies exactly `with_rate`'s `arrival * k`) and completed
/// records fold into a constant-memory [`StreamingSlo`] sink — no
/// rescaled trace copy, no full record vector, no retained token times
/// per point. Counts/attainment/throughput are exact; the latency
/// percentiles are sketch estimates (tolerance-banded against the exact
/// oracle in `metrics::tests` and `tests/streaming.rs`).
fn run_point(
    sys: System,
    base: &CostModel,
    trace: &Trace,
    w: &Workload,
    gpus: usize,
    rate: f64,
) -> SloReport {
    let k = trace.rate() / rate;
    let mut src = Scaled::new(TraceSource::new(trace), k);
    let cl = build(sys, gpus, base, w.ttft_slo, w.tpot_slo, false);
    let mut slo = StreamingSlo::new(w.ttft_slo, w.tpot_slo);
    cl.run_streamed(&mut src, &mut |rec| slo.observe(&rec));
    // Same span as the materialized path: the rescaled trace's duration
    // is its last arrival times k, bit-identically.
    slo.report(trace.duration() * k)
}

/// Sweep every system over the grid for one workload, then search each
/// system's max sustainable rate.
fn sweep_workload(w: &Workload, base: &CostModel, cfg: &ClaimsConfig) -> WorkloadOutcome {
    assert!(!cfg.rate_mults.is_empty(), "claims need a non-empty rate grid");
    let trace = w.generate(cfg.seed).clip_seconds(cfg.clip_seconds);
    assert!(!trace.is_empty(), "workload {} clipped to nothing", w.name());
    let base_rate = trace.rate();

    // Grid sweep: system-major job order so the slices below line up.
    let jobs: Vec<(System, f64)> = System::all()
        .into_iter()
        .flat_map(|s| cfg.rate_mults.iter().map(move |&m| (s, m)))
        .collect();
    let reports = parallel_map(jobs, cfg.workers, |&(sys, m)| {
        run_point(sys, base, &trace, w, cfg.gpus, base_rate * m)
    });

    // Max-rate search per system (independently parallel; each search is
    // internally sequential by nature of bisection).
    let max_rates = parallel_map(System::all().to_vec(), cfg.workers, |&sys| {
        max_sustainable_rate(
            |rate| run_point(sys, base, &trace, w, cfg.gpus, rate),
            base_rate,
            cfg.target,
            cfg.rate_search_tolerance,
        )
    });

    let n_mults = cfg.rate_mults.len();
    let systems: Vec<SystemOutcome> = System::all()
        .into_iter()
        .enumerate()
        .map(|(si, sys)| SystemOutcome {
            system: sys,
            sweep: reports[si * n_mults..(si + 1) * n_mults]
                .iter()
                .zip(&cfg.rate_mults)
                .map(|(rep, &m)| SweepPoint {
                    rate_mult: m,
                    rate: base_rate * m,
                    report: rep.clone(),
                })
                .collect(),
            max_sustainable: max_rates[si],
        })
        .collect();

    // Stress point: lightest swept overload of the best static split.
    let best_static_att = |m: f64| {
        STATIC_SPLITS
            .iter()
            .map(|&s| {
                systems
                    .iter()
                    .find(|o| o.system == s)
                    .unwrap()
                    .at_mult(m)
                    .slo_attainment
            })
            .fold(0.0f64, f64::max)
    };
    let stress_mult = cfg
        .rate_mults
        .iter()
        .copied()
        .find(|&m| best_static_att(m) < cfg.target)
        .unwrap_or(*cfg.rate_mults.last().unwrap());

    WorkloadOutcome {
        workload: w.name().to_string(),
        ttft_slo: w.ttft_slo,
        tpot_slo: w.tpot_slo,
        base_rate,
        n_requests: trace.len(),
        systems,
        stress_mult,
    }
}

/// Evaluate the paper's ordering claims for one swept workload.
fn verdicts_for(o: &WorkloadOutcome, cfg: &ClaimsConfig) -> Vec<ClaimVerdict> {
    let mut out = Vec::new();
    let arrow = o.system(System::Arrow);

    // 1. Max-rate ordering: Arrow sustains >= every static split (band
    //    widened by the bisection quantization).
    for &s in &STATIC_SPLITS {
        let st = o.system(s);
        let bound = st.max_sustainable * cfg.rate_band();
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: format!("max_rate:arrow>={}", s.label()),
            holds: arrow.max_sustainable >= bound,
            measured: arrow.max_sustainable,
            bound,
            detail: format!(
                "arrow sustains {:.2} req/s vs {} {:.2} (band {:.2})",
                arrow.max_sustainable,
                s.label(),
                st.max_sustainable,
                cfg.rate_band()
            ),
        });
    }

    // 2. Goodput ordering at the stress point.
    let m = o.stress_mult;
    let a = arrow.at_mult(m);
    for &s in &STATIC_SPLITS {
        let sr = o.system(s).at_mult(m);
        let bound = sr.goodput_tokens * (1.0 - cfg.tolerance);
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: format!("goodput:arrow>={}@x{}", s.label(), m),
            holds: a.goodput_tokens >= bound,
            measured: a.goodput_tokens,
            bound,
            detail: format!(
                "arrow goodput {:.1} tok/s vs {} {:.1} at stress x{} (att {:.3} vs {:.3})",
                a.goodput_tokens,
                s.label(),
                sr.goodput_tokens,
                m,
                a.slo_attainment,
                sr.slo_attainment
            ),
        });
    }

    // 3. Degradation shapes, on the burst workload (§7.2 is an
    //    azure_code observation; the other traces don't saturate the
    //    TP=n colocated engine inside the swept grid).
    if o.workload == "azure_code" {
        let lo = *cfg.rate_mults.first().unwrap();
        let hi = *cfg.rate_mults.last().unwrap();
        let coloc = o.system(System::VllmColocated);
        let (cl, ch) = (coloc.at_mult(lo), coloc.at_mult(hi));
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: "colocated:ttft_inflates".into(),
            holds: ch.p90_ttft >= 3.0 * cl.p90_ttft,
            measured: ch.p90_ttft,
            bound: 3.0 * cl.p90_ttft,
            detail: format!(
                "colocated p90 TTFT {:.3}s at x{lo} -> {:.3}s at x{hi}",
                cl.p90_ttft, ch.p90_ttft
            ),
        });
        // meets_target-style inversion: these two are *upper* bounds, so
        // `measured`/`bound` are negated to keep "holds iff measured >=
        // bound" uniform for report consumers.
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: "colocated:tpot_stays_low".into(),
            holds: ch.p90_tpot <= o.tpot_slo,
            measured: -ch.p90_tpot,
            bound: -o.tpot_slo,
            detail: format!(
                "colocated p90 TPOT {:.4}s at x{hi} vs SLO {}s (decode priority)",
                ch.p90_tpot, o.tpot_slo
            ),
        });
        let ah = arrow.at_mult(hi);
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: "disagg:tpot_stable_past_saturation".into(),
            holds: ah.p90_tpot <= o.tpot_slo,
            measured: -ah.p90_tpot,
            bound: -o.tpot_slo,
            detail: format!(
                "arrow p90 TPOT {:.4}s at x{hi} vs SLO {}s (disaggregation isolates decode)",
                ah.p90_tpot, o.tpot_slo
            ),
        });
    }

    // 4. PR 10 scheduling adversaries. `deflect:*`/`unified:*` claims
    //    are excluded from benchdiff's core-claims headline (same
    //    mechanism as `slo_class:*`) so pre-PR-10 baselines compare
    //    like-for-like; `arrow claims` and `tests/claims.rs` gate them.
    let deflect = o.system(System::Deflect);
    let unified = o.system(System::Unified);
    // Deflection is Arrow plus one strictly guarded extra move, so it
    // must sustain at least Arrow's rate (band-widened).
    let bound = arrow.max_sustainable * cfg.rate_band();
    out.push(ClaimVerdict {
        workload: o.workload.clone(),
        claim: "deflect:max_rate>=arrow".into(),
        holds: deflect.max_sustainable >= bound,
        measured: deflect.max_sustainable,
        bound,
        detail: format!(
            "deflect sustains {:.2} req/s vs arrow {:.2} (band {:.2})",
            deflect.max_sustainable,
            arrow.max_sustainable,
            cfg.rate_band()
        ),
    });
    // Arrow's adaptive flipping must at least match the unified-elastic
    // adversary — the paper's "adaptivity wins" ordering, now evaluated
    // against a non-straw-man baseline.
    let bound = unified.max_sustainable * cfg.rate_band();
    out.push(ClaimVerdict {
        workload: o.workload.clone(),
        claim: "unified:max_rate:arrow>=unified".into(),
        holds: arrow.max_sustainable >= bound,
        measured: arrow.max_sustainable,
        bound,
        detail: format!(
            "arrow sustains {:.2} req/s vs unified {:.2} (band {:.2})",
            arrow.max_sustainable,
            unified.max_sustainable,
            cfg.rate_band()
        ),
    });
    // Flip-window claim (burst workload): at the stress point deflection
    // absorbs small prefills inside the very window Arrow spends waiting
    // for a flip to drain, so its goodput must be at least Arrow's minus
    // tolerance.
    if o.workload == "azure_code" {
        let d = deflect.at_mult(m);
        let bound = a.goodput_tokens * (1.0 - cfg.tolerance);
        out.push(ClaimVerdict {
            workload: o.workload.clone(),
            claim: "deflect:flip_window:goodput>=arrow".into(),
            holds: d.goodput_tokens >= bound,
            measured: d.goodput_tokens,
            bound,
            detail: format!(
                "deflect goodput {:.1} tok/s vs arrow {:.1} at stress x{} (att {:.3} vs {:.3})",
                d.goodput_tokens, a.goodput_tokens, m, d.slo_attainment, a.slo_attainment
            ),
        });
    }
    out
}

/// PR 8 claim: at the workload's stress point, class-aware Arrow (SLO
/// classes steering placement, priority-ranked prefill queues, and
/// class-aware admission) attains at least what class-blind Arrow
/// attains on the *interactive* class, on a mixed-class twin of the
/// trace. "Shed the right work": degrading batch first must never come
/// at interactive's expense.
fn slo_class_verdict(
    w: &Workload,
    o: &WorkloadOutcome,
    base: &CostModel,
    cfg: &ClaimsConfig,
) -> ClaimVerdict {
    // Mixed-class twin of the swept trace: identical arrivals and
    // lengths, classes assigned by the deterministic id hash (~30%
    // interactive / 40% standard / 30% batch). Assignment is a pure
    // function of the request id — no trace RNG consumed — so both runs
    // below see byte-identical arrivals.
    let mix = ClassMix {
        interactive: 0.3,
        batch: 0.3,
    };
    let mut trace = w.generate(cfg.seed).clip_seconds(cfg.clip_seconds);
    for r in &mut trace.requests {
        r.class = mix.assign(r.id.0);
    }
    let rate = o.base_rate * o.stress_mult;
    let k = trace.rate() / rate;
    let span = trace.duration() * k;
    // In-system cap sized to bite only under overload: transparent at
    // sustainable rates (the gate is a no-op below the cap, pinned by
    // the sim tests), binding at the stress point.
    let cap = cfg.gpus * 16;
    let run = |class_aware: bool| -> SloReport {
        let mut src = Scaled::new(TraceSource::new(&trace), k);
        let mut adm = AdmissionControl::new(cap);
        adm.class_aware = class_aware;
        let cl =
            build_arrow_classed(cfg.gpus, base, w.ttft_slo, w.tpot_slo, class_aware, Some(adm));
        let mut slo = StreamingSlo::new(w.ttft_slo, w.tpot_slo);
        cl.run_streamed(&mut src, &mut |rec| slo.observe(&rec));
        slo.report(span)
    };
    let reports = parallel_map(vec![true, false], cfg.workers.min(2), |&aware| run(aware));
    let aware = reports[0].class_attainment(SloClass::Interactive);
    let blind = reports[1].class_attainment(SloClass::Interactive);
    let bound = blind - cfg.tolerance;
    ClaimVerdict {
        workload: o.workload.clone(),
        claim: "slo_class:interactive:aware>=blind".into(),
        holds: aware >= bound,
        measured: aware,
        bound,
        detail: format!(
            "interactive attainment {:.3} class-aware vs {:.3} class-blind at stress x{} (cap {})",
            aware, blind, o.stress_mult, cap
        ),
    }
}

/// Run the conformance sweep over an explicit workload list (the test
/// tiers use this to focus on one trace).
pub fn run_claims_for(workloads: &[Workload], cfg: &ClaimsConfig) -> ClaimsReport {
    let base = CostModel::normalized();
    let outcomes: Vec<WorkloadOutcome> = workloads
        .iter()
        .map(|w| sweep_workload(w, &base, cfg))
        .collect();
    let verdicts = workloads
        .iter()
        .zip(&outcomes)
        .flat_map(|(w, o)| {
            let mut vs = verdicts_for(o, cfg);
            vs.push(slo_class_verdict(w, o, &base, cfg));
            vs
        })
        .collect();
    ClaimsReport {
        cfg: cfg.clone(),
        cost_model: "normalized",
        outcomes,
        verdicts,
    }
}

/// Run the full conformance sweep: all eight systems × all Table-1
/// workloads × the configured rate grid.
pub fn run_claims(cfg: &ClaimsConfig) -> ClaimsReport {
    run_claims_for(&catalog::table1(), cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smallest meaningful config: one tiny clip, one rate point — unit
    /// tests only exercise plumbing; the claims *tier* does the real run.
    fn tiny_cfg() -> ClaimsConfig {
        ClaimsConfig {
            clip_seconds: 20.0,
            rate_mults: vec![2.0],
            rate_search_tolerance: 0.5,
            workers: 2,
            ..ClaimsConfig::smoke()
        }
    }

    #[test]
    fn sweep_covers_all_systems_and_accounts_every_request() {
        let w = catalog::by_name("smoke").unwrap();
        let report = run_claims_for(&[w], &tiny_cfg());
        assert_eq!(report.outcomes.len(), 1);
        let o = &report.outcomes[0];
        assert_eq!(o.systems.len(), System::all().len());
        for sys in &o.systems {
            assert_eq!(sys.sweep.len(), 1);
            let r = &sys.sweep[0].report;
            assert_eq!(
                r.n_finished + r.n_failed,
                r.n_requests,
                "{}: accounting",
                sys.system.label()
            );
            assert!(sys.max_sustainable >= 0.0);
            assert!(sys.max_sustainable.is_finite());
        }
        // Stress point is on the grid.
        assert!(report.cfg.rate_mults.contains(&o.stress_mult));
    }

    #[test]
    fn report_json_roundtrips_and_is_self_describing() {
        let w = catalog::by_name("smoke").unwrap();
        let report = run_claims_for(&[w], &tiny_cfg());
        let text = report.to_json().encode();
        let back = Json::parse(&text).expect("claims report must be valid JSON");
        assert_eq!(back.get("report").as_str(), Some("claims"));
        assert_eq!(back.get("cost_model").as_str(), Some("normalized"));
        assert_eq!(back.get("workloads").as_arr().unwrap().len(), 1);
        let w0 = &back.get("workloads").as_arr().unwrap()[0];
        assert_eq!(w0.get("systems").as_arr().unwrap().len(), System::all().len());
        assert!(back.get("claims").as_arr().is_some());
        assert!(back.get("all_hold").as_bool().is_some());
        // Summary renders every verdict.
        let s = report.summary();
        for v in &report.verdicts {
            assert!(s.contains(&v.claim), "summary missing claim {}", v.claim);
        }
    }

    #[test]
    fn configs_are_sane() {
        for cfg in [ClaimsConfig::full(), ClaimsConfig::smoke()] {
            assert!(!cfg.rate_mults.is_empty());
            assert!(cfg.rate_mults.windows(2).all(|w| w[0] < w[1]), "grid sorted");
            assert!(cfg.clip_seconds > 0.0);
            assert!((0.0..1.0).contains(&cfg.tolerance));
            assert!(cfg.rate_band() > 0.5, "claim band degenerated");
        }
        assert!(ClaimsConfig::smoke().clip_seconds < ClaimsConfig::full().clip_seconds);
    }

    #[test]
    fn verdicts_cover_the_burst_claims_for_azure_code() {
        // Claim *presence* is part of the contract (a refactor that
        // silently stops evaluating a claim must fail here); claim
        // *truth* on the real grid is tests/claims.rs territory.
        let w = catalog::by_name("azure_code").unwrap();
        let cfg = ClaimsConfig {
            clip_seconds: 30.0,
            ..tiny_cfg()
        };
        let report = run_claims_for(&[w], &cfg);
        let names: Vec<&str> = report.verdicts.iter().map(|v| v.claim.as_str()).collect();
        for split in STATIC_SPLITS {
            assert!(
                names.iter().any(|n| *n == format!("max_rate:arrow>={}", split.label())),
                "missing max-rate claim for {}",
                split.label()
            );
        }
        assert!(names.contains(&"colocated:ttft_inflates"));
        assert!(names.contains(&"colocated:tpot_stays_low"));
        assert!(names.contains(&"disagg:tpot_stable_past_saturation"));
        assert!(names.contains(&"slo_class:interactive:aware>=blind"));
        // PR 10 adversary claims: the flip-window verdict is burst-only,
        // the max-rate orderings exist per workload.
        assert!(names.contains(&"deflect:flip_window:goodput>=arrow"));
        assert!(names.contains(&"deflect:max_rate>=arrow"));
        assert!(names.contains(&"unified:max_rate:arrow>=unified"));
    }

    #[test]
    fn slo_class_claim_reports_real_attainments() {
        // The verdict's measured/bound are attainments (plus tolerance
        // slack), so they must be probabilities, and the claim must be
        // present exactly once per workload.
        let w = catalog::by_name("smoke").unwrap();
        let report = run_claims_for(&[w], &tiny_cfg());
        let vs: Vec<_> = report
            .verdicts
            .iter()
            .filter(|v| v.claim.starts_with("slo_class:"))
            .collect();
        assert_eq!(vs.len(), 1);
        let v = vs[0];
        assert!((0.0..=1.0).contains(&v.measured), "attainment {}", v.measured);
        assert!(v.bound <= 1.0, "bound {}", v.bound);
    }
}
