//! Open-loop soak harness (PR 9): `arrow loadgen`.
//!
//! Drives `/v1/completions` with open-loop Poisson arrivals — the pacer
//! sends on the arrival clock regardless of how the server is doing, so
//! an overloaded server sees the queue it would see in production
//! instead of the closed-loop mercy of a client that waits for each
//! response. SLO classes ride along (`--mix`), every sent request is
//! accounted into exactly one ledger bucket
//! (`ok/shed/deadline/client-err/conn-err` — sent must equal the sum, so
//! silent loss is a hard failure), `/metrics` is scraped before and
//! after to cross-check the server's shed ledger against the client's,
//! and the result is emitted as `BENCH_server.json` for the benchdiff
//! trajectory (sustained RPS higher-is-better, p99 TTFT
//! lower-is-better).
//!
//! `--self-test` runs the whole pipeline against an in-process stub
//! server with a deterministic shed/error schedule — no artifacts, no
//! live cluster — which is what ci.sh smokes. Wall-clock time is fine
//! here (unlike the flight recorder's no-wall-clock rule): this is the
//! measuring client, not the deterministic record.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::http::{self, HttpResponse};
use crate::json::Json;
use crate::request::SloClass;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

/// `arrow loadgen` configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Base URL of the server under test, e.g. `http://127.0.0.1:8080`.
    pub url: String,
    /// Offered (open-loop Poisson) request rate.
    pub rps: f64,
    /// Length of the send window in seconds; workers drain afterwards.
    pub duration_s: f64,
    pub seed: u64,
    /// Worker threads issuing the paced requests. Workers bound the
    /// request *concurrency*, never the arrival process — arrivals queue
    /// when all workers are busy, exactly like an external load source.
    pub workers: usize,
    /// Class weights [interactive, standard, batch] for `Rng::weighted`.
    pub class_mix: [f64; 3],
    /// SLO targets used for the client-side attainment proxy: an ok
    /// request attains its SLO when total latency is within
    /// `ttft_slo + max_tokens * tpot_slo`.
    pub ttft_slo: f64,
    pub tpot_slo: f64,
    /// Where to write the `BENCH_server.json` report (skipped if None).
    pub out: Option<String>,
    /// Mark the emitted report as a smoke-regime run (benchdiff refuses
    /// cross-regime diffs, same convention as the cargo benches).
    pub smoke: bool,
    /// Run against the in-process stub server instead of `url`.
    pub self_test: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            url: "http://127.0.0.1:8080".into(),
            rps: 8.0,
            duration_s: 10.0,
            seed: 42,
            workers: 8,
            class_mix: [0.3, 0.5, 0.2],
            ttft_slo: 2.0,
            tpot_slo: 0.5,
            out: None,
            smoke: false,
            self_test: false,
        }
    }
}

/// One paced request, fully determined by the seed before sending starts.
#[derive(Debug, Clone)]
struct Planned {
    /// Offset of the arrival from the start of the send window.
    at_s: f64,
    class: SloClass,
    tokens: Vec<i64>,
    max_tokens: u64,
}

/// Where a sent request ended up. Every request lands in exactly one
/// bucket; `sent == sum(buckets)` is the no-silent-loss invariant.
#[derive(Debug, Default)]
struct Ledger {
    ok: u64,
    /// 503 admission sheds, by class index.
    shed: [u64; 3],
    /// 504 deadline expiries.
    deadline: u64,
    /// Any other HTTP status (4xx validation, 5xx handler faults).
    client_err: u64,
    /// Connect/socket failures and unparseable responses.
    conn_err: u64,
    /// Client-observed total latency of each ok request, seconds.
    latencies: Vec<f64>,
    /// Ok requests inside their latency budget (SLO attainment proxy).
    attained: u64,
}

impl Ledger {
    fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }
    fn accounted(&self) -> u64 {
        self.ok + self.shed_total() + self.deadline + self.client_err + self.conn_err
    }
}

/// The soak verdict. `ok()` is what `arrow loadgen` exits on.
#[derive(Debug)]
pub struct LoadReport {
    pub mode: &'static str,
    pub sent: u64,
    pub ok: u64,
    pub shed_by_class: [u64; 3],
    pub deadline: u64,
    pub client_err: u64,
    pub conn_err: u64,
    /// Completed-ok throughput over the whole run (send + drain).
    pub sustained_rps: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Fraction of ok requests inside their latency budget.
    pub slo_attainment: f64,
    /// Server-reported p99 TTFT from the closing `/metrics` scrape
    /// (NaN when the scrape failed).
    pub server_p99_ttft_s: f64,
    /// Server-side shed growth across the run (closing minus opening
    /// scrape), summed over classes; NaN when either scrape failed.
    pub server_shed_delta: f64,
    /// Violated invariants; empty means the soak passed.
    pub failures: Vec<String>,
}

impl LoadReport {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "loadgen [{}]: {} sent = {} ok + {} shed + {} deadline + {} client-err + {} conn-err\n",
            self.mode,
            self.sent,
            self.ok,
            self.shed_by_class.iter().sum::<u64>(),
            self.deadline,
            self.client_err,
            self.conn_err,
        ));
        s.push_str(&format!(
            "  shed by class: interactive {} / standard {} / batch {}\n",
            self.shed_by_class[0], self.shed_by_class[1], self.shed_by_class[2]
        ));
        s.push_str(&format!(
            "  sustained {:.2} req/s, latency p50 {:.4}s p99 {:.4}s, SLO attainment {:.3}\n",
            self.sustained_rps, self.p50_latency_s, self.p99_latency_s, self.slo_attainment
        ));
        if self.server_p99_ttft_s.is_finite() {
            s.push_str(&format!(
                "  server: p99 TTFT {:.4}s, shed delta {:.0}\n",
                self.server_p99_ttft_s, self.server_shed_delta
            ));
        }
        for f in &self.failures {
            s.push_str(&format!("  FAIL: {f}\n"));
        }
        s
    }

    fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("server".into())),
            ("smoke", Json::Bool(cfg.smoke)),
            ("mode", Json::Str(self.mode.into())),
            ("rps_offered", Json::Num(cfg.rps)),
            ("duration_s", Json::Num(cfg.duration_s)),
            ("seed", Json::Num(cfg.seed as f64)),
            ("workers", Json::Num(cfg.workers as f64)),
            ("ttft_slo", Json::Num(cfg.ttft_slo)),
            ("tpot_slo", Json::Num(cfg.tpot_slo)),
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            (
                "shed_by_class",
                Json::obj(
                    SloClass::ALL
                        .iter()
                        .zip(self.shed_by_class)
                        .map(|(c, n)| (c.label(), Json::Num(n as f64)))
                        .collect(),
                ),
            ),
            ("deadline", Json::Num(self.deadline as f64)),
            ("client_err", Json::Num(self.client_err as f64)),
            ("conn_err", Json::Num(self.conn_err as f64)),
            ("sustained_rps", Json::Num(self.sustained_rps)),
            ("p50_latency_s", Json::Num(self.p50_latency_s)),
            ("p99_latency_s", Json::Num(self.p99_latency_s)),
            ("slo_attainment", Json::Num(self.slo_attainment)),
            // NaN encodes as JSON null (scrape unavailable).
            ("p99_ttft_s", Json::Num(self.server_p99_ttft_s)),
            ("server_shed_delta", Json::Num(self.server_shed_delta)),
            ("passed", Json::Bool(self.ok())),
        ])
    }
}

/// Plan the whole arrival schedule up front — deterministic in the seed,
/// independent of how the run goes.
fn plan(cfg: &LoadgenConfig) -> Vec<Planned> {
    let mut rng = Rng::new(cfg.seed ^ 0x10ad_6e4e);
    let mut out = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exp(cfg.rps.max(1e-9));
        if t > cfg.duration_s {
            return out;
        }
        let class = SloClass::ALL[rng.weighted(&cfg.class_mix)];
        // Log-normal prompt lengths (heavy-tailed, like the paper's
        // traces), clamped to something a stub engine finishes quickly.
        let input_len = (rng.lognormal(3.0, 0.8) as i64).clamp(2, 256);
        let tokens: Vec<i64> = (0..input_len).map(|_| rng.int_range(1, 999)).collect();
        let max_tokens = rng.int_range(1, 8) as u64;
        out.push(Planned {
            at_s: t,
            class,
            tokens,
            max_tokens,
        });
    }
}

/// Raw HTTP/1.1 POST over a fresh connection (the server speaks
/// Connection: close). Returns (status, body) or None on socket failure.
fn post_completions(addr: &str, body: &str, timeout: Duration) -> Option<(u16, String)> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(timeout)).ok();
    s.set_write_timeout(Some(timeout)).ok();
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(raw.as_bytes()).ok()?;
    let mut out = String::new();
    s.read_to_string(&mut out).ok()?;
    let status: u16 = out
        .strip_prefix("HTTP/1.1 ")?
        .split_whitespace()
        .next()?
        .parse()
        .ok()?;
    let body = out.split_once("\r\n\r\n").map(|x| x.1.to_string())?;
    Some((status, body))
}

/// Scrape `/metrics`; None when unreachable or unparseable.
fn scrape_metrics(addr: &str) -> Option<Json> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(10))).ok();
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").ok()?;
    let mut out = String::new();
    s.read_to_string(&mut out).ok()?;
    Json::parse(out.split_once("\r\n\r\n")?.1).ok()
}

fn shed_sum(metrics: &Json) -> f64 {
    SloClass::ALL
        .iter()
        .filter_map(|c| metrics.get("shed_by_class").get(c.label()).as_f64())
        .sum()
}

/// Deterministic stub server for `--self-test`: sequence number `i`
/// (assigned per arriving request) answers 500 when `i % 13 == 0`, 503
/// when `i % 5 == 0`, 200 otherwise — so the expected ledger is a pure
/// function of how many requests arrive, and the stub's own shed
/// counters must match the client's 503 count exactly.
struct StubServer {
    addr: String,
    shutdown: Arc<AtomicBool>,
}

impl StubServer {
    fn start() -> Result<StubServer, String> {
        // Bind :0 to learn a free port, then serve on it (http::serve
        // binds by string address, same idiom as the http tests).
        let probe = TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
        let addr = probe.local_addr().map_err(|e| e.to_string())?.to_string();
        drop(probe);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let seq = AtomicU64::new(0);
        let completed = Arc::new(AtomicU64::new(0));
        let shed: Arc<[AtomicU64; 3]> = Arc::new(Default::default());
        let a = addr.clone();
        std::thread::spawn(move || {
            http::serve(&a, sd, move |req| match (req.method.as_str(), req.path.as_str()) {
                ("POST", "/v1/completions") => {
                    let i = seq.fetch_add(1, Ordering::Relaxed);
                    if i % 13 == 0 {
                        return HttpResponse::json(500, "{\"error\":\"stub fault\"}");
                    }
                    if i % 5 == 0 {
                        let class = Json::parse(&req.body_str())
                            .ok()
                            .and_then(|b| {
                                b.get("class").as_str().and_then(SloClass::from_label)
                            })
                            .unwrap_or(SloClass::Standard);
                        shed[class.index()].fetch_add(1, Ordering::Relaxed);
                        return HttpResponse::json(503, "{\"error\":\"queue full\"}");
                    }
                    completed.fetch_add(1, Ordering::Relaxed);
                    HttpResponse::json(200, "{\"tokens\":[1],\"latency_s\":0.001}")
                }
                ("GET", "/metrics") => {
                    let body = Json::obj(vec![
                        (
                            "completed_requests",
                            Json::Num(completed.load(Ordering::Relaxed) as f64),
                        ),
                        ("p99_ttft_s", Json::Num(0.001)),
                        ("p99_tpot_s", Json::Num(0.001)),
                        (
                            "shed_by_class",
                            Json::obj(
                                SloClass::ALL
                                    .iter()
                                    .map(|c| {
                                        (
                                            c.label(),
                                            Json::Num(
                                                shed[c.index()].load(Ordering::Relaxed) as f64,
                                            ),
                                        )
                                    })
                                    .collect(),
                            ),
                        ),
                    ]);
                    HttpResponse::json(200, &body.encode())
                }
                _ => HttpResponse::not_found(),
            })
        });
        // Wait for the listener to come up.
        let t0 = Instant::now();
        loop {
            if TcpStream::connect(&addr).is_ok() {
                return Ok(StubServer { addr, shutdown });
            }
            if t0.elapsed() > Duration::from_secs(10) {
                return Err("self-test stub server never came up".into());
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for StubServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
    }
}

/// Run the soak. Errors are setup problems (bad URL, stub failure);
/// soak verdicts live in the returned report's `failures`.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport, String> {
    let stub = if cfg.self_test {
        Some(StubServer::start()?)
    } else {
        None
    };
    let addr = match &stub {
        Some(s) => s.addr.clone(),
        None => cfg
            .url
            .strip_prefix("http://")
            .ok_or("only http:// URLs are supported")?
            .trim_end_matches('/')
            .to_string(),
    };

    let schedule = plan(cfg);
    let sent = schedule.len() as u64;
    let before = scrape_metrics(&addr);

    let ledger = Arc::new(Mutex::new(Ledger::default()));
    let (tx, rx) = mpsc::channel::<Planned>();
    let rx = Arc::new(Mutex::new(rx));
    let deadline = Duration::from_secs_f64((cfg.ttft_slo + 8.0 * cfg.tpot_slo).max(30.0));
    let mut workers = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let rx = Arc::clone(&rx);
        let ledger = Arc::clone(&ledger);
        let addr = addr.clone();
        let (ttft_slo, tpot_slo) = (cfg.ttft_slo, cfg.tpot_slo);
        workers.push(std::thread::spawn(move || loop {
            // Hold the receiver lock only long enough to pull one job.
            let job = match rx.lock().unwrap_or_else(|e| e.into_inner()).recv() {
                Ok(j) => j,
                Err(_) => return,
            };
            let toks: Vec<String> = job.tokens.iter().map(|t| t.to_string()).collect();
            let body = format!(
                "{{\"tokens\":[{}],\"max_tokens\":{},\"class\":\"{}\"}}",
                toks.join(","),
                job.max_tokens,
                job.class.label()
            );
            let t0 = Instant::now();
            let resp = post_completions(&addr, &body, deadline);
            let dt = t0.elapsed().as_secs_f64();
            let mut l = ledger.lock().unwrap_or_else(|e| e.into_inner());
            match resp {
                Some((200, _)) => {
                    l.ok += 1;
                    l.latencies.push(dt);
                    if dt <= ttft_slo + job.max_tokens as f64 * tpot_slo {
                        l.attained += 1;
                    }
                }
                Some((503, _)) => l.shed[job.class.index()] += 1,
                Some((504, _)) => l.deadline += 1,
                Some(_) => l.client_err += 1,
                None => l.conn_err += 1,
            }
        }));
    }

    // The pacer: send on the arrival clock, never on the response clock.
    let t0 = Instant::now();
    for job in schedule {
        let target = Duration::from_secs_f64(job.at_s);
        if let Some(wait) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        // A full channel is impossible (unbounded); a closed one means
        // every worker died, which the balance check below will surface.
        let _ = tx.send(job);
    }
    drop(tx);
    for w in workers {
        let _ = w.join();
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);

    let after = scrape_metrics(&addr);
    let l = Arc::try_unwrap(ledger)
        .map_err(|_| "worker leaked the ledger")?
        .into_inner()
        .unwrap_or_else(|e| e.into_inner());

    let server_shed_delta = match (&before, &after) {
        (Some(b), Some(a)) => shed_sum(a) - shed_sum(b),
        _ => f64::NAN,
    };
    let mut report = LoadReport {
        mode: if cfg.self_test { "self-test" } else { "live" },
        sent,
        ok: l.ok,
        shed_by_class: l.shed,
        deadline: l.deadline,
        client_err: l.client_err,
        conn_err: l.conn_err,
        sustained_rps: l.ok as f64 / elapsed,
        p50_latency_s: percentile(&l.latencies, 50.0),
        p99_latency_s: percentile(&l.latencies, 99.0),
        slo_attainment: if l.ok > 0 {
            l.attained as f64 / l.ok as f64
        } else {
            f64::NAN
        },
        server_p99_ttft_s: after
            .as_ref()
            .and_then(|m| m.get("p99_ttft_s").as_f64())
            .unwrap_or(f64::NAN),
        server_shed_delta,
        failures: Vec::new(),
    };

    // No silent loss: every planned arrival must be accounted somewhere.
    if l.accounted() != sent {
        report.failures.push(format!(
            "silent loss: sent {} but accounted {}",
            sent,
            l.accounted()
        ));
    }
    // Shed accounting: the server must have counted at least as many
    // sheds as we observed as 503s (exactly as many under --self-test,
    // where we are the only client).
    if server_shed_delta.is_finite() {
        let client_shed = l.shed_total() as f64;
        let consistent = if cfg.self_test {
            server_shed_delta == client_shed
        } else {
            server_shed_delta >= client_shed
        };
        if !consistent {
            report.failures.push(format!(
                "shed accounting mismatch: client observed {client_shed} 503s, \
                 server shed ledger grew by {server_shed_delta}"
            ));
        }
    } else if cfg.self_test {
        report
            .failures
            .push("self-test /metrics scrape failed".into());
    }
    // SLO attainment: ok requests must land inside their latency budget.
    // The stub answers instantly, so self-test demands (near-)perfect
    // attainment; a live soak tolerates a 10% tail.
    let min_attainment = if cfg.self_test { 0.99 } else { 0.90 };
    if report.slo_attainment.is_finite() && report.slo_attainment < min_attainment {
        report.failures.push(format!(
            "SLO attainment {:.3} below {min_attainment}",
            report.slo_attainment
        ));
    }
    if cfg.self_test && l.conn_err > 0 {
        report
            .failures
            .push(format!("{} connection errors against the in-process stub", l.conn_err));
    }

    if let Some(out) = &cfg.out {
        std::fs::write(out, report.to_json(cfg).encode()).map_err(|e| e.to_string())?;
        println!("loadgen: wrote {out}");
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planning_is_deterministic_in_the_seed() {
        let cfg = LoadgenConfig {
            rps: 50.0,
            duration_s: 2.0,
            ..Default::default()
        };
        let a = plan(&cfg);
        let b = plan(&cfg);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.max_tokens, y.max_tokens);
            assert_eq!(x.class, y.class);
        }
        let c = plan(&LoadgenConfig {
            seed: 43,
            ..cfg.clone()
        });
        assert!(
            a.len() != c.len()
                || a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens),
            "different seeds must plan different schedules"
        );
    }

    #[test]
    fn self_test_soak_passes_and_balances() {
        let cfg = LoadgenConfig {
            rps: 200.0,
            duration_s: 1.0,
            workers: 4,
            self_test: true,
            ..Default::default()
        };
        let report = run(&cfg).expect("self-test runs");
        assert!(report.ok(), "failures: {:?}", report.failures);
        assert!(report.sent > 0);
        assert!(report.ok > 0);
        // The stub sheds every 5th non-faulted arrival — some sheds must
        // have been observed and cross-checked against the stub's ledger.
        assert!(report.shed_by_class.iter().sum::<u64>() > 0);
        assert_eq!(
            report.sent,
            report.ok
                + report.shed_by_class.iter().sum::<u64>()
                + report.deadline
                + report.client_err
                + report.conn_err
        );
    }
}
