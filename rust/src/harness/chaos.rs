//! Chaos conformance harness (PR 6 — ROADMAP "Robustness architecture").
//!
//! The claims harness proves Arrow schedules *well*; this module proves
//! it degrades *honestly*. It sweeps seeded, fully deterministic
//! [`FaultPlan`]s of increasing intensity through the recovery-armed
//! Arrow cluster ([`crate::scenarios::arrow_chaos`]) under the
//! dimensionless [`CostModel::normalized`] preset and turns the PR 6
//! robustness contracts into machine-checkable verdicts:
//!
//! * **no silent loss** — under any fault plan, every request either
//!   finishes or is explicitly shed with a recorded [`ShedReason`]; a
//!   `Failed` record without a reason is a bug, full stop;
//! * **determinism** — the same seed produces byte-identical schedules
//!   in the calendar-cursor and heap-reference event loops, faults
//!   included (chaos runs must be replayable to be debuggable);
//! * **goodput bound** — injecting faults never *increases* goodput
//!   beyond a tolerance band (a violation means the fault machinery
//!   perturbs fault-free scheduling, which the golden digests forbid);
//! * **recovery** — requests arriving after the plan's recovery horizon
//!   (all faults clear by 0.75 × duration) complete at close to the
//!   fault-free tail rate: faults must not leave permanent scar tissue.
//!
//! `tests/chaos.rs` asserts the verdicts; `arrow chaos` emits the full
//! machine-readable report (`chaos.json`, same `BENCH_*.json`-style
//! conventions as the claims report) and exits non-zero when a verdict
//! fails, which is how ci.sh gates it.

use crate::costmodel::CostModel;
use crate::fault::FaultPlan;
use crate::json::Json;
use crate::metrics::SloReport;
use crate::request::{RequestRecord, RequestState, ShedReason};
use crate::scenarios::arrow_chaos;
use crate::trace::catalog::{self, Workload};
use crate::util::threads::{default_workers, parallel_map};

/// `ARROW_CHAOS_SMOKE` (the ci.sh knob): truthy when set to anything but
/// "0"/empty — same convention as `ARROW_CLAIMS_SMOKE`.
pub fn smoke_env() -> bool {
    std::env::var("ARROW_CHAOS_SMOKE").map_or(false, |v| v != "0" && !v.is_empty())
}

/// Sweep parameters for one chaos run.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub seed: u64,
    /// Clip the trace to this many seconds before injecting faults.
    pub clip_seconds: f64,
    pub gpus: usize,
    /// Fault intensities swept. 0.0 (required, first) is the fault-free
    /// baseline; intensity `i` seeds `round(4·i)` faults.
    pub intensities: Vec<f64>,
    /// Goodput tolerance band: a faulted run may exceed the fault-free
    /// baseline by this fraction before the bound verdict fails (absorbs
    /// shed-vs-finished discretization, not real inversions).
    pub tolerance: f64,
    /// Allowed absolute drop in post-horizon completion rate vs the
    /// fault-free baseline (residual backlog drains, it does not linger).
    pub recovery_band: f64,
    pub workers: usize,
    pub smoke: bool,
}

impl ChaosConfig {
    /// The full sweep `arrow chaos` runs by default.
    pub fn full() -> ChaosConfig {
        ChaosConfig {
            seed: 42,
            clip_seconds: 120.0,
            gpus: 8,
            intensities: vec![0.0, 0.5, 1.0, 2.0],
            tolerance: 0.05,
            recovery_band: 0.25,
            workers: default_workers(),
            smoke: false,
        }
    }

    /// CI-budget variant (`ARROW_CHAOS_SMOKE=1`): shorter clip, two
    /// intensities — the same invariants, evaluated inside the bench-gate
    /// time budget.
    pub fn smoke() -> ChaosConfig {
        ChaosConfig {
            clip_seconds: 60.0,
            intensities: vec![0.0, 1.0],
            smoke: true,
            ..ChaosConfig::full()
        }
    }

    /// Full or smoke, per the `ARROW_CHAOS_SMOKE` environment knob.
    pub fn from_env() -> ChaosConfig {
        if smoke_env() {
            ChaosConfig::smoke()
        } else {
            ChaosConfig::full()
        }
    }
}

/// One (intensity, run) sweep point with its robustness accounting.
#[derive(Debug, Clone)]
pub struct ChaosPoint {
    pub intensity: f64,
    /// Faults in the seeded plan at this intensity.
    pub n_faults: usize,
    pub report: SloReport,
    /// Failed records with no recorded shed reason — silently lost.
    /// The contract is that this is always zero.
    pub silently_lost: usize,
    /// Explicit sheds by reason:
    /// [NoCapacity, Oversized, TransferTimeout, DeadlineExceeded].
    pub shed: [usize; 4],
    /// Completion rate of requests arriving after the recovery horizon
    /// (0.75 × duration, when every fault has cleared). 1.0 when the
    /// clip leaves no tail arrivals.
    pub tail_completion: f64,
    /// Cursor and heap-reference event loops produced byte-identical
    /// schedules for this seed.
    pub deterministic: bool,
}

/// One robustness invariant, evaluated: `holds` iff `measured >= bound`.
#[derive(Debug, Clone)]
pub struct ChaosVerdict {
    pub claim: String,
    pub holds: bool,
    pub measured: f64,
    pub bound: f64,
    pub detail: String,
}

/// The full chaos report: sweep points plus verdicts.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub cfg: ChaosConfig,
    /// Always "normalized": robustness is a scheduler property, never a
    /// calibration property.
    pub cost_model: &'static str,
    pub workload: String,
    pub points: Vec<ChaosPoint>,
    pub verdicts: Vec<ChaosVerdict>,
}

fn shed_index(r: ShedReason) -> usize {
    match r {
        ShedReason::NoCapacity => 0,
        ShedReason::Oversized => 1,
        ShedReason::TransferTimeout => 2,
        ShedReason::DeadlineExceeded => 3,
    }
}

/// Byte-identity of two runs' request schedules (the same fields the
/// cross-substrate tier compares, plus the shed reasons).
fn records_identical(a: &[RequestRecord], b: &[RequestRecord]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.state == y.state
                && x.token_times == y.token_times
                && x.prefill_instance == y.prefill_instance
                && x.decode_instance == y.decode_instance
                && x.shed == y.shed
        })
}

/// Run one intensity point: seeded plan, both event-loop modes, full
/// robustness accounting.
fn run_point(w: &Workload, cfg: &ChaosConfig, intensity: f64) -> ChaosPoint {
    let base = CostModel::normalized();
    let trace = w.generate(cfg.seed).clip_seconds(cfg.clip_seconds);
    assert!(!trace.is_empty(), "workload {} clipped to nothing", w.name());
    let duration = trace.duration();
    // Per-intensity fault seed: deterministic, distinct per point.
    let plan = FaultPlan::seeded(cfg.seed ^ intensity.to_bits(), cfg.gpus, duration, intensity);

    let mut cursor = arrow_chaos(cfg.gpus, &base, w.ttft_slo, w.tpot_slo);
    cursor.schedule_fault_plan(&plan);
    let res = cursor.run(&trace);
    let mut reference = arrow_chaos(cfg.gpus, &base, w.ttft_slo, w.tpot_slo);
    reference.schedule_fault_plan(&plan);
    let ref_res = reference.run_reference(&trace);
    let deterministic = res.events_processed == ref_res.events_processed
        && records_identical(&res.records, &ref_res.records);

    let mut silently_lost = 0usize;
    let mut shed = [0usize; 4];
    for r in &res.records {
        if r.state == RequestState::Failed {
            match r.shed {
                Some(reason) => shed[shed_index(reason)] += 1,
                None => silently_lost += 1,
            }
        }
    }
    let horizon = 0.75 * duration;
    let tail: Vec<&RequestRecord> =
        res.records.iter().filter(|r| r.arrival > horizon).collect();
    let tail_completion = if tail.is_empty() {
        1.0
    } else {
        tail.iter().filter(|r| r.finished()).count() as f64 / tail.len() as f64
    };

    ChaosPoint {
        intensity,
        n_faults: plan.len(),
        report: SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, duration),
        silently_lost,
        shed,
        tail_completion,
        deterministic,
    }
}

/// Evaluate the robustness invariants over a sweep.
fn verdicts_for(points: &[ChaosPoint], cfg: &ChaosConfig) -> Vec<ChaosVerdict> {
    let mut out = Vec::new();
    let baseline = &points[0];
    assert!(
        baseline.intensity == 0.0,
        "the first intensity must be the fault-free baseline"
    );
    for p in points {
        out.push(ChaosVerdict {
            claim: format!("no_silent_loss@x{}", p.intensity),
            holds: p.silently_lost == 0,
            measured: -(p.silently_lost as f64),
            bound: 0.0,
            detail: format!(
                "{} silently lost of {} requests ({} faults, shed {:?})",
                p.silently_lost, p.report.n_requests, p.n_faults, p.shed
            ),
        });
        out.push(ChaosVerdict {
            claim: format!("deterministic@x{}", p.intensity),
            holds: p.deterministic,
            measured: if p.deterministic { 1.0 } else { 0.0 },
            bound: 1.0,
            detail: format!(
                "cursor vs heap-reference schedules at intensity {} ({} faults)",
                p.intensity, p.n_faults
            ),
        });
    }
    for p in &points[1..] {
        let bound = p.report.goodput_tokens;
        let measured = baseline.report.goodput_tokens * (1.0 + cfg.tolerance) + 1e-6;
        out.push(ChaosVerdict {
            claim: format!("goodput_bound@x{}", p.intensity),
            holds: measured >= bound,
            measured,
            bound,
            detail: format!(
                "fault-free goodput {:.1} tok/s (band +{:.0}%) vs faulted {:.1} at intensity {}",
                baseline.report.goodput_tokens,
                cfg.tolerance * 100.0,
                p.report.goodput_tokens,
                p.intensity
            ),
        });
        let bound = baseline.tail_completion - cfg.recovery_band;
        out.push(ChaosVerdict {
            claim: format!("recovery@x{}", p.intensity),
            holds: p.tail_completion >= bound,
            measured: p.tail_completion,
            bound,
            detail: format!(
                "post-horizon completion {:.3} vs fault-free {:.3} (band {:.2}) at intensity {}",
                p.tail_completion, baseline.tail_completion, cfg.recovery_band, p.intensity
            ),
        });
    }
    out
}

impl ChaosReport {
    pub fn all_hold(&self) -> bool {
        self.verdicts.iter().all(|v| v.holds)
    }

    pub fn failed(&self) -> Vec<&ChaosVerdict> {
        self.verdicts.iter().filter(|v| !v.holds).collect()
    }

    /// Machine-readable report, `BENCH_*.json`-style: one deterministic
    /// self-describing object.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("intensity", Json::Num(p.intensity)),
                    ("n_faults", Json::Num(p.n_faults as f64)),
                    ("goodput_tokens", Json::Num(p.report.goodput_tokens)),
                    ("slo_attainment", Json::Num(p.report.slo_attainment)),
                    ("n_finished", Json::Num(p.report.n_finished as f64)),
                    ("n_failed", Json::Num(p.report.n_failed as f64)),
                    ("silently_lost", Json::Num(p.silently_lost as f64)),
                    ("shed_no_capacity", Json::Num(p.shed[0] as f64)),
                    ("shed_oversized", Json::Num(p.shed[1] as f64)),
                    ("shed_transfer_timeout", Json::Num(p.shed[2] as f64)),
                    ("shed_deadline", Json::Num(p.shed[3] as f64)),
                    ("tail_completion", Json::Num(p.tail_completion)),
                    ("deterministic", Json::Bool(p.deterministic)),
                ])
            })
            .collect();
        let verdicts: Vec<Json> = self
            .verdicts
            .iter()
            .map(|v| {
                Json::obj(vec![
                    ("claim", Json::Str(v.claim.clone())),
                    ("holds", Json::Bool(v.holds)),
                    ("measured", Json::Num(v.measured)),
                    ("bound", Json::Num(v.bound)),
                    ("detail", Json::Str(v.detail.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("report", Json::Str("chaos".into())),
            ("cost_model", Json::Str(self.cost_model.into())),
            ("workload", Json::Str(self.workload.clone())),
            ("seed", Json::Num(self.cfg.seed as f64)),
            ("clip_seconds", Json::Num(self.cfg.clip_seconds)),
            ("gpus", Json::Num(self.cfg.gpus as f64)),
            ("tolerance", Json::Num(self.cfg.tolerance)),
            ("recovery_band", Json::Num(self.cfg.recovery_band)),
            ("smoke", Json::Bool(self.cfg.smoke)),
            ("intensities", Json::arr_f64(&self.cfg.intensities)),
            ("points", Json::Arr(points)),
            ("claims", Json::Arr(verdicts)),
            ("all_hold", Json::Bool(self.all_hold())),
        ])
    }

    /// Human-readable summary (the `arrow chaos` stdout table).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "Chaos conformance — {} cost model, {} mode ({} GPUs, seed {}, clip {:.0}s, [{}])",
            self.cost_model,
            if self.cfg.smoke { "smoke" } else { "full" },
            self.cfg.gpus,
            self.cfg.seed,
            self.cfg.clip_seconds,
            self.workload,
        );
        let _ = writeln!(
            s,
            "  {:>9} {:>7} {:>10} {:>9} {:>7} {:>6} {:>9} {:>6}",
            "intensity", "faults", "goodput", "finished", "shed", "lost", "tail_cmp", "det"
        );
        for p in &self.points {
            let _ = writeln!(
                s,
                "  {:>9} {:>7} {:>10.1} {:>9} {:>7} {:>6} {:>9.3} {:>6}",
                p.intensity,
                p.n_faults,
                p.report.goodput_tokens,
                p.report.n_finished,
                p.shed.iter().sum::<usize>(),
                p.silently_lost,
                p.tail_completion,
                if p.deterministic { "yes" } else { "NO" }
            );
        }
        let n_ok = self.verdicts.iter().filter(|v| v.holds).count();
        let _ = writeln!(s, "\nchaos invariants: {}/{} hold", n_ok, self.verdicts.len());
        for v in &self.verdicts {
            let _ = writeln!(
                s,
                "  {} {} — {}",
                if v.holds { "ok  " } else { "FAIL" },
                v.claim,
                v.detail
            );
        }
        s
    }
}

/// Run the chaos sweep on one explicit workload.
pub fn run_chaos_for(w: &Workload, cfg: &ChaosConfig) -> ChaosReport {
    assert!(!cfg.intensities.is_empty(), "chaos needs a non-empty sweep");
    let points = parallel_map(cfg.intensities.clone(), cfg.workers, |&i| {
        run_point(w, cfg, i)
    });
    let verdicts = verdicts_for(&points, cfg);
    ChaosReport {
        cfg: cfg.clone(),
        cost_model: "normalized",
        workload: w.name().to_string(),
        points,
        verdicts,
    }
}

/// Run the default chaos sweep: the burst workload in full mode, the
/// smoke trace under the CI budget.
pub fn run_chaos(cfg: &ChaosConfig) -> ChaosReport {
    let name = if cfg.smoke { "smoke" } else { "azure_code" };
    let w = catalog::by_name(name).expect("catalog workload");
    run_chaos_for(&w, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smallest meaningful sweep: short clip, baseline + one intensity —
    /// unit tests exercise plumbing; the chaos *tier* does the real run.
    fn tiny_cfg() -> ChaosConfig {
        ChaosConfig {
            clip_seconds: 20.0,
            intensities: vec![0.0, 1.0],
            gpus: 4,
            workers: 2,
            ..ChaosConfig::smoke()
        }
    }

    #[test]
    fn sweep_accounts_every_request_and_covers_verdicts() {
        let w = catalog::by_name("smoke").unwrap();
        let report = run_chaos_for(&w, &tiny_cfg());
        assert_eq!(report.points.len(), 2);
        for p in &report.points {
            assert_eq!(
                p.report.n_finished + p.report.n_failed,
                p.report.n_requests,
                "accounting at intensity {}",
                p.intensity
            );
            assert_eq!(p.silently_lost, 0, "silent loss at intensity {}", p.intensity);
            assert!(p.deterministic, "nondeterminism at intensity {}", p.intensity);
        }
        assert_eq!(report.points[0].n_faults, 0, "baseline must be fault-free");
        assert!(report.points[1].n_faults > 0);
        // Verdict presence is part of the contract.
        let names: Vec<&str> = report.verdicts.iter().map(|v| v.claim.as_str()).collect();
        for want in [
            "no_silent_loss@x0",
            "no_silent_loss@x1",
            "deterministic@x0",
            "deterministic@x1",
            "goodput_bound@x1",
            "recovery@x1",
        ] {
            assert!(names.contains(&want), "missing verdict {want}: {names:?}");
        }
        assert!(report.all_hold(), "failed: {:?}", report.failed());
    }

    #[test]
    fn report_json_roundtrips_and_is_self_describing() {
        let w = catalog::by_name("smoke").unwrap();
        let report = run_chaos_for(&w, &tiny_cfg());
        let text = report.to_json().encode();
        let back = Json::parse(&text).expect("chaos report must be valid JSON");
        assert_eq!(back.get("report").as_str(), Some("chaos"));
        assert_eq!(back.get("cost_model").as_str(), Some("normalized"));
        assert_eq!(back.get("points").as_arr().unwrap().len(), 2);
        assert!(back.get("claims").as_arr().is_some());
        assert!(back.get("all_hold").as_bool().is_some());
        let s = report.summary();
        for v in &report.verdicts {
            assert!(s.contains(&v.claim), "summary missing {}", v.claim);
        }
    }

    #[test]
    fn configs_are_sane() {
        for cfg in [ChaosConfig::full(), ChaosConfig::smoke()] {
            assert!(!cfg.intensities.is_empty());
            assert_eq!(cfg.intensities[0], 0.0, "baseline leads the sweep");
            assert!(cfg.intensities.windows(2).all(|w| w[0] < w[1]));
            assert!(cfg.clip_seconds > 0.0);
            assert!((0.0..1.0).contains(&cfg.tolerance));
            assert!((0.0..1.0).contains(&cfg.recovery_band));
        }
        assert!(ChaosConfig::smoke().clip_seconds < ChaosConfig::full().clip_seconds);
    }
}
