//! Evaluation scenario builders: one function per system in §7.1,
//! producing a ready-to-run simulator [`Cluster`] for a GPU budget.
//!
//! | system            | topology                  | quirks encoded            |
//! |-------------------|---------------------------|---------------------------|
//! | Arrow             | n × TP=1 stateless        | elastic pools, SLO-aware  |
//! | vLLM (colocated)  | 1 × TP=n                  | chunked prefill interfere |
//! | vLLM-disaggregated| 1P + 1D, TP=n/2           | transfer buffer cap+fail  |
//! | DistServe-like    | n/2 P + n/2 D, TP=1       | 0.55× engine efficiency,  |
//! |                   |                           | low KV cap (long-ctx OOM) |
//! | Minimal Load      | n/2 P + n/2 D, TP=1       | ablation arm (§7.3)       |
//! | Round Robin       | n/2 P + n/2 D, TP=1       | ablation arm (§7.3)       |
//! | Deflect (PR 10)   | n × TP=1 stateless        | Arrow + load-aware        |
//! |                   |                           | prefill deflection        |
//! | Unified (PR 10)   | n × TP=1 stateless        | every instance both       |
//! |                   |                           | phases, movable cut point |

use std::sync::Arc;

use crate::baselines::{ColocatedPolicy, PickRule, StaticDisaggPolicy};
use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use crate::costmodel::CostModel;
use crate::engine::SimInstance;
use crate::fault::TransferRetryPolicy;
use crate::request::InstanceId;
use crate::sched::{DeflectConfig, DeflectPolicy, UnifiedConfig, UnifiedPolicy};
use crate::sim::{AdmissionControl, Cluster, MembershipChange, SimConfig, MONITOR_PERIOD};

/// Systems evaluated in Fig. 7 / Fig. 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum System {
    Arrow,
    VllmColocated,
    VllmDisaggregated,
    DistServe,
    MinimalLoad,
    RoundRobin,
    /// PR 10: Arrow + load-aware prefill deflection
    /// ([`crate::sched::DeflectPolicy`]).
    Deflect,
    /// PR 10: unified-elastic, every instance serves both phases behind
    /// a movable cut point ([`crate::sched::UnifiedPolicy`]).
    Unified,
}

impl System {
    pub fn label(self) -> &'static str {
        match self {
            System::Arrow => "arrow",
            System::VllmColocated => "vllm",
            System::VllmDisaggregated => "vllm-disagg",
            System::DistServe => "distserve",
            System::MinimalLoad => "minimal-load",
            System::RoundRobin => "round-robin",
            System::Deflect => "deflect",
            System::Unified => "unified",
        }
    }

    pub fn all() -> [System; 8] {
        [
            System::Arrow,
            System::VllmColocated,
            System::VllmDisaggregated,
            System::DistServe,
            System::MinimalLoad,
            System::RoundRobin,
            System::Deflect,
            System::Unified,
        ]
    }

    pub fn by_label(s: &str) -> Option<System> {
        System::all().into_iter().find(|x| x.label() == s)
    }
}

/// Build the simulation cluster for `system` with `n_gpus` GPUs under the
/// given SLO (SLOs parameterize Arrow's scheduler and the Max-Running-
/// Tokens profiling).
pub fn build(
    system: System,
    n_gpus: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    record_timeline: bool,
) -> Cluster {
    build_time_scaled(system, n_gpus, base, ttft_slo, tpot_slo, record_timeline, 1.0)
}

/// [`build`]'s Arrow arm with the PR 8 knobs exposed: the class-aware
/// scheduling toggle ([`ArrowConfig::class_aware`]) and optional
/// admission control. With `class_aware = true` and `admission = None`
/// this is byte-identical to `build(System::Arrow, ..)` on an
/// all-Standard trace (Standard's scaled targets *are* the base pair and
/// the all-zero rank stream reproduces FIFO order) — the metamorphic
/// tier pins that. The claims harness uses it to compare class-aware
/// vs class-blind Arrow on a mixed-class trace under the same
/// admission cap.
pub fn build_arrow_classed(
    n_gpus: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    class_aware: bool,
    admission: Option<AdmissionControl>,
) -> Cluster {
    assert!(n_gpus >= 2, "scenarios need >= 2 GPUs");
    let cfg = SimConfig {
        record_timeline: false,
        drain_timeout: 300.0,
        monitor_period: MONITOR_PERIOD,
        admission,
        ..Default::default()
    };
    let mut pcfg = ArrowConfig::new(ttft_slo, tpot_slo, n_gpus);
    pcfg.class_aware = class_aware;
    let policy = ArrowPolicy::new(pcfg, n_gpus);
    let cost = Arc::new(base.clone());
    let instances: Vec<SimInstance> = (0..n_gpus)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
            inst.iter_time_budget = Some(0.8 * tpot_slo);
            inst
        })
        .collect();
    Cluster::new(instances, Box::new(policy), cfg)
}

/// [`build`] with every *time* dimension dilated by `time_scale`: cost
/// model coefficients, SLOs, drain timeout, monitor period, and the
/// vLLM-disagg transfer-fail timeout all scale together (token/byte
/// capacities are dimensionless and do not). For power-of-two scales the
/// dilation is bit-exact, so a scheduler whose decisions depend only on
/// *ratios* of times — which is all of them — must produce the identical
/// placement schedule on a correspondingly dilated trace. The metamorphic
/// conformance tier (`tests/metamorphic.rs`) enforces exactly that; a
/// divergence means some placement path sneaked in an absolute-seconds
/// constant.
pub fn build_time_scaled(
    system: System,
    n_gpus: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    record_timeline: bool,
    time_scale: f64,
) -> Cluster {
    assert!(n_gpus >= 2, "scenarios need >= 2 GPUs");
    let k = time_scale;
    let base = &base.scaled(k);
    let (ttft_slo, tpot_slo) = (ttft_slo * k, tpot_slo * k);
    let cfg = SimConfig {
        record_timeline,
        // 5 minutes of drain after the last arrival: ample for any run
        // that can still meet a 90% SLO target, and it bounds the cost of
        // the (many) deliberately-oversaturated sweep points.
        drain_timeout: 300.0 * k,
        monitor_period: MONITOR_PERIOD * k,
        ..Default::default()
    };
    match system {
        System::Arrow => {
            let policy = ArrowPolicy::new(ArrowConfig::new(ttft_slo, tpot_slo, n_gpus), n_gpus);
            // One shared cost model behind n refcounts, not n deep clones.
            let cost = Arc::new(base.clone());
            let instances: Vec<SimInstance> = (0..n_gpus)
                .map(|i| {
                    let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
                    // SLO-aware mixed-iteration chunk cap: protects TPOT
                    // of decodes co-resident with prefill on P→D / D→P
                    // instances (engine::instance docs).
                    inst.iter_time_budget = Some(0.8 * tpot_slo);
                    inst
                })
                .collect();
            Cluster::new(instances, Box::new(policy), cfg)
        }
        System::VllmColocated => {
            // TP = n_gpus, one fat engine; high TP efficiency on NVLink.
            // vLLM's chunked prefill uses a fixed token budget with
            // decode priority — TPOT stays low, TTFT queues under load
            // (exactly the behaviour Fig. 7's first row shows).
            let cost = base.with_tensor_parallel(n_gpus, 0.9);
            Cluster::homogeneous(1, cost, Box::new(ColocatedPolicy::new(1)), cfg)
        }
        System::VllmDisaggregated => {
            // vLLM v0.7.3 experimental PD: exactly 1 prefill + 1 decode
            // instance (TP = n/2 each), KV transfer buffer workaround:
            // bounded buffer + reduced batch size (§7.1 footnotes).
            let mut cost = base.with_tensor_parallel(n_gpus / 2, 0.88);
            cost.max_batch = 32; // "limiting the batch size"
            let cost = Arc::new(cost);
            let instances: Vec<SimInstance> = (0..2)
                .map(|i| SimInstance::new(InstanceId(i), Arc::clone(&cost)))
                .collect();
            let quirks = SimConfig {
                transfer_buffer_tokens: Some(120_000), // bounded KV buffer
                transfer_fail_timeout: Some(120.0 * k),
                ..cfg
            };
            let policy =
                StaticDisaggPolicy::new("vllm-disagg", vec![0], vec![1], PickRule::MinimalLoad);
            Cluster::new(instances, Box::new(policy), quirks)
        }
        System::DistServe => {
            // Unmaintained engine: markedly lower per-instance efficiency
            // and a smaller usable KV pool (OOM on long context, §7.1).
            let mut cost = base.with_efficiency(0.55);
            cost.max_kv_tokens = 90_000;
            let half = n_gpus / 2;
            let policy = StaticDisaggPolicy::new(
                "distserve",
                (0..half).collect(),
                (half..n_gpus).collect(),
                PickRule::MinimalLoad,
            );
            Cluster::homogeneous(n_gpus, cost, Box::new(policy), cfg)
        }
        System::MinimalLoad => {
            let half = n_gpus / 2;
            let policy = StaticDisaggPolicy::new(
                "minimal-load",
                (0..half).collect(),
                (half..n_gpus).collect(),
                PickRule::MinimalLoad,
            );
            Cluster::homogeneous(n_gpus, base.clone(), Box::new(policy), cfg)
        }
        System::RoundRobin => {
            let half = n_gpus / 2;
            let policy = StaticDisaggPolicy::new(
                "round-robin",
                (0..half).collect(),
                (half..n_gpus).collect(),
                PickRule::RoundRobin,
            );
            Cluster::homogeneous(n_gpus, base.clone(), Box::new(policy), cfg)
        }
        System::Deflect => {
            // Arrow's exact topology — n stateless TP=1 instances with
            // SLO-aware chunking — under the deflection wrapper. The
            // deflection cap is a token count and both guards are
            // SLO-ratio tests, so the arm dilates exactly like Arrow's.
            let policy = DeflectPolicy::new(DeflectConfig::new(ttft_slo, tpot_slo, n_gpus), n_gpus);
            let cost = Arc::new(base.clone());
            let instances: Vec<SimInstance> = (0..n_gpus)
                .map(|i| {
                    let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
                    inst.iter_time_budget = Some(0.8 * tpot_slo);
                    inst
                })
                .collect();
            Cluster::new(instances, Box::new(policy), cfg)
        }
        System::Unified => {
            // Unified-elastic: same stateless instances, but every one
            // serves both phases — the iteration budget is what protects
            // decode TPOT inside every mixed batch, so it is essential
            // here rather than transitional.
            let policy = UnifiedPolicy::new(UnifiedConfig::new(ttft_slo, tpot_slo), n_gpus);
            let cost = Arc::new(base.clone());
            let instances: Vec<SimInstance> = (0..n_gpus)
                .map(|i| {
                    let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
                    inst.iter_time_budget = Some(0.8 * tpot_slo);
                    inst
                })
                .collect();
            Cluster::new(instances, Box::new(policy), cfg)
        }
    }
}

// ---------------------------------------------------------------------------
// Elastic-membership scenarios (PR 3): the regimes the fixed-instance
// builders above cannot express — traffic spikes absorbed by scale-out,
// rolling restarts, and correlated decode-node failures. All run the
// Arrow policy (the baselines are membership-blind by design; §7.3's
// static arms have nothing to re-seed).
// ---------------------------------------------------------------------------

/// Policy arm for the *dynamic* (membership-aware) schedulers — Arrow
/// and the PR-10 adversaries. The static baselines are membership-blind
/// by design (§7.3 has nothing to re-seed), so asking for one here is a
/// caller bug. `n_seed` sizes the pool seed to the live set at t=0;
/// `n_total` sizes the instance table (spares join later).
fn dynamic_policy(
    system: System,
    n_seed: usize,
    n_total: usize,
    ttft_slo: f64,
    tpot_slo: f64,
) -> Box<dyn crate::sched::Policy> {
    match system {
        System::Arrow => Box::new(ArrowPolicy::new(
            ArrowConfig::new(ttft_slo, tpot_slo, n_seed),
            n_total,
        )),
        System::Deflect => Box::new(DeflectPolicy::new(
            DeflectConfig::new(ttft_slo, tpot_slo, n_seed),
            n_total,
        )),
        System::Unified => Box::new(UnifiedPolicy::new(
            UnifiedConfig::new(ttft_slo, tpot_slo),
            n_total,
        )),
        other => panic!(
            "{} is membership-blind; elastic/chaos scenarios cover the dynamic schedulers",
            other.label()
        ),
    }
}

/// A dynamic-scheduler cluster whose instance table has `n_total` slots
/// but only `n_live` live at t=0 — the substrate for every elastic
/// scenario. Spare slots (`n_live..n_total`) join whenever the caller
/// schedules it. `elastic_for(System::Arrow, ..)` is byte-identical to
/// [`arrow_elastic`].
pub fn elastic_for(
    system: System,
    n_total: usize,
    n_live: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    record_timeline: bool,
) -> Cluster {
    assert!(n_live >= 2 && n_live <= n_total, "need 2 <= n_live <= n_total");
    let cfg = SimConfig {
        record_timeline,
        drain_timeout: 300.0,
        ..Default::default()
    };
    // Pool seed is sized to the *live* set: spares start outside the
    // cluster and join into whichever pool the policy's membership
    // handling picks at join time.
    let policy = dynamic_policy(system, n_live, n_total, ttft_slo, tpot_slo);
    let cost = Arc::new(base.clone());
    let instances: Vec<SimInstance> = (0..n_total)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
            inst.iter_time_budget = Some(0.8 * tpot_slo);
            inst
        })
        .collect();
    let mut cl = Cluster::new(instances, policy, cfg);
    if n_live < n_total {
        cl.set_initial_live((0..n_total).map(|i| i < n_live).collect());
    }
    cl
}

/// An Arrow cluster whose instance table has `n_total` slots but only
/// `n_live` live at t=0. See [`elastic_for`].
pub fn arrow_elastic(
    n_total: usize,
    n_live: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    record_timeline: bool,
) -> Cluster {
    elastic_for(System::Arrow, n_total, n_live, base, ttft_slo, tpot_slo, record_timeline)
}

/// Spike scale-out: `n_spare` instances join at `join_at` (the moment a
/// traffic spike is detected) and stay for the rest of the run — the
/// DynaServe-style elastic regime. Compare against `build(System::Arrow,
/// n_base, ..)` on the same trace for the fixed-membership baseline.
pub fn spike_scale_out(
    n_base: usize,
    n_spare: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    join_at: f64,
) -> Cluster {
    spike_scale_out_for(System::Arrow, n_base, n_spare, base, ttft_slo, tpot_slo, join_at)
}

/// [`spike_scale_out`] under any dynamic scheduler (PR 10): the same
/// spare-join schedule with the policy arm selected by `system`, so the
/// elastic-membership dominance property can be asserted for the
/// scheduling adversaries too.
pub fn spike_scale_out_for(
    system: System,
    n_base: usize,
    n_spare: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    join_at: f64,
) -> Cluster {
    let mut cl = elastic_for(system, n_base + n_spare, n_base, base, ttft_slo, tpot_slo, false);
    for s in 0..n_spare {
        cl.schedule_membership(join_at, MembershipChange::Join(n_base + s));
    }
    cl
}

/// Rolling restart: each instance in turn begins draining at
/// `start + i*gap` and rejoins `downtime` seconds after its drain
/// actually *completes* (`MembershipChange::Restart`) — so a slow drain
/// is waited out, never cancelled by its own rejoin. The timeline is
/// recorded so drills can assert the dips really happened.
pub fn rolling_restart(
    n: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    start: f64,
    gap: f64,
    downtime: f64,
) -> Cluster {
    let mut cl = arrow_elastic(n, n, base, ttft_slo, tpot_slo, true);
    for i in 0..n {
        cl.schedule_membership(
            start + i as f64 * gap,
            MembershipChange::Restart { inst: i, downtime },
        );
    }
    cl
}

/// Correlated decode-node failure: the last `victims` instances — the
/// seed decode pool — fail together at `fail_at` (rack loss). The
/// policy must re-seed pools and the event loop re-queues every lost
/// request; the acceptance test asserts all of them still finish.
pub fn decode_node_failure(
    n: usize,
    victims: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
    fail_at: f64,
) -> Cluster {
    assert!(victims < n, "must leave at least one survivor");
    let mut cl = arrow_elastic(n, n, base, ttft_slo, tpot_slo, false);
    for v in 0..victims {
        cl.schedule_membership(fail_at, MembershipChange::Fail(n - 1 - v));
    }
    cl
}

/// An Arrow cluster with the PR 6 recovery machinery armed: a bounded
/// transfer fabric (buffer cap + fail timeout) so flapped links actually
/// block, KV-transfer retry with capped backoff, and monitor-tick
/// straggler detection feeding `Liveness::Degraded`. The chaos harness
/// (`arrow chaos`) and `tests/chaos.rs` drive seeded [`crate::fault::FaultPlan`]s
/// through this builder; with an empty plan it behaves like
/// `build(System::Arrow, ..)` plus the bounded fabric.
pub fn arrow_chaos(
    n: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
) -> Cluster {
    system_chaos(System::Arrow, n, base, ttft_slo, tpot_slo)
}

/// [`arrow_chaos`]'s recovery-armed configuration under any dynamic
/// scheduler (PR 10): the same bounded fabric, retry policy and
/// straggler detection with the policy arm selected by `system`, so the
/// chaos tier's no-silent-loss and determinism contracts can be enforced
/// on the scheduling adversaries too. `system_chaos(System::Arrow, ..)`
/// is byte-identical to [`arrow_chaos`].
pub fn system_chaos(
    system: System,
    n: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
) -> Cluster {
    assert!(n >= 2, "chaos scenarios need >= 2 instances");
    let cfg = SimConfig {
        record_timeline: false,
        drain_timeout: 300.0,
        // Bounded fabric: generous enough that fault-free runs never
        // block, small enough that a flapped link backs it up.
        transfer_buffer_tokens: Some(200_000),
        transfer_fail_timeout: Some(10.0),
        transfer_retry: Some(TransferRetryPolicy::default()),
        straggler_factor: Some(3.0),
        ..Default::default()
    };
    let policy = dynamic_policy(system, n, n, ttft_slo, tpot_slo);
    let cost = Arc::new(base.clone());
    let instances: Vec<SimInstance> = (0..n)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
            inst.iter_time_budget = Some(0.8 * tpot_slo);
            inst
        })
        .collect();
    Cluster::new(instances, policy, cfg)
}

// ---------------------------------------------------------------------------
// Large-cluster scenarios (PR 4): the scale regime the ROADMAP north-star
// ("heavy traffic from millions of users") needs — 64/256 stateless
// TP=1 instances behind one Arrow scheduler, driven by deep-queue burst
// traces that put tens of requests behind every instance. These builders
// exist so the O(1)-placement fast path is exercised end-to-end (and
// demoable via `workload_explorer --instances N`), not just in the
// `benches/scale.rs` micro gate.
// ---------------------------------------------------------------------------

/// An Arrow cluster at large scale: `n` stateless TP=1 instances (64 and
/// 256 are the reference points of the scale sweep), one shared cost
/// model behind refcounts, SLO-aware chunking enabled — the same shape
/// `build(System::Arrow, ..)` produces, with a scale guard and a shorter
/// drain timeout so oversaturated sweep points stay cheap.
pub fn large_cluster(
    n_instances: usize,
    base: &CostModel,
    ttft_slo: f64,
    tpot_slo: f64,
) -> Cluster {
    assert!(n_instances >= 8, "large_cluster is for >= 8 instances");
    let cfg = SimConfig {
        record_timeline: false,
        drain_timeout: 120.0,
        ..Default::default()
    };
    let policy =
        ArrowPolicy::new(ArrowConfig::new(ttft_slo, tpot_slo, n_instances), n_instances);
    let cost = Arc::new(base.clone());
    let instances: Vec<SimInstance> = (0..n_instances)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), Arc::clone(&cost));
            inst.iter_time_budget = Some(0.8 * tpot_slo);
            inst
        })
        .collect();
    Cluster::new(instances, Box::new(policy), cfg)
}

/// Deterministic deep-queue burst trace for large clusters:
/// `per_instance × n_instances` requests arrive inside a `window`-second
/// burst, so every instance ends up with a deep prefill backlog — the
/// regime where the pre-PR-4 scheduler cost was
/// O(members × queue depth) per placement.
pub fn deep_queue_burst(
    n_instances: usize,
    per_instance: usize,
    window_s: f64,
    seed: u64,
) -> crate::trace::Trace {
    use crate::request::Request;
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x5ca1e);
    let n = n_instances * per_instance;
    assert!(n > 0);
    let mut requests = Vec::with_capacity(n);
    for i in 0..n {
        let arrival = window_s * (i as f64 / n as f64);
        let input = rng.int_range(200, 16_000) as u32;
        let output = rng.int_range(4, 48) as u32;
        requests.push(Request::new(i as u64, arrival, input, output));
    }
    crate::trace::Trace::new("deep_queue_burst", requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SloReport;
    use crate::trace::synthetic::smoke;

    fn run(system: System) -> SloReport {
        let trace = smoke(150, 2).generate(3);
        let cl = build(system, 8, &CostModel::h800_llama8b(), 2.0, 0.1, false);
        let res = cl.run(&trace);
        SloReport::from_records(&res.records, 2.0, 0.1, trace.duration())
    }

    #[test]
    fn all_systems_complete_light_load() {
        for sys in System::all() {
            let rep = run(sys);
            assert!(
                rep.n_finished + rep.n_failed == rep.n_requests,
                "{}: accounting",
                sys.label()
            );
            assert!(
                rep.n_finished as f64 >= 0.95 * rep.n_requests as f64,
                "{}: finished {}/{}",
                sys.label(),
                rep.n_finished,
                rep.n_requests
            );
        }
    }

    #[test]
    fn labels_roundtrip() {
        for sys in System::all() {
            assert_eq!(System::by_label(sys.label()), Some(sys));
        }
        assert_eq!(System::by_label("nope"), None);
    }

    #[test]
    fn arrow_flips_under_smoke_load() {
        let trace = smoke(300, 2).generate(5);
        let cl = build(System::Arrow, 8, &CostModel::h800_llama8b(), 2.0, 0.1, false);
        let res = cl.run(&trace);
        // Light smoke load may or may not flip; the counter must at least
        // be consistent (no panic) and requests finish.
        assert!(res.records.iter().filter(|r| r.finished()).count() > 280);
    }

    #[test]
    fn large_cluster_completes_deep_queue_burst() {
        // 16 instances × 6 queued requests each: small enough for a unit
        // test, deep enough that every placement runs against loaded
        // queues (the debug-mode moment oracles verify the O(1) path on
        // every decision of this run).
        let base = CostModel::h800_llama8b();
        let trace = deep_queue_burst(16, 6, 5.0, 3);
        assert_eq!(trace.len(), 96);
        let res = large_cluster(16, &base, 5.0, 0.1).run(&trace);
        let finished = res.records.iter().filter(|r| r.finished()).count();
        assert_eq!(finished, trace.len(), "burst must fully drain");
    }

    #[test]
    fn deep_queue_burst_is_deterministic_and_bursty() {
        let a = deep_queue_burst(8, 4, 10.0, 7);
        let b = deep_queue_burst(8, 4, 10.0, 7);
        assert_eq!(a.requests, b.requests);
        assert!(a.requests.iter().all(|r| r.arrival <= 10.0));
        assert!(
            a.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "arrivals sorted"
        );
    }

    #[test]
    fn chaos_builder_fault_free_completes_light_load() {
        // With no fault plan, the armed recovery machinery must be inert:
        // every request finishes, nothing is shed.
        let base = CostModel::h800_llama8b();
        let trace = smoke(120, 2).generate(11);
        let res = arrow_chaos(4, &base, 2.0, 0.1).run(&trace);
        let finished = res.records.iter().filter(|r| r.finished()).count();
        assert_eq!(finished, trace.len(), "fault-free chaos builder lost requests");
        assert!(res.records.iter().all(|r| r.shed.is_none()));
    }

    #[test]
    fn adversary_elastic_and_chaos_builders_complete_light_load() {
        // The PR-10 arms of the generic builders: membership churn and the
        // armed (fault-free) recovery fabric must both be inert at light
        // load, exactly like Arrow's.
        let base = CostModel::h800_llama8b();
        let trace = smoke(120, 2).generate(17);
        let d = trace.duration();
        for sys in [System::Deflect, System::Unified] {
            let res = spike_scale_out_for(sys, 4, 2, &base, 2.0, 0.1, 0.3 * d).run(&trace);
            assert!(
                res.records.iter().all(|r| r.finished()),
                "{}: elastic light load lost requests",
                sys.label()
            );
            let res = system_chaos(sys, 4, &base, 2.0, 0.1).run(&trace);
            assert!(
                res.records.iter().all(|r| r.finished()),
                "{}: fault-free chaos light load lost requests",
                sys.label()
            );
        }
    }

    #[test]
    fn elastic_builders_complete_light_load() {
        let base = CostModel::h800_llama8b();
        let trace = smoke(150, 2).generate(7);
        let d = trace.duration();
        let runs = [
            spike_scale_out(4, 2, &base, 2.0, 0.1, 0.3 * d),
            rolling_restart(4, &base, 2.0, 0.1, 0.2 * d, 0.2 * d, 0.05 * d),
            decode_node_failure(4, 1, &base, 2.0, 0.1, 0.5 * d),
        ];
        for cl in runs {
            let res = cl.run(&trace);
            let rep = SloReport::from_records(&res.records, 2.0, 0.1, d);
            assert_eq!(rep.n_finished + rep.n_failed, rep.n_requests);
            assert_eq!(
                rep.n_finished, rep.n_requests,
                "membership churn must lose no request at light load"
            );
        }
    }
}
