//! Baseline systems the paper compares against (§7.1) plus the ablation
//! strategies (§7.3). All are [`Policy`] implementations over the same
//! substrate-agnostic [`ClusterView`] interface as Arrow; architectural
//! differences (TP degree, static roles, transfer quirks, engine
//! efficiency) are encoded in the cluster built by [`crate::scenarios`].

use crate::coordinator::predictor::TtftPredictor;
use crate::request::{InstanceId, Request, Time};
use crate::sched::{ClusterView, Policy, ProfileSource};

// ---------------------------------------------------------------------------
// vLLM-colocated: one fat TP=8 instance, chunked prefill, decode priority.
// ---------------------------------------------------------------------------

/// PD-colocated serving (vLLM): every request prefills *and* decodes on
/// the same engine; the engine's decode-prioritized chunked-prefill local
/// scheduler reproduces vLLM's interference behaviour (TTFT inflates under
/// load while TPOT stays low — §7.2's observation).
pub struct ColocatedPolicy {
    n: usize,
    next: usize,
}

impl ColocatedPolicy {
    /// `n` engines (1 for TP=8 on one node; >1 models data parallelism).
    pub fn new(n: usize) -> Self {
        ColocatedPolicy { n, next: 0 }
    }
}

impl Policy for ColocatedPolicy {
    fn name(&self) -> &'static str {
        "vllm-colocated"
    }

    fn place_prefill(&mut self, _: Time, _: &Request, _: &dyn ClusterView) -> InstanceId {
        let id = InstanceId(self.next % self.n);
        self.next += 1;
        id
    }

    fn place_decode(
        &mut self,
        _: Time,
        _: &Request,
        prefill_instance: InstanceId,
        _: &dyn ClusterView,
    ) -> InstanceId {
        prefill_instance // colocated: no migration ever
    }
}

// ---------------------------------------------------------------------------
// Static PD-disaggregation (vLLM-disaggregated, DistServe): fixed roles.
// ---------------------------------------------------------------------------

/// How a static-disaggregation policy picks within its fixed pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PickRule {
    /// Cycle through instances in order (§7.3 "Round Robin").
    RoundRobin,
    /// Least predicted prefill delay / least running tokens
    /// (§7.3 "Minimal Load" — Arrow's request scheduling without the
    /// instance scheduling).
    MinimalLoad,
}

/// Static prefill/decode split with a pluggable pick rule. Serves as:
/// * vLLM-disaggregated (1P + 1D, TP=4 each, transfer quirks),
/// * DistServe-like (4P + 4D, lower engine efficiency),
/// * the Round-Robin and Minimal-Load ablation arms (4P + 4D).
pub struct StaticDisaggPolicy {
    name: &'static str,
    prefill_ids: Vec<usize>,
    decode_ids: Vec<usize>,
    rule: PickRule,
    predictor: Option<TtftPredictor>,
    next_p: usize,
    next_d: usize,
}

impl StaticDisaggPolicy {
    pub fn new(
        name: &'static str,
        prefill_ids: Vec<usize>,
        decode_ids: Vec<usize>,
        rule: PickRule,
    ) -> Self {
        assert!(!prefill_ids.is_empty() && !decode_ids.is_empty());
        StaticDisaggPolicy {
            name,
            prefill_ids,
            decode_ids,
            rule,
            predictor: None,
            next_p: 0,
            next_d: 0,
        }
    }
}

impl Policy for StaticDisaggPolicy {
    fn name(&self) -> &'static str {
        self.name
    }

    fn init(&mut self, profile: &dyn ProfileSource) {
        // Static pools are homogeneous within a scenario: one curve,
        // fitted for the first prefill instance, serves the whole pool.
        self.predictor = Some(profile.fit_predictor(self.prefill_ids[0]));
    }

    fn place_prefill(&mut self, _: Time, _: &Request, view: &dyn ClusterView) -> InstanceId {
        match self.rule {
            PickRule::RoundRobin => {
                let id = self.prefill_ids[self.next_p % self.prefill_ids.len()];
                self.next_p += 1;
                InstanceId(id)
            }
            PickRule::MinimalLoad => {
                let pred = self.predictor.as_ref().expect("init not called");
                let id = self
                    .prefill_ids
                    .iter()
                    .copied()
                    .min_by(|&a, &b| {
                        // O(1) per candidate (PR 4): price the queue from
                        // its maintained moments, never by walking it.
                        let da = pred.queue_delay_moments(&view.prefill_queue_moments(a));
                        let db = pred.queue_delay_moments(&view.prefill_queue_moments(b));
                        // total_cmp: a NaN prediction must never panic
                        // the placement path.
                        da.total_cmp(&db)
                    })
                    .unwrap();
                InstanceId(id)
            }
        }
    }

    fn place_decode(
        &mut self,
        _: Time,
        _: &Request,
        _prefill: InstanceId,
        view: &dyn ClusterView,
    ) -> InstanceId {
        match self.rule {
            PickRule::RoundRobin => {
                let id = self.decode_ids[self.next_d % self.decode_ids.len()];
                self.next_d += 1;
                InstanceId(id)
            }
            PickRule::MinimalLoad => {
                let id = self
                    .decode_ids
                    .iter()
                    .copied()
                    .min_by_key(|&i| view.running_tokens(i))
                    .unwrap();
                InstanceId(id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::engine::SimInstance;
    use crate::request::RequestId;
    use crate::sim::SimView;

    fn insts(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect()
    }

    fn req(id: u64) -> Request {
        Request::new(id, 0.0, 1000, 10)
    }

    #[test]
    fn colocated_keeps_request_on_one_instance() {
        let is = insts(2);
        let mut p = ColocatedPolicy::new(2);
        let a = p.place_prefill(0.0, &req(0), &SimView(&is));
        let d = p.place_decode(0.0, &req(0), a, &SimView(&is));
        assert_eq!(a, d);
        // Round-robins across engines.
        let b = p.place_prefill(0.0, &req(1), &SimView(&is));
        assert_ne!(a, b);
    }

    #[test]
    fn round_robin_cycles() {
        let is = insts(4);
        let mut p = StaticDisaggPolicy::new("rr", vec![0, 1], vec![2, 3], PickRule::RoundRobin);
        p.init(&SimView(&is));
        let t1 = p.place_prefill(0.0, &req(0), &SimView(&is));
        let t2 = p.place_prefill(0.0, &req(1), &SimView(&is));
        let t3 = p.place_prefill(0.0, &req(2), &SimView(&is));
        assert_eq!((t1.0, t2.0, t3.0), (0, 1, 0));
        let d1 = p.place_decode(0.0, &req(0), t1, &SimView(&is));
        let d2 = p.place_decode(0.0, &req(1), t2, &SimView(&is));
        assert_eq!((d1.0, d2.0), (2, 3));
    }

    #[test]
    fn minimal_load_prefers_empty_instance() {
        let mut is = insts(4);
        is[0].enqueue_prefill(RequestId(9), 80_000);
        let mut p =
            StaticDisaggPolicy::new("ml", vec![0, 1], vec![2, 3], PickRule::MinimalLoad);
        p.init(&SimView(&is));
        assert_eq!(p.place_prefill(0.0, &req(0), &SimView(&is)).0, 1);
        assert!(is[2].try_reserve_kv(50_000));
        is[2].enqueue_decode(RequestId(8), 50_000, 100);
        assert_eq!(
            p.place_decode(0.0, &req(0), InstanceId(1), &SimView(&is)).0,
            3
        );
    }

    #[test]
    fn static_roles_never_cross() {
        let is = insts(4);
        let mut p = StaticDisaggPolicy::new("ml", vec![0, 1], vec![2, 3], PickRule::MinimalLoad);
        p.init(&SimView(&is));
        for i in 0..20 {
            let t = p.place_prefill(0.0, &req(i), &SimView(&is));
            assert!(t.0 < 2);
            let d = p.place_decode(0.0, &req(i), t, &SimView(&is));
            assert!(d.0 >= 2);
        }
    }
}
