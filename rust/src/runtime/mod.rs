//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them on the request path — python is never involved.
//!
//! Artifact contract (see aot.py):
//! * `model_config.json` — hyper-params, serving shapes, artifact index.
//! * `weights.bin` + `weights_manifest.json` — f32-LE parameters in
//!   `param_spec` order; entry computations take them first.
//! * `prefill_s{S}.hlo.txt` — `(params…, tokens[1,S] i32, valid_len i32)
//!   → (first_token[1] i32, k[L,S,H,Dh] f32, v alike)`.
//! * `decode_b{B}.hlo.txt` — `(params…, tokens[B] i32, k[L,B,T,H,Dh],
//!   v alike, cache_len[B] i32) → (next[B] i32, k', v')`.
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod kvstate;

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::json::Json;
pub use kvstate::DecodeBatchState;

/// Model hyper-parameters + serving shapes loaded from model_config.json.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub prefill_buckets: Vec<usize>,
    pub decode_batch: usize,
    pub max_seq_len: usize,
    pub kv_bytes_per_token: u64,
    pub n_params: u64,
    prefill_files: Vec<(usize, String)>,
    decode_file: String,
}

impl ModelInfo {
    pub fn load(dir: &Path) -> Result<ModelInfo> {
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let buckets: Vec<usize> = v
            .get("prefill_buckets")
            .as_arr()
            .ok_or_else(|| anyhow!("missing prefill_buckets"))?
            .iter()
            .map(|x| x.as_u64().unwrap_or(0) as usize)
            .collect();
        let arts = v.get("artifacts");
        let mut prefill_files: Vec<(usize, String)> = Vec::new();
        if let Some(m) = arts.get("prefill").as_obj() {
            for (k, f) in m {
                prefill_files.push((
                    k.parse::<usize>().context("bucket key")?,
                    f.as_str().unwrap_or_default().to_string(),
                ));
            }
        }
        prefill_files.sort();
        Ok(ModelInfo {
            name: v.req_str("name")?.to_string(),
            vocab_size: v.req_u64("vocab_size")? as usize,
            d_model: v.req_u64("d_model")? as usize,
            n_layers: v.req_u64("n_layers")? as usize,
            n_heads: v.req_u64("n_heads")? as usize,
            head_dim: v.req_u64("head_dim")? as usize,
            prefill_buckets: buckets,
            decode_batch: v.req_u64("decode_batch")? as usize,
            max_seq_len: v.req_u64("max_seq_len")? as usize,
            kv_bytes_per_token: v.req_u64("kv_bytes_per_token")?,
            n_params: v.req_u64("n_params")?,
            prefill_files,
            decode_file: arts
                .req_str("decode")
                .map_err(|e| anyhow!("{e}"))?
                .to_string(),
        })
    }
}

/// Result of a prefill execution: first output token plus the per-layer
/// KV slabs `[L, S, H, Dh]` (only the first `valid_len` positions matter).
pub struct PrefillOutput {
    pub first_token: i32,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub bucket: usize,
}

/// A loaded model: compiled executables + device-resident weights.
///
/// Perf note (EXPERIMENTS.md §Perf-L2): weights are uploaded to the PJRT
/// device ONCE at load and passed as buffers via `execute_b`, instead of
/// re-marshalled as literals on every call; the decode artifact returns
/// only the per-layer new K/V rows, which the host scatters into its
/// batch state — together cutting per-step host↔device traffic from
/// ~(weights + 2·full-KV) to ~(2·full-KV up + 2·rows down).
pub struct ModelRuntime {
    pub info: ModelInfo,
    client: xla::PjRtClient,
    weights: Vec<xla::PjRtBuffer>,
    prefill_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    decode_exe: xla::PjRtLoadedExecutable,
}

// The xla crate wraps raw PJRT pointers without Send markers; the CPU
// client is thread-safe for our use (each ModelRuntime is owned by one
// engine thread; the client itself is internally synchronized).
unsafe impl Send for ModelRuntime {}

impl ModelRuntime {
    /// Load artifacts, upload weights, compile all executables.
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelRuntime> {
        let dir = dir.as_ref();
        let info = ModelInfo::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;

        let weights = load_weights(dir, &client)?;

        let mut prefill_exes = Vec::new();
        for (bucket, file) in &info.prefill_files {
            let exe = compile_hlo(&client, &dir.join(file))?;
            prefill_exes.push((*bucket, exe));
        }
        if prefill_exes.is_empty() {
            bail!("no prefill artifacts in {}", dir.display());
        }
        let decode_exe = compile_hlo(&client, &dir.join(&info.decode_file))?;

        Ok(ModelRuntime {
            info,
            client,
            weights,
            prefill_exes,
            decode_exe,
        })
    }

    /// Smallest bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_exes
            .iter()
            .map(|&(b, _)| b)
            .find(|&b| b >= len)
    }

    /// Run the prefill phase for a prompt; returns the first sampled
    /// token and the KV slabs for handoff into a [`DecodeBatchState`].
    pub fn prefill(&self, prompt: &[i32]) -> Result<PrefillOutput> {
        let len = prompt.len();
        let bucket = self
            .bucket_for(len)
            .ok_or_else(|| anyhow!("prompt of {len} tokens exceeds largest bucket"))?;
        let exe = &self
            .prefill_exes
            .iter()
            .find(|(b, _)| *b == bucket)
            .unwrap()
            .1;

        let mut padded = vec![0i32; bucket];
        padded[..len].copy_from_slice(prompt);
        let tokens = self
            .client
            .buffer_from_host_buffer(&padded, &[1, bucket], None)?;
        let vlen = self
            .client
            .buffer_from_host_buffer(&[len as i32], &[], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tokens);
        args.push(&vlen);

        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let (first, k, v) = result.to_tuple3()?;
        Ok(PrefillOutput {
            first_token: first.to_vec::<i32>()?[0],
            k: k.to_vec::<f32>()?,
            v: v.to_vec::<f32>()?,
            bucket,
        })
    }

    /// One continuous-batching decode iteration over the batch state.
    /// Mutates `state` in place (KV row scatter + next tokens + lengths).
    pub fn decode_step(&self, state: &mut DecodeBatchState) -> Result<Vec<i32>> {
        let b = self.info.decode_batch;
        let (l, t, h, d) = (
            self.info.n_layers,
            self.info.max_seq_len,
            self.info.n_heads,
            self.info.head_dim,
        );
        let tokens = self
            .client
            .buffer_from_host_buffer(state.tokens(), &[b], None)?;
        let clen = self
            .client
            .buffer_from_host_buffer(state.cache_lens(), &[b], None)?;
        let k = self
            .client
            .buffer_from_host_buffer(state.k(), &[l, b, t, h, d], None)?;
        let v = self
            .client
            .buffer_from_host_buffer(state.v(), &[l, b, t, h, d], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.weights.iter().collect();
        args.push(&tokens);
        args.push(&k);
        args.push(&v);
        args.push(&clen);

        // Output: (next[B], k_rows[L,B,H,Dh], v_rows[L,B,H,Dh]) — the new
        // rows only; the full updated cache never crosses the device
        // boundary (EXPERIMENTS.md §Perf-L2).
        let result = self.decode_exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0]
            .to_literal_sync()?;
        let (next, k_rows, v_rows) = result.to_tuple3()?;
        let next = next.to_vec::<i32>()?;
        let k_rows = k_rows.to_vec::<f32>()?;
        let v_rows = v_rows.to_vec::<f32>()?;
        state.scatter_rows(&k_rows, &v_rows);
        state.advance(&next);
        Ok(next)
    }

    /// Fresh decode batch state sized for this model.
    pub fn new_decode_state(&self) -> DecodeBatchState {
        DecodeBatchState::new(
            self.info.n_layers,
            self.info.decode_batch,
            self.info.max_seq_len,
            self.info.n_heads,
            self.info.head_dim,
        )
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

fn compile_hlo(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

/// Load weights.bin into per-tensor device buffers following the manifest.
fn load_weights(dir: &Path, client: &xla::PjRtClient) -> Result<Vec<xla::PjRtBuffer>> {
    let man_path = dir.join("weights_manifest.json");
    let man = Json::parse(
        &std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {}", man_path.display()))?,
    )
    .map_err(|e| anyhow!("{}: {e}", man_path.display()))?;
    if man.req_str("dtype").map_err(|e| anyhow!("{e}"))? != "f32le" {
        bail!("unsupported weights dtype");
    }
    let blob = std::fs::read(dir.join("weights.bin"))?;
    let total = man.req_u64("total_bytes").map_err(|e| anyhow!("{e}"))? as usize;
    if blob.len() != total {
        bail!("weights.bin size {} != manifest {}", blob.len(), total);
    }
    let mut out = Vec::new();
    for t in man
        .get("tensors")
        .as_arr()
        .ok_or_else(|| anyhow!("manifest: missing tensors"))?
    {
        let off = t.req_u64("offset_bytes").map_err(|e| anyhow!("{e}"))? as usize;
        let size = t.req_u64("size_bytes").map_err(|e| anyhow!("{e}"))? as usize;
        let dims: Vec<usize> = t
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor without shape"))?
            .iter()
            .map(|x| x.as_u64().unwrap_or(0) as usize)
            .collect();
        let n = size / 4;
        let mut vals = vec![0f32; n];
        for (i, chunk) in blob[off..off + size].chunks_exact(4).enumerate() {
            vals[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        // Upload once; all executions borrow the device-resident buffer.
        out.push(client.buffer_from_host_buffer(&vals, &dims, None)?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Calibration: time the real executables, fit the simulator cost model.
// ---------------------------------------------------------------------------

/// Profile the loaded model's prefill/decode latencies and report a cost-
/// model fit (the `arrow calibrate` subcommand; EXPERIMENTS.md §Calib).
pub fn calibrate(dir: &str) -> Result<String> {
    use std::fmt::Write;
    let rt = ModelRuntime::load(PathBuf::from(dir))?;
    let mut s = String::new();
    writeln!(
        s,
        "calibrating '{}' on {} ({} params)",
        rt.info.name,
        rt.platform(),
        rt.info.n_params
    )?;

    // Prefill: one run per bucket (padded => cost is bucket-shaped).
    let mut prefill_samples: Vec<(u32, f64)> = Vec::new();
    for &bucket in rt.info.prefill_buckets.clone().iter() {
        let prompt: Vec<i32> = (0..bucket as i32).map(|i| (i * 7 + 3) % 101 + 1).collect();
        // Warm up compile caches.
        rt.prefill(&prompt)?;
        let reps = 3;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.prefill(&prompt)?;
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        writeln!(s, "  prefill s={bucket:<5} {:.2} ms", dt * 1e3)?;
        prefill_samples.push((bucket as u32, dt));
    }

    // Decode: vary active slots (batch token count).
    let mut decode_samples: Vec<(u64, f64)> = Vec::new();
    for active in 1..=rt.info.decode_batch {
        let mut st = rt.new_decode_state();
        let prompt: Vec<i32> = (1..40).collect();
        let pre = rt.prefill(&prompt)?;
        for slot in 0..active {
            st.insert_prefill(slot, prompt.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
        }
        rt.decode_step(&mut st)?; // warmup
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.decode_step(&mut st)?;
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        let toks = st.total_cached_tokens();
        writeln!(s, "  decode batch={active} tokens={toks:<6} {:.2} ms", dt * 1e3)?;
        decode_samples.push((toks, dt));
    }

    let mut model = crate::costmodel::CostModel::h800_llama8b();
    model.calibrate_from_samples(&prefill_samples, &decode_samples);
    writeln!(
        s,
        "fitted: iter_overhead={:.3}ms prefill_per_token={:.3}us prefill_quad={:.3e} decode_per_token={:.3}ns",
        model.iter_overhead * 1e3,
        model.prefill_per_token * 1e6,
        model.prefill_quad,
        model.decode_per_token * 1e9,
    )?;
    Ok(s)
}
