//! Host-side KV cache state for one decode engine: B slots of [T, H, Dh]
//! per layer, plus per-slot token/length bookkeeping.
//!
//! Slot lifecycle: `insert_prefill` scatters a prefill's `[L, S, H, Dh]`
//! KV slab into the slot (this memcpy IS the "KV migration" of the
//! disaggregated architecture when source ≠ target engine), `advance`
//! applies a decode step's outputs, `release` frees the slot.

/// KV + token state for a fixed-shape decode executable.
#[derive(Debug, Clone)]
pub struct DecodeBatchState {
    l: usize,
    b: usize,
    t: usize,
    h: usize,
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    tokens: Vec<i32>,
    cache_len: Vec<i32>,
    active: Vec<bool>,
}

impl DecodeBatchState {
    pub fn new(l: usize, b: usize, t: usize, h: usize, d: usize) -> Self {
        let n = l * b * t * h * d;
        DecodeBatchState {
            l,
            b,
            t,
            h,
            d,
            k: vec![0.0; n],
            v: vec![0.0; n],
            tokens: vec![0; b],
            cache_len: vec![0; b],
            active: vec![false; b],
        }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn capacity_per_slot(&self) -> usize {
        self.t
    }

    pub fn k(&self) -> &[f32] {
        &self.k
    }

    pub fn v(&self) -> &[f32] {
        &self.v
    }

    pub fn k_mut(&mut self) -> &mut [f32] {
        &mut self.k
    }

    pub fn v_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }

    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    pub fn cache_lens(&self) -> &[i32] {
        &self.cache_len
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.active[slot]
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.active.iter().position(|a| !a)
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    /// Total KV tokens cached across active slots (decode-load metric).
    pub fn total_cached_tokens(&self) -> u64 {
        self.cache_len.iter().map(|&c| c as u64).sum()
    }

    /// Slot length in tokens (prompt + generated so far).
    pub fn slot_len(&self, slot: usize) -> usize {
        self.cache_len[slot] as usize
    }

    /// Current last token of a slot.
    pub fn slot_token(&self, slot: usize) -> i32 {
        self.tokens[slot]
    }

    /// Scatter a prefill's KV slab `[L, S(bucket), H, Dh]` (first
    /// `prompt_len` positions valid) into `slot`, arming it for decode.
    pub fn insert_prefill(
        &mut self,
        slot: usize,
        prompt_len: usize,
        k: &[f32],
        v: &[f32],
        first_token: i32,
        bucket: usize,
    ) {
        assert!(slot < self.b, "slot out of range");
        assert!(prompt_len <= self.t, "prompt exceeds KV capacity");
        assert_eq!(k.len(), self.l * bucket * self.h * self.d, "bad k slab");
        assert_eq!(v.len(), k.len());
        let row = self.h * self.d; // one position's K (or V) for one layer
        for layer in 0..self.l {
            let src_base = layer * bucket * row;
            let dst_base = (layer * self.b + slot) * self.t * row;
            let n = prompt_len * row;
            self.k[dst_base..dst_base + n]
                .copy_from_slice(&k[src_base..src_base + n]);
            self.v[dst_base..dst_base + n]
                .copy_from_slice(&v[src_base..src_base + n]);
        }
        self.tokens[slot] = first_token;
        self.cache_len[slot] = prompt_len as i32;
        self.active[slot] = true;
    }

    /// Scatter a decode step's new K/V rows (`[L, B, H, Dh]` each) into
    /// every slot at its current `cache_len` position — the host-side
    /// half of the rows-only decode output (runtime perf optimization;
    /// matches the in-graph `at[i, b, pos].set(...)` semantics exactly,
    /// including idle slots writing harmlessly at position 0).
    pub fn scatter_rows(&mut self, k_rows: &[f32], v_rows: &[f32]) {
        let row = self.h * self.d;
        assert_eq!(k_rows.len(), self.l * self.b * row, "bad k_rows");
        assert_eq!(v_rows.len(), k_rows.len());
        for layer in 0..self.l {
            for slot in 0..self.b {
                let pos = self.cache_len[slot] as usize;
                debug_assert!(pos < self.t, "KV capacity overflow");
                let src = (layer * self.b + slot) * row;
                let dst = (layer * self.b + slot) * self.t * row + pos * row;
                self.k[dst..dst + row].copy_from_slice(&k_rows[src..src + row]);
                self.v[dst..dst + row].copy_from_slice(&v_rows[src..src + row]);
            }
        }
    }

    /// Apply a decode step's sampled tokens: active slots grow by one.
    /// (`scatter_rows` placed the new K/V at position `cache_len` first.)
    pub fn advance(&mut self, next_tokens: &[i32]) {
        assert_eq!(next_tokens.len(), self.b);
        for slot in 0..self.b {
            if self.active[slot] {
                self.tokens[slot] = next_tokens[slot];
                self.cache_len[slot] += 1;
            }
        }
    }

    /// Free a slot (request finished or migrated away).
    pub fn release(&mut self, slot: usize) {
        self.active[slot] = false;
        self.cache_len[slot] = 0;
        self.tokens[slot] = 0;
    }

    /// Extract a slot's KV as a compact `[L, len, H, Dh]` slab — the
    /// outbound half of a KV migration between engines.
    pub fn extract(&self, slot: usize) -> (Vec<f32>, Vec<f32>, usize) {
        let len = self.cache_len[slot] as usize;
        let row = self.h * self.d;
        let mut k = vec![0.0f32; self.l * len * row];
        let mut v = vec![0.0f32; self.l * len * row];
        for layer in 0..self.l {
            let src_base = (layer * self.b + slot) * self.t * row;
            let dst_base = layer * len * row;
            let n = len * row;
            k[dst_base..dst_base + n].copy_from_slice(&self.k[src_base..src_base + n]);
            v[dst_base..dst_base + n].copy_from_slice(&self.v[src_base..src_base + n]);
        }
        (k, v, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> DecodeBatchState {
        DecodeBatchState::new(2, 3, 8, 2, 4)
    }

    #[test]
    fn fresh_state_inactive() {
        let s = state();
        assert_eq!(s.active_count(), 0);
        assert_eq!(s.free_slot(), Some(0));
        assert_eq!(s.total_cached_tokens(), 0);
    }

    #[test]
    fn insert_scatters_per_layer() {
        let mut s = state();
        let bucket = 4;
        let row = 2 * 4; // h*d
        let n = 2 * bucket * row;
        let k: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..n).map(|i| (i as f32) * 10.0).collect();
        s.insert_prefill(1, 3, &k, &v, 42, bucket);
        assert!(s.is_active(1));
        assert_eq!(s.slot_len(1), 3);
        assert_eq!(s.slot_token(1), 42);
        // Layer 0, slot 1, position 0 must equal k[0..row].
        let dst = (0 * 3 + 1) * 8 * row;
        assert_eq!(&s.k()[dst..dst + row], &k[0..row]);
        // Layer 1, slot 1, position 2.
        let dst = (1 * 3 + 1) * 8 * row + 2 * row;
        let src = 1 * bucket * row + 2 * row;
        assert_eq!(&s.k()[dst..dst + row], &k[src..src + row]);
        assert_eq!(&s.v()[dst..dst + row], &v[src..src + row]);
    }

    #[test]
    fn advance_only_touches_active() {
        let mut s = state();
        let bucket = 4;
        let n = 2 * bucket * 8;
        s.insert_prefill(0, 2, &vec![0.0; n], &vec![0.0; n], 7, bucket);
        s.advance(&[11, 22, 33]);
        assert_eq!(s.slot_token(0), 11);
        assert_eq!(s.slot_len(0), 3);
        assert_eq!(s.slot_token(1), 0, "inactive slot untouched");
        assert_eq!(s.slot_len(1), 0);
    }

    #[test]
    fn release_frees_slot() {
        let mut s = state();
        let n = 2 * 4 * 8;
        s.insert_prefill(0, 2, &vec![0.0; n], &vec![0.0; n], 7, 4);
        assert_eq!(s.free_slot(), Some(1));
        s.release(0);
        assert_eq!(s.free_slot(), Some(0));
        assert_eq!(s.total_cached_tokens(), 0);
    }

    #[test]
    fn extract_roundtrips_insert() {
        let mut s = state();
        let bucket = 4;
        let row = 8;
        let n = 2 * bucket * row;
        let k: Vec<f32> = (0..n).map(|i| i as f32 + 1.0).collect();
        let v: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        s.insert_prefill(2, 3, &k, &v, 5, bucket);
        let (ke, ve, len) = s.extract(2);
        assert_eq!(len, 3);
        // Extracted slab is [L, 3, H, D]; compare with source prefix
        // layer by layer.
        for layer in 0..2 {
            let src = layer * bucket * row;
            let dst = layer * 3 * row;
            assert_eq!(&ke[dst..dst + 3 * row], &k[src..src + 3 * row]);
            assert_eq!(&ve[dst..dst + 3 * row], &v[src..src + 3 * row]);
        }
    }

    #[test]
    #[should_panic(expected = "prompt exceeds KV capacity")]
    fn insert_rejects_overlong_prompt() {
        let mut s = state();
        let n = 2 * 16 * 8;
        s.insert_prefill(0, 16, &vec![0.0; n], &vec![0.0; n], 1, 16);
    }
}
