//! Trace persistence: JSONL (one request per line) — the same shape the
//! public Azure/BurstGPT trace releases use (arrival, input, output), so
//! real traces can be dropped in without code changes.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use super::Trace;
use crate::json::Json;
use crate::request::Request;

/// Save as JSONL: `{"ts": <sec>, "input": <tokens>, "output": <tokens>}`.
pub fn save_jsonl(trace: &Trace, path: &Path) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for r in &trace.requests {
        let line = Json::obj(vec![
            ("ts", Json::Num(r.arrival)),
            ("input", Json::Num(r.input_len as f64)),
            ("output", Json::Num(r.output_len as f64)),
        ]);
        writeln!(w, "{}", line.encode())?;
    }
    Ok(())
}

/// Load a JSONL trace. Lines must carry `ts`, `input`, `output`; ids are
/// assigned by line order after sorting by timestamp.
pub fn load_jsonl(name: &str, path: &Path) -> std::io::Result<Trace> {
    let reader = BufReader::new(File::open(path)?);
    let mut requests = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("{}:{}: {}", path.display(), i + 1, e),
            )
        })?;
        let ts = v.get("ts").as_f64().ok_or_else(|| bad(path, i, "ts"))?;
        let input = v.get("input").as_u64().ok_or_else(|| bad(path, i, "input"))?;
        let output = v
            .get("output")
            .as_u64()
            .ok_or_else(|| bad(path, i, "output"))?;
        requests.push(Request::new(i as u64, ts, input as u32, output as u32));
    }
    Ok(Trace::new(name, requests))
}

fn bad(path: &Path, line: usize, field: &str) -> std::io::Error {
    std::io::Error::new(
        std::io::ErrorKind::InvalidData,
        format!("{}:{}: missing field '{}'", path.display(), line + 1, field),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic::smoke;

    #[test]
    fn roundtrip_jsonl() {
        let t = smoke(100, 2).generate(1);
        let dir = std::env::temp_dir().join("arrow_trace_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        save_jsonl(&t, &path).unwrap();
        let back = load_jsonl("t", &path).unwrap();
        assert_eq!(back.len(), t.len());
        for (a, b) in t.requests.iter().zip(&back.requests) {
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.output_len, b.output_len);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("arrow_trace_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{\"ts\": 1.0}\n").unwrap();
        assert!(load_jsonl("bad", &path).is_err());
        std::fs::write(&path, "not json\n").unwrap();
        assert!(load_jsonl("bad", &path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_lines_skipped() {
        let dir = std::env::temp_dir().join("arrow_trace_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sparse.jsonl");
        std::fs::write(
            &path,
            "{\"ts\":0.5,\"input\":10,\"output\":5}\n\n{\"ts\":1.5,\"input\":20,\"output\":2}\n",
        )
        .unwrap();
        let t = load_jsonl("sparse", &path).unwrap();
        assert_eq!(t.len(), 2);
        std::fs::remove_file(&path).unwrap();
    }
}
