//! Streaming arrival sources (PR 7): the event loop's calendar cursor
//! consumes arrivals one at a time, so a sweep never materializes a
//! `Vec<Request>` for the whole trace.
//!
//! Contract: a source yields requests in **nondecreasing arrival order**
//! (ties in generation order), exactly the order of the corresponding
//! materialized `Trace`'s sorted `requests` vector. Request *ids* carried
//! by a source are advisory — the simulator re-normalizes ids to the
//! arrival index, which is what makes a [`SyntheticSource`] run
//! byte-identical to running the materialized `WorkloadSpec::generate`
//! trace (pinned by `tests/streaming.rs`).

use super::synthetic::WorkloadSpec;
use super::Trace;
use crate::request::Request;
use crate::util::rng::Rng;

/// A lazily-consumed stream of trace arrivals.
pub trait ArrivalSource {
    /// The next request, in nondecreasing arrival order; `None` once the
    /// source is exhausted (it stays exhausted — fused).
    fn next_request(&mut self) -> Option<Request>;

    /// How many requests this source will yield in total, if cheaply
    /// known (used only for capacity hints, never for control flow).
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Cursor over a materialized trace's (already sorted) request slice —
/// the bridge that lets every existing `Trace` run through the streaming
/// entry point, and the equivalence oracle's view of the same data.
pub struct TraceSource<'a> {
    requests: &'a [Request],
    pos: usize,
}

impl<'a> TraceSource<'a> {
    pub fn new(trace: &'a Trace) -> Self {
        TraceSource {
            requests: &trace.requests,
            pos: 0,
        }
    }

    /// Stream an arbitrary arrival-sorted slice.
    pub fn from_slice(requests: &'a [Request]) -> Self {
        TraceSource { requests, pos: 0 }
    }
}

impl ArrivalSource for TraceSource<'_> {
    fn next_request(&mut self) -> Option<Request> {
        let r = self.requests.get(self.pos).copied();
        self.pos += r.is_some() as usize;
        r
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.requests.len())
    }
}

/// Lazy synthetic generator: identical RNG consumption to
/// `WorkloadSpec::generate`, but holding only the per-minute weight table
/// (O(duration_min)) and one minute's batch (O(arrivals/minute)) instead
/// of the full trace.
pub struct SyntheticSource {
    spec: WorkloadSpec,
    rng: Rng,
    weights: Vec<f64>,
    total_w: f64,
    minute: usize,
    batch: Vec<Request>,
    pos: usize,
    next_id: u64,
}

impl SyntheticSource {
    pub fn new(spec: &WorkloadSpec, seed: u64) -> Self {
        let (rng, weights, total_w) = spec.arrival_setup(seed);
        SyntheticSource {
            spec: spec.clone(),
            rng,
            weights,
            total_w,
            minute: 0,
            batch: Vec::new(),
            pos: 0,
            next_id: 0,
        }
    }
}

impl ArrivalSource for SyntheticSource {
    fn next_request(&mut self) -> Option<Request> {
        loop {
            if self.pos < self.batch.len() {
                let r = self.batch[self.pos];
                self.pos += 1;
                return Some(r);
            }
            if self.minute >= self.weights.len() {
                return None;
            }
            let minute = self.minute;
            self.minute += 1;
            let lam = self.spec.n_requests as f64 * self.weights[minute] / self.total_w;
            self.spec
                .minute_batch(&mut self.rng, minute, lam, &mut self.next_id, &mut self.batch);
            self.pos = 0;
        }
    }
}

/// Timestamp rescale — the streaming twin of `Trace::with_rate`, which
/// multiplies every arrival by `k = current_rate / target_rate`. Same
/// arithmetic (`arrival * k`), so the streamed request is bit-identical
/// to the rescaled trace's. Monotone for `k > 0`, so order is preserved.
pub struct Scaled<S> {
    inner: S,
    k: f64,
}

impl<S: ArrivalSource> Scaled<S> {
    pub fn new(inner: S, k: f64) -> Self {
        assert!(k > 0.0 && k.is_finite(), "bad time-scale factor {k}");
        Scaled { inner, k }
    }
}

impl<S: ArrivalSource> ArrivalSource for Scaled<S> {
    fn next_request(&mut self) -> Option<Request> {
        self.inner.next_request().map(|r| Request {
            arrival: r.arrival * self.k,
            ..r
        })
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }
}

/// Prefix clip — the streaming twin of `Trace::clip_seconds(secs)`, which
/// keeps requests with `arrival <= secs`. On an arrival-sorted stream
/// that is a prefix, so the clip stops (and fuses) at the first arrival
/// past the cutoff. NaN arrivals compare `false` here and sort last in
/// the materialized path — both drop them.
pub struct Clipped<S> {
    inner: S,
    secs: f64,
    done: bool,
}

impl<S: ArrivalSource> Clipped<S> {
    pub fn new(inner: S, secs: f64) -> Self {
        Clipped {
            inner,
            secs,
            done: false,
        }
    }
}

impl<S: ArrivalSource> ArrivalSource for Clipped<S> {
    fn next_request(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        match self.inner.next_request() {
            Some(r) if r.arrival <= self.secs => Some(r),
            _ => {
                self.done = true;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synthetic;

    fn drain(mut s: impl ArrivalSource) -> Vec<Request> {
        let mut v = Vec::new();
        while let Some(r) = s.next_request() {
            v.push(r);
        }
        v
    }

    /// The core PR 7 generator equivalence: lazy emission matches the
    /// materialized trace bit-for-bit — arrivals, lengths, ids, order —
    /// for every catalog workload.
    #[test]
    fn synthetic_source_matches_generate_exactly() {
        for spec in [
            synthetic::azure_code(),
            synthetic::azure_conversation(),
            synthetic::burstgpt(),
            synthetic::mooncake_conversation(),
            synthetic::smoke(500, 5),
        ] {
            for seed in [1u64, 42] {
                let trace = spec.generate(seed);
                let streamed = drain(SyntheticSource::new(&spec, seed));
                assert_eq!(
                    trace.requests.len(),
                    streamed.len(),
                    "{} seed {seed}",
                    spec.name
                );
                for (i, (a, b)) in trace.requests.iter().zip(&streamed).enumerate() {
                    assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "req {i}");
                    assert_eq!((a.id, a.input_len, a.output_len), (b.id, b.input_len, b.output_len));
                }
            }
        }
    }

    #[test]
    fn synthetic_source_is_fused_and_sorted() {
        let spec = synthetic::smoke(300, 4);
        let mut src = SyntheticSource::new(&spec, 9);
        let mut prev = f64::NEG_INFINITY;
        let mut n = 0usize;
        while let Some(r) = src.next_request() {
            assert!(r.arrival >= prev, "unsorted stream");
            prev = r.arrival;
            n += 1;
        }
        assert!(n > 0);
        assert!(src.next_request().is_none(), "fused after exhaustion");
        assert!(src.next_request().is_none());
    }

    #[test]
    fn scaled_matches_with_rate() {
        let trace = synthetic::smoke(200, 3).generate(5);
        let target = trace.rate() * 2.5;
        let rescaled = trace.with_rate(target);
        let k = trace.rate() / target;
        let streamed = drain(Scaled::new(TraceSource::new(&trace), k));
        assert_eq!(streamed.len(), rescaled.requests.len());
        for (a, b) in rescaled.requests.iter().zip(&streamed) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
    }

    #[test]
    fn clipped_matches_clip_seconds() {
        let trace = synthetic::smoke(200, 5).generate(6);
        let cut = 0.6 * trace.duration();
        let clipped = trace.clip_seconds(cut);
        let streamed = drain(Clipped::new(TraceSource::new(&trace), cut));
        assert_eq!(streamed.len(), clipped.requests.len());
        for (a, b) in clipped.requests.iter().zip(&streamed) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
        // Clip boundary is inclusive, like the materialized filter.
        let boundary = Trace::new(
            "b",
            vec![Request::new(0, 1.0, 4, 4), Request::new(1, 2.0, 4, 4)],
        );
        let kept = drain(Clipped::new(TraceSource::new(&boundary), 1.0));
        assert_eq!(kept.len(), 1);
    }

    #[test]
    fn trace_source_len_hint() {
        let trace = synthetic::smoke(50, 2).generate(3);
        let src = TraceSource::new(&trace);
        assert_eq!(src.len_hint(), Some(trace.len()));
    }
}
