//! Workload traces: synthetic generators for the four production traces
//! the paper evaluates on, plus load/save and rate-rescaling machinery.
//!
//! The real Azure / BurstGPT / Mooncake traces are not available offline;
//! `synthetic.rs` reproduces their *published statistics* (request counts,
//! arrival burstiness cv, length distributions, input↔output correlation
//! — paper §3.1 and Table 1). See DESIGN.md §3 for the substitution
//! rationale.

pub mod catalog;
pub mod io;
pub mod stream;
pub mod synthetic;

pub use stream::{ArrivalSource, SyntheticSource, TraceSource};

use crate::request::Request;

/// A workload trace: requests sorted by arrival time.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub name: String,
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn new(name: &str, mut requests: Vec<Request>) -> Self {
        // total_cmp: a NaN arrival from a malformed trace file sorts last
        // instead of panicking the loader.
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        Trace {
            name: name.to_string(),
            requests,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.arrival).unwrap_or(0.0)
    }

    /// Mean request rate over the trace (req/s).
    pub fn rate(&self) -> f64 {
        let d = self.duration();
        if d > 0.0 {
            self.len() as f64 / d
        } else {
            0.0
        }
    }

    /// Rescale to a target request rate by multiplying timestamps — the
    /// paper's evaluation workflow (§7.1: "we multiply the timestamps by a
    /// constant to simulate varying request rates").
    pub fn with_rate(&self, target_rate: f64) -> Trace {
        assert!(target_rate > 0.0);
        let cur = self.rate();
        assert!(cur > 0.0, "cannot rescale an instantaneous trace");
        let k = cur / target_rate;
        let requests = self
            .requests
            .iter()
            .map(|r| Request {
                arrival: r.arrival * k,
                ..*r
            })
            .collect();
        Trace {
            name: format!("{}@{:.2}rps", self.name, target_rate),
            requests,
        }
    }

    /// Clip to the first `secs` seconds (paper takes 10-minute / 1-hour
    /// clips of Mooncake / BurstGPT).
    pub fn clip_seconds(&self, secs: f64) -> Trace {
        Trace {
            name: format!("{}[0..{}s]", self.name, secs),
            requests: self
                .requests
                .iter()
                .filter(|r| r.arrival <= secs)
                .copied()
                .collect(),
        }
    }

    /// Clip to a time window [from, to) and shift arrivals to start at 0
    /// (Fig. 4 uses the Azure Conversation minutes 20-40).
    pub fn window(&self, from: f64, to: f64) -> Trace {
        Trace {
            name: format!("{}[{}..{}s]", self.name, from, to),
            requests: self
                .requests
                .iter()
                .filter(|r| r.arrival >= from && r.arrival < to)
                .map(|r| Request {
                    arrival: r.arrival - from,
                    ..*r
                })
                .collect(),
        }
    }

    /// Take the first n requests.
    pub fn take(&self, n: usize) -> Trace {
        Trace {
            name: self.name.clone(),
            requests: self.requests.iter().take(n).copied().collect(),
        }
    }

    /// Per-minute total input/output token sums — the Fig. 1 series.
    pub fn per_minute_load(&self) -> Vec<MinuteLoad> {
        let mut out: Vec<MinuteLoad> = Vec::new();
        for r in &self.requests {
            let m = (r.arrival / 60.0).floor() as usize;
            if out.len() <= m {
                out.resize(
                    m + 1,
                    MinuteLoad {
                        minute: 0,
                        input_tokens: 0,
                        output_tokens: 0,
                        requests: 0,
                    },
                );
            }
            let slot = &mut out[m];
            slot.minute = m;
            slot.input_tokens += r.input_len as u64;
            slot.output_tokens += r.output_len as u64;
            slot.requests += 1;
        }
        for (i, s) in out.iter_mut().enumerate() {
            s.minute = i;
        }
        out
    }

    /// Summary statistics used to validate generators against the paper's
    /// published numbers (§3.1).
    pub fn stats(&self) -> TraceStats {
        use crate::util::stats as st;
        let mut inputs: Vec<f64> = self.requests.iter().map(|r| r.input_len as f64).collect();
        let mut outputs: Vec<f64> =
            self.requests.iter().map(|r| r.output_len as f64).collect();
        let per_min = self.per_minute_load();
        let min_inputs: Vec<f64> = per_min.iter().map(|m| m.input_tokens as f64).collect();
        // Order-dependent statistics first (pearson needs the pairing,
        // mean is order-blind), then selection-based percentiles reorder
        // the same buffers in place — no clone-and-full-sort per
        // percentile (this runs once per generated trace in the sweeps).
        let io_correlation = st::pearson(&inputs, &outputs);
        let mean_input = st::mean(&inputs);
        let mean_output = st::mean(&outputs);
        TraceStats {
            n: self.len(),
            duration_s: self.duration(),
            mean_input,
            median_input: st::percentile_in_place(&mut inputs, 50.0),
            p99_input: st::percentile_in_place(&mut inputs, 99.0),
            mean_output,
            median_output: st::percentile_in_place(&mut outputs, 50.0),
            p99_output: st::percentile_in_place(&mut outputs, 99.0),
            io_correlation,
            minute_input_cv: st::coeff_of_variation(&min_inputs),
        }
    }
}

/// One minute of aggregate load (Fig. 1 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MinuteLoad {
    pub minute: usize,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub requests: u64,
}

/// Aggregate statistics of a trace (validation against §3.1 numbers).
#[derive(Debug, Clone)]
pub struct TraceStats {
    pub n: usize,
    pub duration_s: f64,
    pub mean_input: f64,
    pub median_input: f64,
    pub p99_input: f64,
    pub mean_output: f64,
    pub median_output: f64,
    pub p99_output: f64,
    pub io_correlation: f64,
    pub minute_input_cv: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> Trace {
        Trace::new(
            "t",
            vec![
                Request::new(0, 10.0, 100, 20),
                Request::new(1, 0.0, 50, 10),
                Request::new(2, 70.0, 200, 5),
            ],
        )
    }

    #[test]
    fn constructor_sorts_by_arrival() {
        let t = mk();
        assert_eq!(t.requests[0].id.0, 1);
        assert_eq!(t.requests[2].id.0, 2);
    }

    #[test]
    fn rate_rescaling_changes_rate() {
        let t = mk();
        let fast = t.with_rate(t.rate() * 2.0);
        assert!((fast.rate() - t.rate() * 2.0).abs() / t.rate() < 1e-9);
        // Lengths untouched.
        assert_eq!(fast.requests[0].input_len, t.requests[0].input_len);
        // Order preserved.
        assert!(fast.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn window_shifts_to_zero() {
        let t = mk();
        let w = t.window(5.0, 60.0);
        assert_eq!(w.len(), 1);
        assert!((w.requests[0].arrival - 5.0).abs() < 1e-12);
    }

    #[test]
    fn per_minute_load_buckets() {
        let t = mk();
        let pm = t.per_minute_load();
        assert_eq!(pm.len(), 2);
        assert_eq!(pm[0].requests, 2);
        assert_eq!(pm[0].input_tokens, 150);
        assert_eq!(pm[1].requests, 1);
        assert_eq!(pm[1].output_tokens, 5);
    }

    #[test]
    fn clip_keeps_prefix() {
        let t = mk();
        assert_eq!(t.clip_seconds(10.0).len(), 2);
        assert_eq!(t.take(1).len(), 1);
    }

    #[test]
    fn stats_shapes() {
        let s = mk().stats();
        assert_eq!(s.n, 3);
        assert!(s.mean_input > 0.0);
        assert!(s.io_correlation.abs() <= 1.0 + 1e-12);
    }
}
