//! Workload catalog: Table 1 of the paper — the four evaluation traces
//! with their request counts and SLO settings — plus lookup by name.

use super::synthetic::{
    azure_code, azure_conversation, burstgpt, mooncake_conversation, smoke,
    WorkloadSpec,
};
use super::Trace;

/// One Table-1 row: a workload plus its SLO targets.
#[derive(Debug, Clone)]
pub struct Workload {
    pub spec: WorkloadSpec,
    /// TTFT SLO in seconds (Table 1).
    pub ttft_slo: f64,
    /// TPOT SLO in seconds (Table 1).
    pub tpot_slo: f64,
}

impl Workload {
    pub fn name(&self) -> &'static str {
        self.spec.name
    }

    pub fn generate(&self, seed: u64) -> Trace {
        self.spec.generate(seed)
    }
}

/// Table 1, row by row.
pub fn table1() -> Vec<Workload> {
    vec![
        Workload {
            spec: azure_code(),
            ttft_slo: 3.0,
            tpot_slo: 0.1,
        },
        Workload {
            spec: azure_conversation(),
            ttft_slo: 2.0,
            tpot_slo: 0.15,
        },
        Workload {
            spec: burstgpt(),
            ttft_slo: 0.25,
            tpot_slo: 0.075,
        },
        Workload {
            spec: mooncake_conversation(),
            ttft_slo: 30.0,
            tpot_slo: 0.1,
        },
    ]
}

/// Look a workload up by name; also accepts the `smoke` test workload.
pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "smoke" => Some(Workload {
            spec: smoke(500, 5),
            ttft_slo: 2.0,
            tpot_slo: 0.1,
        }),
        _ => table1().into_iter().find(|w| w.name() == name),
    }
}

pub fn names() -> Vec<&'static str> {
    let mut v: Vec<&'static str> = table1().iter().map(|w| w.name()).collect();
    v.push("smoke");
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let t = table1();
        assert_eq!(t.len(), 4);
        let code = &t[0];
        assert_eq!(code.name(), "azure_code");
        assert_eq!(code.spec.n_requests, 8819);
        assert_eq!(code.ttft_slo, 3.0);
        assert_eq!(code.tpot_slo, 0.1);
        let conv = &t[1];
        assert_eq!(conv.spec.n_requests, 19366);
        assert_eq!((conv.ttft_slo, conv.tpot_slo), (2.0, 0.15));
        let bg = &t[2];
        assert_eq!(bg.spec.n_requests, 6009);
        assert_eq!((bg.ttft_slo, bg.tpot_slo), (0.25, 0.075));
        let mc = &t[3];
        assert_eq!(mc.spec.n_requests, 1756);
        assert_eq!((mc.ttft_slo, mc.tpot_slo), (30.0, 0.1));
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("azure_code").is_some());
        assert!(by_name("smoke").is_some());
        assert!(by_name("nope").is_none());
        for n in names() {
            assert!(by_name(n).is_some(), "{n}");
        }
    }

    #[test]
    fn names_and_by_name_round_trip_exactly() {
        // names() -> by_name -> name() must be the identity, the catalog
        // must contain no duplicates, and by_name must agree with the
        // Table-1 row it resolves to (same spec target + SLOs) — the
        // claims harness keys everything on these names.
        let ns = names();
        assert_eq!(ns.len(), table1().len() + 1, "table1 + smoke");
        for n in &ns {
            let w = by_name(n).unwrap_or_else(|| panic!("{n} in names() but not by_name"));
            assert_eq!(w.name(), *n, "by_name({n}) resolved to {}", w.name());
        }
        let mut sorted = ns.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ns.len(), "duplicate workload names");
        for row in table1() {
            let via = by_name(row.name()).unwrap();
            assert_eq!(via.spec.n_requests, row.spec.n_requests, "{}", row.name());
            assert_eq!(via.ttft_slo, row.ttft_slo, "{}", row.name());
            assert_eq!(via.tpot_slo, row.tpot_slo, "{}", row.name());
        }
    }

    #[test]
    fn generate_is_seed_deterministic_for_every_workload() {
        // Same seed => byte-identical trace (every request field equal,
        // arrival bits included — `Request` is PartialEq over exact f64),
        // for all Table-1 workloads and the smoke workload. The claims
        // and golden tiers depend on this holding for the *whole* trace,
        // not a prefix.
        for n in names() {
            let w = by_name(n).unwrap();
            let a = w.generate(42);
            let b = w.generate(42);
            assert_eq!(a.len(), b.len(), "{n}: length drifted across same-seed runs");
            assert_eq!(a.requests, b.requests, "{n}: same seed must be byte-identical");
            assert_eq!(a.name, b.name, "{n}");
        }
    }

    #[test]
    fn different_seeds_change_the_arrivals_for_every_workload() {
        for n in names() {
            let w = by_name(n).unwrap();
            let a = w.generate(1);
            let b = w.generate(2);
            // Arrival *times* must differ somewhere (lengths could
            // coincide by chance for a few requests, timestamps cannot
            // across a whole trace from an independent stream).
            let arrivals = |t: &crate::trace::Trace| {
                t.requests.iter().map(|r| r.arrival.to_bits()).collect::<Vec<_>>()
            };
            assert_ne!(
                arrivals(&a),
                arrivals(&b),
                "{n}: different seeds produced identical arrival sequences"
            );
        }
    }
}
