//! Synthetic workload generators reproducing the published statistics of
//! the paper's four production traces (§3.1, Table 1).
//!
//! The real traces are unavailable offline, so each generator is tuned to
//! match what the paper reports:
//!
//! * request count & duration (Table 1),
//! * per-minute input-token burstiness: cv = 0.80 (Azure Code),
//!   1.11 (BurstGPT), 0.16 (Mooncake Conversation),
//! * input↔output length correlation: r = 0.95 (Azure Code),
//!   0.29 (Azure Conversation),
//! * length distributions: Azure Code has large median inputs / small
//!   median outputs; Azure Conversation the reverse; Mooncake features
//!   extremely long inputs (Fig. 2 CDF shapes).
//!
//! Arrivals are a doubly-stochastic (Cox) process: per-minute intensity is
//! an AR(1) lognormal random walk plus occasional burst spikes; request
//! arrivals are then Poisson within each minute. Lengths come from a
//! correlated lognormal pair pushed through per-trace clamps, so both the
//! marginal CDFs and the joint correlation are controlled.

use super::Trace;
use crate::request::{Request, SloClass};
use crate::util::rng::Rng;

/// Deterministic SLO-class mix of a workload (PR 8): the fraction of
/// requests assigned to the interactive and batch tiers (the remainder is
/// standard). The default is all-zero — every request stays
/// [`SloClass::Standard`] and generation is *bit-identical* to the
/// pre-class trace layer (assignment is skipped entirely, and it never
/// consumes the arrival/length RNG stream in any case).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ClassMix {
    /// Fraction of requests in the interactive tier, in [0, 1].
    pub interactive: f64,
    /// Fraction of requests in the batch tier, in [0, 1].
    pub batch: f64,
}

impl ClassMix {
    /// All-standard mix — the transparent default.
    pub fn standard_only() -> ClassMix {
        ClassMix::default()
    }

    pub fn is_single_class(&self) -> bool {
        self.interactive == 0.0 && self.batch == 0.0
    }

    /// Deterministic, seed-free class of request `id`: a bit-mixed hash
    /// of the id mapped to [0, 1) and cut against the mix fractions.
    /// Independent of the trace RNG stream, so turning a mix on or off
    /// never perturbs arrivals or lengths — only the `class` field.
    pub fn assign(&self, id: u64) -> SloClass {
        if self.is_single_class() {
            return SloClass::Standard;
        }
        // splitmix64 finalizer: uniform bits from sequential ids.
        let mut h = id.wrapping_add(0x9e3779b97f4a7c15);
        h = (h ^ (h >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        h = (h ^ (h >> 27)).wrapping_mul(0x94d049bb133111eb);
        h ^= h >> 31;
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.interactive {
            SloClass::Interactive
        } else if u < self.interactive + self.batch {
            SloClass::Batch
        } else {
            SloClass::Standard
        }
    }
}

/// Complete parameterization of one synthetic workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Target number of requests in the trace.
    pub n_requests: usize,
    /// Trace duration in minutes.
    pub duration_min: usize,
    // --- arrival process ---
    /// AR(1) coefficient of the log-intensity walk (0 = iid, ~1 = smooth).
    pub intensity_ar: f64,
    /// Std-dev of the log-intensity innovations (drives per-minute cv).
    pub intensity_sigma: f64,
    /// Probability a given minute is a burst spike.
    pub burst_prob: f64,
    /// Intensity multiplier during a burst minute.
    pub burst_mult: f64,
    // --- length distributions (lognormal, token units) ---
    pub input_log_mu: f64,
    pub input_log_sigma: f64,
    pub output_log_mu: f64,
    pub output_log_sigma: f64,
    /// Latent Gaussian correlation between input and output lengths.
    pub io_rho: f64,
    pub max_input: u32,
    pub max_output: u32,
    /// SLO-class mix (PR 8). Defaults to all-standard, which leaves the
    /// generated trace bit-identical to the pre-class generator.
    pub class_mix: ClassMix,
}

impl WorkloadSpec {
    /// Deterministically generate the trace for a seed.
    ///
    /// Built from the same [`WorkloadSpec::arrival_setup`] /
    /// [`WorkloadSpec::minute_batch`] phases the streaming
    /// `trace::stream::SyntheticSource` consumes lazily, so both paths
    /// draw from the RNG identically and yield the same requests in the
    /// same order (PR 7 equivalence tests pin this bit-for-bit).
    pub fn generate(&self, seed: u64) -> Trace {
        let (mut rng, weights, total_w) = self.arrival_setup(seed);
        let mut requests = Vec::with_capacity(self.n_requests + 64);
        let mut id = 0u64;
        let mut batch = Vec::new();
        for (minute, w) in weights.iter().enumerate() {
            let lam = self.n_requests as f64 * w / total_w;
            self.minute_batch(&mut rng, minute, lam, &mut id, &mut batch);
            requests.extend_from_slice(&batch);
        }
        Trace::new(self.name, requests)
    }

    /// Phase 1 of generation: the seeded RNG plus the per-minute
    /// intensity weights and their sum. O(duration_min) memory — the one
    /// part of the arrival process that cannot stream, because every
    /// minute's Poisson mean is normalized by the total weight.
    pub(crate) fn arrival_setup(&self, seed: u64) -> (Rng, Vec<f64>, f64) {
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        let mut log_i = 0.0f64;
        let mut weights = Vec::with_capacity(self.duration_min);
        for _ in 0..self.duration_min {
            log_i = self.intensity_ar * log_i
                + self.intensity_sigma * rng.normal();
            let mut w = log_i.exp();
            if rng.bool(self.burst_prob) {
                w *= self.burst_mult;
            }
            weights.push(w);
        }
        let total_w: f64 = weights.iter().sum();
        (rng, weights, total_w)
    }

    /// Phase 2, one minute at a time: Poisson count, then per-request
    /// arrival + lengths, then a *stable* in-batch sort by arrival.
    /// A minute-`m` arrival is `(m + f) * 60` with `f in [0, 1)`, so it
    /// never exceeds `60 * (m + 1)` — stably-sorted batches concatenate
    /// to exactly the globally stable-sorted trace `Trace::new` builds
    /// (boundary ties keep generation order either way).
    pub(crate) fn minute_batch(
        &self,
        rng: &mut Rng,
        minute: usize,
        lam: f64,
        id: &mut u64,
        out: &mut Vec<Request>,
    ) {
        out.clear();
        let count = poisson(rng, lam);
        let single_class = self.class_mix.is_single_class();
        for _ in 0..count {
            let arrival = (minute as f64 + rng.f64()) * 60.0;
            let (inp, outl) = self.sample_lengths(rng);
            // Class assignment hashes the id — it never touches `rng`, so
            // arrivals/lengths are identical whatever the mix; the
            // single-class fast path skips even the hash.
            let mut r = Request::new(*id, arrival, inp, outl);
            if !single_class {
                r = r.with_class(self.class_mix.assign(*id));
            }
            out.push(r);
            *id += 1;
        }
        out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    }

    /// Builder-style class-mix override (claims harness / tests).
    pub fn with_class_mix(mut self, mix: ClassMix) -> Self {
        self.class_mix = mix;
        self
    }

    /// Correlated lognormal input/output lengths.
    fn sample_lengths(&self, rng: &mut Rng) -> (u32, u32) {
        let z1 = rng.normal();
        let z2 = self.io_rho * z1 + (1.0 - self.io_rho * self.io_rho).sqrt() * rng.normal();
        let inp = (self.input_log_mu + self.input_log_sigma * z1).exp();
        let out = (self.output_log_mu + self.output_log_sigma * z2).exp();
        (
            (inp.round() as u32).clamp(1, self.max_input),
            (out.round() as u32).clamp(1, self.max_output),
        )
    }
}

/// Poisson sampler: inversion for small lambda, normal approx for large.
fn poisson(rng: &mut Rng, lam: f64) -> usize {
    if lam <= 0.0 {
        return 0;
    }
    if lam > 64.0 {
        let x = lam + lam.sqrt() * rng.normal();
        return x.round().max(0.0) as usize;
    }
    let l = (-lam).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        if k > 10_000 {
            return k; // numerical guard
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// The four paper workloads (Table 1 + §3.1 statistics).
// ---------------------------------------------------------------------------

/// Azure Code: 8819 requests / 1h; very long prompts, tiny outputs,
/// strong io correlation (r = 0.95), bursty (minute-cv ≈ 0.80).
pub fn azure_code() -> WorkloadSpec {
    WorkloadSpec {
        name: "azure_code",
        n_requests: 8819,
        duration_min: 60,
        intensity_ar: 0.55,
        intensity_sigma: 0.48,
        burst_prob: 0.05,
        burst_mult: 3.5,
        input_log_mu: 7.6,   // median ~2000 tokens
        input_log_sigma: 1.1,
        output_log_mu: 3.4,  // median ~30 tokens
        output_log_sigma: 1.0,
        io_rho: 0.96,
        max_input: 120_000,
        max_output: 4_096,
        class_mix: ClassMix::default(),
    }
}

/// Azure Conversation: 19366 requests / 1h; moderate prompts, longer
/// outputs, weak io correlation (r = 0.29), gentler fluctuation.
pub fn azure_conversation() -> WorkloadSpec {
    WorkloadSpec {
        name: "azure_conv",
        n_requests: 19366,
        duration_min: 60,
        intensity_ar: 0.80,
        intensity_sigma: 0.22,
        burst_prob: 0.02,
        burst_mult: 2.0,
        input_log_mu: 6.9,   // median ~1000
        input_log_sigma: 1.2,
        output_log_mu: 5.2,  // median ~180
        output_log_sigma: 0.8,
        io_rho: 0.30,
        max_input: 100_000,
        max_output: 8_192,
        class_mix: ClassMix::default(),
    }
}

/// BurstGPT 1-hour clip: 6009 requests; short conversational lengths but
/// the most bursty arrivals (minute-cv ≈ 1.11).
pub fn burstgpt() -> WorkloadSpec {
    WorkloadSpec {
        name: "burstgpt",
        n_requests: 6009,
        duration_min: 60,
        intensity_ar: 0.35,
        intensity_sigma: 0.60,
        burst_prob: 0.08,
        burst_mult: 4.0,
        input_log_mu: 5.8,   // median ~330
        input_log_sigma: 0.9,
        output_log_mu: 5.0,  // median ~150
        output_log_sigma: 0.85,
        io_rho: 0.45,
        max_input: 32_768,
        max_output: 4_096,
        class_mix: ClassMix::default(),
    }
}

/// Mooncake Conversation 10-minute clip: 1756 requests with extremely long
/// inputs and near-constant load (minute-cv ≈ 0.16).
pub fn mooncake_conversation() -> WorkloadSpec {
    WorkloadSpec {
        name: "mooncake_conv",
        n_requests: 1756,
        duration_min: 10,
        intensity_ar: 0.30,
        intensity_sigma: 0.07,
        burst_prob: 0.0,
        burst_mult: 1.0,
        input_log_mu: 8.9,   // median ~7300, heavy tail into 100k+
        input_log_sigma: 1.3,
        output_log_mu: 5.0,
        output_log_sigma: 0.8,
        io_rho: 0.25,
        max_input: 128_000,
        max_output: 8_192,
        class_mix: ClassMix::default(),
    }
}

/// A tiny deterministic workload for unit tests and the quickstart:
/// Poisson arrivals, short lognormal lengths, runs in milliseconds.
pub fn smoke(n: usize, duration_min: usize) -> WorkloadSpec {
    WorkloadSpec {
        name: "smoke",
        n_requests: n,
        duration_min,
        intensity_ar: 0.5,
        intensity_sigma: 0.2,
        burst_prob: 0.05,
        burst_mult: 2.0,
        input_log_mu: 4.5,
        input_log_sigma: 0.8,
        output_log_mu: 3.0,
        output_log_sigma: 0.6,
        io_rho: 0.5,
        max_input: 2_048,
        max_output: 256,
        class_mix: ClassMix::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_deterministic() {
        let a = azure_code().generate(1);
        let b = azure_code().generate(1);
        assert_eq!(a.len(), b.len());
        assert_eq!(a.requests[..50], b.requests[..50]);
    }

    #[test]
    fn seed_changes_trace() {
        let a = azure_code().generate(1);
        let b = azure_code().generate(2);
        assert_ne!(a.requests[..50], b.requests[..50]);
    }

    #[test]
    fn request_count_near_target() {
        for spec in [azure_code(), azure_conversation(), burstgpt(), mooncake_conversation()] {
            let t = spec.generate(7);
            let err = (t.len() as f64 - spec.n_requests as f64).abs()
                / spec.n_requests as f64;
            assert!(err < 0.10, "{}: n={} target={}", spec.name, t.len(), spec.n_requests);
        }
    }

    #[test]
    fn azure_code_statistics_match_paper() {
        let t = azure_code().generate(11);
        let s = t.stats();
        // r = 0.95 published; heavy tails loosen the Pearson estimate.
        assert!(s.io_correlation > 0.75, "r={}", s.io_correlation);
        // minute-cv = 0.80 published.
        assert!(
            (0.45..1.3).contains(&s.minute_input_cv),
            "cv={}",
            s.minute_input_cv
        );
        // Long inputs, short outputs.
        assert!(s.median_input > 1_000.0, "median_input={}", s.median_input);
        assert!(s.median_output < 100.0, "median_output={}", s.median_output);
    }

    #[test]
    fn azure_conversation_statistics_match_paper() {
        let t = azure_conversation().generate(11);
        let s = t.stats();
        assert!(
            (0.1..0.55).contains(&s.io_correlation),
            "r={}",
            s.io_correlation
        );
        assert!(s.minute_input_cv < 0.6, "cv={}", s.minute_input_cv);
        // Outputs longer than Azure Code's.
        let code = azure_code().generate(11).stats();
        assert!(s.median_output > code.median_output);
        assert!(s.median_input < code.median_input);
    }

    #[test]
    fn burstgpt_burstier_than_mooncake() {
        let b = burstgpt().generate(13).stats();
        let m = mooncake_conversation().generate(13).stats();
        assert!(
            b.minute_input_cv > 2.0 * m.minute_input_cv,
            "burstgpt cv={} mooncake cv={}",
            b.minute_input_cv,
            m.minute_input_cv
        );
        assert!(m.minute_input_cv < 0.45, "mooncake cv={}", m.minute_input_cv);
    }

    #[test]
    fn mooncake_has_long_context() {
        let t = mooncake_conversation().generate(17);
        let s = t.stats();
        assert!(s.median_input > 4_000.0, "median={}", s.median_input);
        assert!(s.p99_input > 50_000.0, "p99={}", s.p99_input);
        // 10-minute clip.
        assert!(t.duration() <= 600.0);
    }

    #[test]
    fn lengths_within_clamps() {
        let spec = burstgpt();
        let t = spec.generate(23);
        for r in &t.requests {
            assert!(r.input_len >= 1 && r.input_len <= spec.max_input);
            assert!(r.output_len >= 1 && r.output_len <= spec.max_output);
        }
    }

    #[test]
    fn poisson_mean_matches() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        for lam in [0.5, 4.0, 100.0] {
            let m: f64 = (0..n).map(|_| poisson(&mut rng, lam) as f64).sum::<f64>()
                / n as f64;
            assert!((m - lam).abs() / lam < 0.05, "lam={lam} mean={m}");
        }
    }

    #[test]
    fn default_mix_is_all_standard() {
        let t = smoke(300, 5).generate(42);
        assert!(t.requests.iter().all(|r| r.class == SloClass::Standard));
    }

    #[test]
    fn class_mix_never_perturbs_arrivals_or_lengths() {
        // PR 8 bit-stability: the class hash must not consume the RNG
        // stream — the mixed trace is the plain trace plus a class label.
        let plain = smoke(300, 5).generate(42);
        let mixed = smoke(300, 5)
            .with_class_mix(ClassMix { interactive: 0.3, batch: 0.3 })
            .generate(42);
        assert_eq!(plain.len(), mixed.len());
        for (a, b) in plain.requests.iter().zip(&mixed.requests) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.output_len, b.output_len);
        }
        // And the label is a pure function of the id, not of the seed.
        let reseeded = smoke(300, 5)
            .with_class_mix(ClassMix { interactive: 0.3, batch: 0.3 })
            .generate(43);
        let class_of = |t: &Trace, id| t.requests.iter().find(|r| r.id.0 == id).map(|r| r.class);
        for id in 0..20u64 {
            if let (Some(a), Some(b)) = (class_of(&mixed, id), class_of(&reseeded, id)) {
                assert_eq!(a, b, "class of id {id} must not depend on the seed");
            }
        }
    }

    #[test]
    fn class_mix_fractions_approximately_honored() {
        let mix = ClassMix { interactive: 0.25, batch: 0.50 };
        let t = smoke(2000, 20).with_class_mix(mix).generate(7);
        let n = t.len() as f64;
        let count = |c: SloClass| t.requests.iter().filter(|r| r.class == c).count() as f64;
        assert!((count(SloClass::Interactive) / n - 0.25).abs() < 0.05);
        assert!((count(SloClass::Batch) / n - 0.50).abs() < 0.05);
        assert!((count(SloClass::Standard) / n - 0.25).abs() < 0.05);
    }

    #[test]
    fn prop_arrivals_sorted_and_in_range() {
        crate::util::prop::check_with(3, 16, |rng| {
            let spec = smoke(200, 5);
            let t = spec.generate(rng.next_u64());
            crate::prop_assert!(
                t.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
                "unsorted arrivals"
            );
            crate::prop_assert!(
                t.requests.iter().all(|r| r.arrival >= 0.0
                    && r.arrival <= spec.duration_min as f64 * 60.0),
                "arrival out of range"
            );
            Ok(())
        });
    }
}
