//! Flight recorder for the live coordinator (PR 9): deterministic
//! record/replay of every scheduling decision.
//!
//! The live server serializes *everything* — submissions, engine events,
//! monitor ticks, membership, faults — through one `CoordMsg` channel,
//! and every policy is a pure function of its own state plus the
//! arguments it is handed (the `sched::Policy` determinism contract).
//! Those two facts together make the scheduler black-box replayable:
//! journal, in decision order, the exact `(now, request, view)` triple
//! each policy call consumed plus the decision it produced, and an
//! offline replayer can re-run the identical `Box<dyn Policy>` and
//! assert byte-identical placements, pool states `[P, D, P→D, D→P]`, and
//! flip counts ([`verify`]) — or re-derive the whole schedule through
//! `SimView` as an independent oracle (the PR-2/PR-4 cross-substrate
//! bit-identity contract).
//!
//! # Journal format (v1)
//!
//! An append-only binary log:
//!
//! ```text
//! file   := magic "ARWJ" | u32 version | record*
//! record := u32 payload_len | u64 fnv1a64(payload) | payload
//! ```
//!
//! Payloads are tagged, fixed-layout little-endian structs ([`Record`]).
//! Floats are stored as raw `f64::to_bits` so replay sees the *exact*
//! value the policy consumed — including NaN "no evidence" token
//! intervals. The first record is always [`Record::Meta`]: everything
//! needed to reconstruct the policy (config, per-engine predictors,
//! max-running-tokens) without the artifacts that produced it.
//!
//! # No wall clock in the record
//!
//! The logical timestamp `now` is captured once per message on the
//! coordinator thread — the same value the policy call consumed — and
//! recorded verbatim. Replay never reads a clock: a journal replays to
//! the same decisions on any machine at any time.
//!
//! # Drop-and-count backpressure
//!
//! Recording must add zero blocking to the dispatch path. Encoded
//! records go to a dedicated writer thread over a *bounded* channel via
//! `try_send`; when the writer falls behind, records are dropped and
//! counted (`/metrics` `journal_dropped`), never queued unboundedly and
//! never awaited. A [`Record::Gap`] marker is journaled as soon as the
//! channel drains so the replayer knows exactly where strict state
//! verification must stop — a gap is loud, not a silent divergence.
//!
//! # Crash tolerance
//!
//! A crash mid-write leaves a torn tail: a truncated frame or a payload
//! that fails its checksum. [`load`] truncates to the longest intact
//! prefix and reports the byte offset of the cut instead of refusing to
//! load — the journal before the tear is still bit-exact evidence.

pub mod demo;
pub mod verify;

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use crate::sched::{FixedProfile, Liveness, PrefillQueueMoments};

/// Journal file magic.
pub const MAGIC: [u8; 4] = *b"ARWJ";
/// Journal format version. Readers refuse other versions loudly — a
/// format change bumps this and documents the migration in ROADMAP.
pub const VERSION: u32 = 1;
/// Sanity cap on a single record payload: anything larger is treated as
/// a torn/corrupt length prefix, not an allocation request.
pub const MAX_RECORD_BYTES: u32 = 64 << 20;
/// Default bound on the recorder's in-flight channel. At ~200 bytes per
/// encoded decision this is a few MB of worst-case buffering; beyond it
/// the recorder drops-and-counts rather than stall dispatch.
pub const DEFAULT_JOURNAL_CAPACITY: usize = 8192;

/// FNV-1a 64-bit — the same digest the golden-schedule gate uses; enough
/// to detect torn/corrupt records (this is integrity, not security).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------- records

/// One engine's scheduling capability, as profiled at startup/join.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineProfile {
    /// Fitted TTFT quadratic coefficients (`TtftPredictor`).
    pub coeffs: [f64; 3],
    /// Chunk size the predictor prices overhead with.
    pub chunk: u32,
    /// Per-iteration overhead seconds.
    pub overhead: f64,
    /// Profiled Max Running Tokens (paper §5.3).
    pub max_running_tokens: u64,
}

/// The full cluster profile — enough to rebuild the `FixedProfile` the
/// policy was initialized (or re-seeded on membership) with.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Profile {
    pub engines: Vec<EngineProfile>,
}

impl Profile {
    pub fn from_fixed(p: &FixedProfile) -> Profile {
        Profile {
            engines: p
                .predictors
                .iter()
                .zip(&p.max_running_tokens)
                .map(|(pred, &mrt)| EngineProfile {
                    coeffs: pred.coefficients(),
                    chunk: pred.chunk_tokens(),
                    overhead: pred.overhead_s(),
                    max_running_tokens: mrt,
                })
                .collect(),
        }
    }

    pub fn to_fixed(&self) -> FixedProfile {
        use crate::coordinator::predictor::TtftPredictor;
        FixedProfile {
            predictors: self
                .engines
                .iter()
                .map(|e| TtftPredictor::from_coefficients(e.coeffs, e.chunk, e.overhead))
                .collect(),
            max_running_tokens: self.engines.iter().map(|e| e.max_running_tokens).collect(),
        }
    }
}

/// Journal header record: reconstructs the policy object exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct Meta {
    /// `Policy::name()` — selects the replay constructor.
    pub policy: String,
    pub ttft_slo: f64,
    pub tpot_slo: f64,
    pub initial_prefill: u64,
    pub decode_low_watermark: f64,
    pub tpot_violation_ticks: u32,
    pub tpot_violation_frac: f64,
    pub class_aware: bool,
    /// Engine count at startup.
    pub instances: u64,
    /// Static-split instance sets (empty for other policies) — lets the
    /// round-trip property test cover the baseline policies too.
    pub split_prefill: Vec<u32>,
    pub split_decode: Vec<u32>,
    pub profile: Profile,
}

/// One engine's slice of a recorded view snapshot. Mirrors
/// `server::view::EngineSnapshot`, with the queue always materialized
/// (the journal is the offline oracle; release-build snapshot elision
/// does not apply to it).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineRec {
    /// `(input_len, remaining)` per queued prefill. On the live path the
    /// coordinator observes no chunk progress, so `remaining == input_len`.
    pub queued: Vec<(u32, u32)>,
    pub moments: PrefillQueueMoments,
    pub chunk_tokens: u32,
    pub running_tokens: u64,
    pub max_kv_tokens: u64,
    /// Raw bits preserved exactly (often NaN = no evidence).
    pub avg_token_interval: f64,
    pub has_decode_work: bool,
    /// Liveness code: 0 active, 1 draining, 2 dead, 3 degraded.
    pub liveness: u8,
}

/// A recorded `ServerView` snapshot — the exact cluster state the policy
/// call consumed.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Snap {
    pub change_epoch: u64,
    pub engines: Vec<EngineRec>,
}

pub fn liveness_code(l: Liveness) -> u8 {
    match l {
        Liveness::Active => 0,
        Liveness::Draining => 1,
        Liveness::Dead => 2,
        Liveness::Degraded => 3,
    }
}

pub fn liveness_from_code(c: u8) -> Liveness {
    match c {
        0 => Liveness::Active,
        1 => Liveness::Draining,
        3 => Liveness::Degraded,
        _ => Liveness::Dead,
    }
}

impl Snap {
    /// Capture a live snapshot. `queued` is the coordinator's per-engine
    /// `(req, input_len)` ledger — the release-build view elides the
    /// queue clone, so the journal rebuilds the `(len, len)` pairs from
    /// the ledger the view itself was derived from.
    pub fn from_server(view: &crate::server::view::ServerView, queued: &[Vec<(u64, u32)>]) -> Snap {
        Snap {
            change_epoch: view.change_epoch,
            engines: view
                .engines
                .iter()
                .zip(queued)
                .map(|(e, q)| EngineRec {
                    queued: q.iter().map(|&(_, l)| (l, l)).collect(),
                    moments: e.moments,
                    chunk_tokens: e.chunk_tokens,
                    running_tokens: e.running_tokens,
                    max_kv_tokens: e.max_kv_tokens,
                    avg_token_interval: e.avg_token_interval,
                    has_decode_work: e.has_decode_work,
                    liveness: liveness_code(e.liveness),
                })
                .collect(),
        }
    }

    /// Rebuild the live-path view: recorded `change_epoch` preserved, so
    /// the policy's O(1) epoch fast path replays exactly as it ran.
    pub fn to_server_view(&self) -> crate::server::view::ServerView {
        crate::server::view::ServerView {
            engines: self
                .engines
                .iter()
                .map(|e| crate::server::view::EngineSnapshot {
                    queued_prefills: e.queued.clone(),
                    moments: e.moments,
                    chunk_tokens: e.chunk_tokens,
                    running_tokens: e.running_tokens,
                    max_kv_tokens: e.max_kv_tokens,
                    avg_token_interval: e.avg_token_interval,
                    has_decode_work: e.has_decode_work,
                    liveness: liveness_from_code(e.liveness),
                })
                .collect(),
            change_epoch: self.change_epoch,
        }
    }
}

/// The request fields a placement call consumed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReqRec {
    pub id: u64,
    pub arrival: f64,
    pub input_len: u32,
    pub output_len: u32,
    /// `SloClass::index()`.
    pub class: u8,
}

/// The decision the policy produced, captured right after the call:
/// placement target (placement calls only), pool sizes, flip count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Decision {
    pub target: Option<u32>,
    pub pools: Option<[u64; 4]>,
    pub flips: u64,
}

/// Membership event kinds (`sched::MembershipEvent`).
pub const MEMBER_JOINED: u8 = 0;
pub const MEMBER_DRAINING: u8 = 1;
pub const MEMBER_LOST: u8 = 2;

/// One journaled event.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    /// Always first: policy + profile reconstruction data.
    Meta(Meta),
    /// `Policy::place_prefill(now, req, view)` → `out.target`.
    Prefill {
        now: f64,
        req: ReqRec,
        snap: Snap,
        out: Decision,
    },
    /// `Policy::place_decode(now, req, InstanceId(from), view)`.
    Decode {
        now: f64,
        req: ReqRec,
        from: u32,
        snap: Snap,
        out: Decision,
    },
    /// `Policy::on_tick(now, view)` — no target, pools/flips only.
    Tick { now: f64, snap: Snap, out: Decision },
    /// `Policy::on_membership(now, event, view, profile)`. Carries the
    /// post-transition profile so a replayed join re-seeds identically.
    Membership {
        now: f64,
        kind: u8,
        engine: u32,
        snap: Snap,
        profile: Profile,
        out: Decision,
    },
    /// `dropped` records were shed under backpressure right before this
    /// point. Strict state replay stops here (the policy's internal
    /// state beyond a gap is unknowable) — loudly, never silently.
    Gap { dropped: u64 },
}

// ------------------------------------------------------------------ codec

const TAG_META: u8 = 0;
const TAG_PREFILL: u8 = 1;
const TAG_DECODE: u8 = 2;
const TAG_TICK: u8 = 3;
const TAG_MEMBERSHIP: u8 = 4;
const TAG_GAP: u8 = 5;

fn put_u8(b: &mut Vec<u8>, v: u8) {
    b.push(v);
}
fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_u128(b: &mut Vec<u8>, v: u128) {
    b.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(b: &mut Vec<u8>, v: f64) {
    put_u64(b, v.to_bits());
}
fn put_bool(b: &mut Vec<u8>, v: bool) {
    put_u8(b, v as u8);
}
fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

fn put_profile(b: &mut Vec<u8>, p: &Profile) {
    put_u32(b, p.engines.len() as u32);
    for e in &p.engines {
        for c in e.coeffs {
            put_f64(b, c);
        }
        put_u32(b, e.chunk);
        put_f64(b, e.overhead);
        put_u64(b, e.max_running_tokens);
    }
}

fn put_snap(b: &mut Vec<u8>, s: &Snap) {
    put_u64(b, s.change_epoch);
    put_u32(b, s.engines.len() as u32);
    for e in &s.engines {
        put_u32(b, e.queued.len() as u32);
        for &(l, r) in &e.queued {
            put_u32(b, l);
            put_u32(b, r);
        }
        put_u64(b, e.moments.count);
        put_u64(b, e.moments.sum_remaining);
        put_u128(b, e.moments.sum_sq_span);
        put_u64(b, e.moments.sum_chunks);
        put_u32(b, e.chunk_tokens);
        put_u64(b, e.running_tokens);
        put_u64(b, e.max_kv_tokens);
        put_f64(b, e.avg_token_interval);
        put_bool(b, e.has_decode_work);
        put_u8(b, e.liveness);
    }
}

fn put_req(b: &mut Vec<u8>, r: &ReqRec) {
    put_u64(b, r.id);
    put_f64(b, r.arrival);
    put_u32(b, r.input_len);
    put_u32(b, r.output_len);
    put_u8(b, r.class);
}

fn put_decision(b: &mut Vec<u8>, d: &Decision) {
    match d.target {
        Some(t) => {
            put_bool(b, true);
            put_u32(b, t);
        }
        None => put_bool(b, false),
    }
    match d.pools {
        Some(p) => {
            put_bool(b, true);
            for v in p {
                put_u64(b, v);
            }
        }
        None => put_bool(b, false),
    }
    put_u64(b, d.flips);
}

/// Encode a record's payload (tag + body, no framing).
pub fn encode_payload(rec: &Record) -> Vec<u8> {
    let mut b = Vec::with_capacity(256);
    match rec {
        Record::Meta(m) => {
            put_u8(&mut b, TAG_META);
            put_str(&mut b, &m.policy);
            put_f64(&mut b, m.ttft_slo);
            put_f64(&mut b, m.tpot_slo);
            put_u64(&mut b, m.initial_prefill);
            put_f64(&mut b, m.decode_low_watermark);
            put_u32(&mut b, m.tpot_violation_ticks);
            put_f64(&mut b, m.tpot_violation_frac);
            put_bool(&mut b, m.class_aware);
            put_u64(&mut b, m.instances);
            put_u32(&mut b, m.split_prefill.len() as u32);
            for &i in &m.split_prefill {
                put_u32(&mut b, i);
            }
            put_u32(&mut b, m.split_decode.len() as u32);
            for &i in &m.split_decode {
                put_u32(&mut b, i);
            }
            put_profile(&mut b, &m.profile);
        }
        Record::Prefill { now, req, snap, out } => {
            put_u8(&mut b, TAG_PREFILL);
            put_f64(&mut b, *now);
            put_req(&mut b, req);
            put_snap(&mut b, snap);
            put_decision(&mut b, out);
        }
        Record::Decode {
            now,
            req,
            from,
            snap,
            out,
        } => {
            put_u8(&mut b, TAG_DECODE);
            put_f64(&mut b, *now);
            put_req(&mut b, req);
            put_u32(&mut b, *from);
            put_snap(&mut b, snap);
            put_decision(&mut b, out);
        }
        Record::Tick { now, snap, out } => {
            put_u8(&mut b, TAG_TICK);
            put_f64(&mut b, *now);
            put_snap(&mut b, snap);
            put_decision(&mut b, out);
        }
        Record::Membership {
            now,
            kind,
            engine,
            snap,
            profile,
            out,
        } => {
            put_u8(&mut b, TAG_MEMBERSHIP);
            put_f64(&mut b, *now);
            put_u8(&mut b, *kind);
            put_u32(&mut b, *engine);
            put_snap(&mut b, snap);
            put_profile(&mut b, profile);
            put_decision(&mut b, out);
        }
        Record::Gap { dropped } => {
            put_u8(&mut b, TAG_GAP);
            put_u64(&mut b, *dropped);
        }
    }
    b
}

/// Encode a record with framing: length prefix + checksum + payload.
pub fn encode_framed(rec: &Record) -> Vec<u8> {
    let payload = encode_payload(rec);
    let mut out = Vec::with_capacity(payload.len() + 12);
    put_u32(&mut out, payload.len() as u32);
    put_u64(&mut out, fnv1a64(&payload));
    out.extend_from_slice(&payload);
    out
}

/// Bounds-checked little-endian cursor for decoding.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

type DecodeResult<T> = Result<T, String>;

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> DecodeResult<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(format!(
                "payload underrun: need {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            ));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> DecodeResult<u8> {
        Ok(self.take(1)?[0])
    }
    fn bool(&mut self) -> DecodeResult<bool> {
        Ok(self.u8()? != 0)
    }
    fn u32(&mut self) -> DecodeResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> DecodeResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn u128(&mut self) -> DecodeResult<u128> {
        Ok(u128::from_le_bytes(self.take(16)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> DecodeResult<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> DecodeResult<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec()).map_err(|e| format!("bad utf-8 string: {e}"))
    }
    fn done(&self) -> DecodeResult<()> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(format!(
                "trailing garbage: {} bytes past the end of the record",
                self.b.len() - self.i
            ))
        }
    }
}

fn get_profile(c: &mut Cur) -> DecodeResult<Profile> {
    let n = c.u32()? as usize;
    let mut engines = Vec::with_capacity(n);
    for _ in 0..n {
        engines.push(EngineProfile {
            coeffs: [c.f64()?, c.f64()?, c.f64()?],
            chunk: c.u32()?,
            overhead: c.f64()?,
            max_running_tokens: c.u64()?,
        });
    }
    Ok(Profile { engines })
}

fn get_snap(c: &mut Cur) -> DecodeResult<Snap> {
    let change_epoch = c.u64()?;
    let n = c.u32()? as usize;
    let mut engines = Vec::with_capacity(n);
    for _ in 0..n {
        let q = c.u32()? as usize;
        let mut queued = Vec::with_capacity(q);
        for _ in 0..q {
            queued.push((c.u32()?, c.u32()?));
        }
        engines.push(EngineRec {
            queued,
            moments: PrefillQueueMoments {
                count: c.u64()?,
                sum_remaining: c.u64()?,
                sum_sq_span: c.u128()?,
                sum_chunks: c.u64()?,
            },
            chunk_tokens: c.u32()?,
            running_tokens: c.u64()?,
            max_kv_tokens: c.u64()?,
            avg_token_interval: c.f64()?,
            has_decode_work: c.bool()?,
            liveness: c.u8()?,
        });
    }
    Ok(Snap {
        change_epoch,
        engines,
    })
}

fn get_req(c: &mut Cur) -> DecodeResult<ReqRec> {
    Ok(ReqRec {
        id: c.u64()?,
        arrival: c.f64()?,
        input_len: c.u32()?,
        output_len: c.u32()?,
        class: {
            let k = c.u8()?;
            if k > 2 {
                return Err(format!("bad SLO class code {k}"));
            }
            k
        },
    })
}

fn get_decision(c: &mut Cur) -> DecodeResult<Decision> {
    let target = if c.bool()? { Some(c.u32()?) } else { None };
    let pools = if c.bool()? {
        Some([c.u64()?, c.u64()?, c.u64()?, c.u64()?])
    } else {
        None
    };
    Ok(Decision {
        target,
        pools,
        flips: c.u64()?,
    })
}

/// Decode one record payload (no framing).
pub fn decode_payload(payload: &[u8]) -> DecodeResult<Record> {
    let mut c = Cur { b: payload, i: 0 };
    let tag = c.u8()?;
    let rec = match tag {
        TAG_META => {
            let policy = c.str()?;
            let ttft_slo = c.f64()?;
            let tpot_slo = c.f64()?;
            let initial_prefill = c.u64()?;
            let decode_low_watermark = c.f64()?;
            let tpot_violation_ticks = c.u32()?;
            let tpot_violation_frac = c.f64()?;
            let class_aware = c.bool()?;
            let instances = c.u64()?;
            let np = c.u32()? as usize;
            let mut split_prefill = Vec::with_capacity(np);
            for _ in 0..np {
                split_prefill.push(c.u32()?);
            }
            let nd = c.u32()? as usize;
            let mut split_decode = Vec::with_capacity(nd);
            for _ in 0..nd {
                split_decode.push(c.u32()?);
            }
            Record::Meta(Meta {
                policy,
                ttft_slo,
                tpot_slo,
                initial_prefill,
                decode_low_watermark,
                tpot_violation_ticks,
                tpot_violation_frac,
                class_aware,
                instances,
                split_prefill,
                split_decode,
                profile: get_profile(&mut c)?,
            })
        }
        TAG_PREFILL => Record::Prefill {
            now: c.f64()?,
            req: get_req(&mut c)?,
            snap: get_snap(&mut c)?,
            out: get_decision(&mut c)?,
        },
        TAG_DECODE => Record::Decode {
            now: c.f64()?,
            req: get_req(&mut c)?,
            from: c.u32()?,
            snap: get_snap(&mut c)?,
            out: get_decision(&mut c)?,
        },
        TAG_TICK => Record::Tick {
            now: c.f64()?,
            snap: get_snap(&mut c)?,
            out: get_decision(&mut c)?,
        },
        TAG_MEMBERSHIP => Record::Membership {
            now: c.f64()?,
            kind: c.u8()?,
            engine: c.u32()?,
            snap: get_snap(&mut c)?,
            profile: get_profile(&mut c)?,
            out: get_decision(&mut c)?,
        },
        TAG_GAP => Record::Gap { dropped: c.u64()? },
        other => return Err(format!("unknown record tag {other}")),
    };
    c.done()?;
    Ok(rec)
}

// --------------------------------------------------------------- recorder

/// `/metrics` counters: events journaled vs dropped under backpressure.
#[derive(Debug, Default)]
pub struct JournalStats {
    events: AtomicU64,
    dropped: AtomicU64,
}

impl JournalStats {
    pub fn events(&self) -> u64 {
        self.events.load(Ordering::Relaxed)
    }
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

enum WriterMsg {
    Rec(Vec<u8>),
    /// Flush + fsync, then ack — the shutdown path's durability barrier.
    Flush(mpsc::Sender<()>),
}

/// Coordinator-side journal handle. `record` never blocks: encoding is
/// a pure in-memory serialization and the handoff is a bounded
/// `try_send` — a slow disk costs dropped records (counted), not stalled
/// placements. Owned by the single coordinator thread (`&mut self`).
pub struct Recorder {
    tx: mpsc::SyncSender<WriterMsg>,
    stats: Arc<JournalStats>,
    /// Records dropped since the last one that got through; journaled as
    /// a `Gap` marker as soon as the channel has room again.
    pending_gap: u64,
}

/// Cloneable flush handle for threads other than the coordinator (the
/// HTTP shutdown endpoint): flush + fsync the journal, blocking.
#[derive(Clone)]
pub struct Flusher {
    tx: mpsc::SyncSender<WriterMsg>,
}

impl Flusher {
    /// Block until everything journaled so far is on disk (fsync'd).
    /// Returns false if the writer thread is gone.
    pub fn flush_sync(&self) -> bool {
        let (ack_tx, ack_rx) = mpsc::channel();
        if self.tx.send(WriterMsg::Flush(ack_tx)).is_err() {
            return false;
        }
        ack_rx.recv().is_ok()
    }
}

impl Recorder {
    /// Create the journal file (truncating), write the header, and start
    /// the writer thread.
    pub fn create(
        path: &Path,
        capacity: usize,
    ) -> std::io::Result<(Recorder, Flusher, Arc<JournalStats>)> {
        let mut file = File::create(path)?;
        file.write_all(&MAGIC)?;
        file.write_all(&VERSION.to_le_bytes())?;
        let (tx, rx) = mpsc::sync_channel::<WriterMsg>(capacity.max(1));
        let stats = Arc::new(JournalStats::default());
        std::thread::Builder::new()
            .name("journal-writer".into())
            .spawn(move || {
                let mut w = BufWriter::new(file);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        WriterMsg::Rec(bytes) => {
                            if let Err(e) = w.write_all(&bytes) {
                                eprintln!("journal write failed: {e}");
                            }
                        }
                        WriterMsg::Flush(ack) => {
                            if let Err(e) = w.flush().and_then(|_| w.get_ref().sync_all()) {
                                eprintln!("journal flush failed: {e}");
                            }
                            let _ = ack.send(());
                        }
                    }
                }
                // Channel closed (recorder dropped): final flush so a
                // graceful exit never loses the tail.
                let _ = w.flush().and_then(|_| w.get_ref().sync_all());
            })?;
        Ok((
            Recorder {
                tx: tx.clone(),
                stats: Arc::clone(&stats),
                pending_gap: 0,
            },
            Flusher { tx },
            stats,
        ))
    }

    /// Journal one record; never blocks. Under backpressure the record
    /// is dropped and counted, and a `Gap` marker is emitted once the
    /// channel drains so replay knows where fidelity ends.
    pub fn record(&mut self, rec: &Record) {
        if self.pending_gap > 0 {
            let gap = encode_framed(&Record::Gap {
                dropped: self.pending_gap,
            });
            if self.tx.try_send(WriterMsg::Rec(gap)).is_ok() {
                self.pending_gap = 0;
            } else {
                // Still backed up: this record joins the gap.
                self.pending_gap += 1;
                self.stats.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        let bytes = encode_framed(rec);
        if self.tx.try_send(WriterMsg::Rec(bytes)).is_ok() {
            self.stats.events.fetch_add(1, Ordering::Relaxed);
        } else {
            self.pending_gap += 1;
            self.stats.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }
}

// ----------------------------------------------------------------- reader

/// Where and why a journal was cut short.
#[derive(Debug, Clone, PartialEq)]
pub struct TornTail {
    /// Byte offset of the first unreadable record — the intact prefix is
    /// exactly `offset` bytes.
    pub offset: u64,
    pub reason: String,
}

/// A loaded journal: the intact prefix, plus the cut report if the tail
/// was torn or corrupt.
#[derive(Debug)]
pub struct LoadedJournal {
    pub meta: Meta,
    /// Records after the leading `Meta`, in journal order.
    pub records: Vec<Record>,
    pub torn: Option<TornTail>,
    /// Total records dropped under backpressure (sum of `Gap` markers).
    pub gaps: u64,
}

/// Load a journal, truncating a torn tail to the longest intact prefix
/// (crash tolerance) — never panics on a damaged file. Hard errors are
/// reserved for files that were never a journal (bad magic/version) or
/// carry no `Meta` record.
pub fn load(path: &Path) -> Result<LoadedJournal, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    if bytes.len() < 8 || bytes[..4] != MAGIC {
        return Err(format!("{} is not an Arrow journal (bad magic)", path.display()));
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(format!(
            "{}: journal format v{version}, this build reads v{VERSION}",
            path.display()
        ));
    }
    let mut records = Vec::new();
    let mut torn = None;
    let mut gaps = 0u64;
    let mut o = 8usize;
    while o < bytes.len() {
        let cut = |reason: String| TornTail {
            offset: o as u64,
            reason,
        };
        if bytes.len() - o < 12 {
            torn = Some(cut("truncated frame header".into()));
            break;
        }
        let len = u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        if len == 0 || len > MAX_RECORD_BYTES {
            torn = Some(cut(format!("implausible record length {len}")));
            break;
        }
        let len = len as usize;
        if bytes.len() - o < 12 + len {
            torn = Some(cut(format!(
                "truncated record body ({} of {len} payload bytes present)",
                bytes.len() - o - 12
            )));
            break;
        }
        let sum = u64::from_le_bytes(bytes[o + 4..o + 12].try_into().unwrap());
        let payload = &bytes[o + 12..o + 12 + len];
        if fnv1a64(payload) != sum {
            torn = Some(cut("checksum mismatch".into()));
            break;
        }
        match decode_payload(payload) {
            Ok(rec) => {
                if let Record::Gap { dropped } = rec {
                    gaps += dropped;
                }
                records.push(rec);
            }
            Err(e) => {
                // Checksum passed but the payload won't decode: encoder
                // drift or in-place corruption. Everything from here on
                // is untrusted — same cut semantics as a torn frame.
                torn = Some(cut(format!("undecodable record: {e}")));
                break;
            }
        }
        o += 12 + len;
    }
    if records.is_empty() {
        return Err(format!(
            "{}: no intact records{}",
            path.display(),
            torn.map(|t| format!(" (torn at byte {}: {})", t.offset, t.reason))
                .unwrap_or_default()
        ));
    }
    let meta = match records.remove(0) {
        Record::Meta(m) => m,
        other => {
            return Err(format!(
                "{}: first record is {:?}, expected Meta",
                path.display(),
                std::mem::discriminant(&other)
            ))
        }
    };
    Ok(LoadedJournal {
        meta,
        records,
        torn,
        gaps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snap() -> Snap {
        Snap {
            change_epoch: 7,
            engines: vec![
                EngineRec {
                    queued: vec![(100, 100), (2048, 2048)],
                    moments: {
                        let mut m = PrefillQueueMoments::default();
                        m.add_task(100, 100, 512);
                        m.add_task(2048, 2048, 512);
                        m
                    },
                    chunk_tokens: 512,
                    running_tokens: 0,
                    max_kv_tokens: 1 << 20,
                    avg_token_interval: f64::NAN,
                    has_decode_work: false,
                    liveness: 0,
                },
                EngineRec {
                    queued: vec![],
                    moments: PrefillQueueMoments::default(),
                    chunk_tokens: 2048,
                    running_tokens: 4096,
                    max_kv_tokens: 1 << 20,
                    avg_token_interval: 0.025,
                    has_decode_work: true,
                    liveness: 3,
                },
            ],
        }
    }

    fn sample_records() -> Vec<Record> {
        vec![
            Record::Meta(Meta {
                policy: "arrow-slo-aware".into(),
                ttft_slo: 2.0,
                tpot_slo: 0.5,
                initial_prefill: 1,
                decode_low_watermark: 0.5,
                tpot_violation_ticks: 2,
                tpot_violation_frac: 0.5,
                class_aware: true,
                instances: 2,
                split_prefill: vec![],
                split_decode: vec![0, 1],
                profile: Profile {
                    engines: vec![EngineProfile {
                        coeffs: [0.01, 1e-4, -1e-9],
                        chunk: 2048,
                        overhead: 0.001,
                        max_running_tokens: 99_999,
                    }],
                },
            }),
            Record::Prefill {
                now: 1.25,
                req: ReqRec {
                    id: 42,
                    arrival: 1.25,
                    input_len: 777,
                    output_len: 16,
                    class: 2,
                },
                snap: sample_snap(),
                out: Decision {
                    target: Some(1),
                    pools: Some([1, 1, 0, 0]),
                    flips: 3,
                },
            },
            Record::Decode {
                now: 2.5,
                req: ReqRec {
                    id: 42,
                    arrival: 1.25,
                    input_len: 777,
                    output_len: 16,
                    class: 0,
                },
                from: 1,
                snap: sample_snap(),
                out: Decision {
                    target: Some(0),
                    pools: None,
                    flips: 0,
                },
            },
            Record::Tick {
                now: 3.0,
                snap: sample_snap(),
                out: Decision {
                    target: None,
                    pools: Some([0, 2, 0, 0]),
                    flips: 4,
                },
            },
            Record::Membership {
                now: 4.0,
                kind: MEMBER_LOST,
                engine: 0,
                snap: sample_snap(),
                profile: Profile { engines: vec![] },
                out: Decision {
                    target: None,
                    pools: Some([0, 1, 0, 0]),
                    flips: 4,
                },
            },
            Record::Gap { dropped: 17 },
        ]
    }

    #[test]
    fn payload_round_trip_is_byte_identical() {
        for rec in sample_records() {
            let payload = encode_payload(&rec);
            let back = decode_payload(&payload).expect("decode");
            assert_eq!(back, rec);
            // Bit-exact: NaN token intervals must survive the trip.
            assert_eq!(encode_payload(&back), payload);
        }
    }

    #[test]
    fn framing_checksums_catch_any_flipped_byte() {
        let rec = &sample_records()[1];
        let framed = encode_framed(rec);
        let len = u32::from_le_bytes(framed[..4].try_into().unwrap()) as usize;
        assert_eq!(framed.len(), 12 + len);
        let sum = u64::from_le_bytes(framed[4..12].try_into().unwrap());
        assert_eq!(sum, fnv1a64(&framed[12..]));
        for i in 12..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x40;
            assert_ne!(fnv1a64(&bad[12..]), sum, "flip at {i} undetected");
        }
    }

    #[test]
    fn decode_rejects_truncated_and_trailing_garbage() {
        let payload = encode_payload(&sample_records()[3]);
        assert!(decode_payload(&payload[..payload.len() - 1]).is_err());
        let mut long = payload.clone();
        long.push(0);
        assert!(decode_payload(&long).is_err());
        assert!(decode_payload(&[99]).is_err(), "unknown tag");
    }

    /// Backpressure is drop-and-count, never blocking: with the writer
    /// channel full, `record` returns immediately, counts the drop, and
    /// journals a `Gap` marker once the channel drains.
    #[test]
    fn backpressure_drops_counts_and_marks_a_gap() {
        // Hand-built recorder whose "writer" is this test holding the
        // receive side, so backpressure is deterministic.
        let (tx, rx) = mpsc::sync_channel::<WriterMsg>(1);
        let stats = Arc::new(JournalStats::default());
        let mut rec = Recorder {
            tx,
            stats: Arc::clone(&stats),
            pending_gap: 0,
        };
        let tick = Record::Tick {
            now: 0.0,
            snap: Snap::default(),
            out: Decision {
                target: None,
                pools: None,
                flips: 0,
            },
        };
        rec.record(&tick); // fills the 1-slot channel
        rec.record(&tick); // dropped
        rec.record(&tick); // dropped
        assert_eq!(stats.events(), 1);
        assert_eq!(stats.dropped(), 2);

        // Drain; the next record emits the Gap marker first.
        let first = rx.try_recv().expect("journaled record");
        rec.record(&tick);
        let gap = rx.try_recv().expect("gap marker");
        rec.record(&tick); // channel full again (gap occupies the slot): dropped
        assert_eq!(stats.events(), 2);
        assert_eq!(stats.dropped(), 3);

        let decode = |m: WriterMsg| match m {
            WriterMsg::Rec(bytes) => decode_payload(&bytes[12..]).expect("decode"),
            WriterMsg::Flush(_) => panic!("unexpected flush"),
        };
        assert_eq!(decode(first), tick);
        assert_eq!(decode(gap), Record::Gap { dropped: 2 });
    }

    #[test]
    fn writer_thread_persists_and_loads_back() {
        let path = std::env::temp_dir().join(format!(
            "arrow-journal-unit-{}-{:?}.arwj",
            std::process::id(),
            std::thread::current().id()
        ));
        let (mut rec, flusher, stats) =
            Recorder::create(&path, DEFAULT_JOURNAL_CAPACITY).expect("create");
        let all = sample_records();
        for r in &all {
            rec.record(r);
        }
        assert!(flusher.flush_sync(), "flush ack");
        assert_eq!(stats.events(), all.len() as u64);
        assert_eq!(stats.dropped(), 0);

        let j = load(&path).expect("load");
        assert_eq!(Record::Meta(j.meta.clone()), all[0]);
        assert_eq!(j.records, all[1..]);
        assert!(j.torn.is_none());
        assert_eq!(j.gaps, 17, "gap marker total surfaced");
        drop(rec);
        let _ = std::fs::remove_file(&path);
    }
}
