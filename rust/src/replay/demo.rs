//! A scripted coordinator session through the real [`super::Recorder`].
//!
//! `arrow replay --record-demo <path>` produces a journal without
//! standing up engines: a seeded mini-coordinator drives the same
//! `Box<dyn Policy>` through the same snapshot shapes the live server
//! materializes — submissions, prefill completions, decode completions,
//! monitor ticks, membership churn with failure re-dispatch — and
//! journals every decision through the production recorder (writer
//! thread, framing, fsync). That gives CI a record→replay smoke gate
//! that needs no model artifacts, and gives the round-trip property
//! tests a journal generator covering every record type.
//!
//! Determinism: the "clock" is a logical time advanced by seeded
//! exponential gaps — the same no-wall-clock rule the live recorder
//! obeys — so one (seed, steps, engines, policy) tuple produces one
//! byte-identical journal everywhere.

use std::path::Path;

use super::verify::build_policy;
use super::{
    liveness_code, EngineProfile, Meta, Profile, Record, Recorder, ReqRec, Snap,
    DEFAULT_JOURNAL_CAPACITY, MEMBER_DRAINING, MEMBER_JOINED, MEMBER_LOST,
};
use crate::request::{InstanceId, Request, RequestId, SloClass};
use crate::sched::{Liveness, MembershipEvent, PrefillQueueMoments, DEFAULT_CHUNK_TOKENS};
use crate::util::rng::Rng;

/// Scripted-session parameters.
#[derive(Debug, Clone)]
pub struct DemoConfig {
    pub seed: u64,
    /// Scheduling events to script (actual record count is higher: a
    /// failure re-dispatches every queued request, each its own record).
    pub steps: u64,
    /// Engines at startup.
    pub engines: usize,
    /// Policy name: `arrow-slo-aware`, `all-to-one`, or `static-split`.
    pub policy: String,
    /// Allow membership churn (join/drain/fail) in the script.
    pub membership: bool,
}

impl Default for DemoConfig {
    fn default() -> Self {
        DemoConfig {
            seed: 42,
            steps: 400,
            engines: 4,
            policy: "arrow-slo-aware".into(),
            membership: true,
        }
    }
}

const DEMO_KV: u64 = 1 << 20;
const DEMO_MRT: u64 = 60_000;
const DEMO_COEFFS: [f64; 3] = [0.01, 1e-4, 0.0];
const DEMO_OVERHEAD: f64 = 0.001;

fn demo_engine_profile() -> EngineProfile {
    EngineProfile {
        coeffs: DEMO_COEFFS,
        chunk: DEFAULT_CHUNK_TOKENS,
        overhead: DEMO_OVERHEAD,
        max_running_tokens: DEMO_MRT,
    }
}

/// One engine's state in the scripted coordinator — the same ledgers the
/// live coordinator keeps (queued prefills + decode residency), minus
/// the engines themselves.
struct DemoEngine {
    queued: Vec<(u64, u32)>,
    moments: PrefillQueueMoments,
    /// `(req, ctx_tokens)` decoding here.
    running: Vec<(u64, u32)>,
    interval: f64,
    life: Liveness,
}

impl DemoEngine {
    fn new() -> DemoEngine {
        DemoEngine {
            queued: Vec::new(),
            moments: PrefillQueueMoments::default(),
            running: Vec::new(),
            interval: f64::NAN,
            life: Liveness::Active,
        }
    }
}

struct InflightReq {
    arrival: f64,
    input_len: u32,
    output_len: u32,
    class: u8,
}

/// Record a scripted session to `path`. Returns the number of journaled
/// records (excluding the leading `Meta`).
pub fn record_demo(path: &Path, cfg: &DemoConfig) -> Result<u64, String> {
    let n0 = cfg.engines.max(1);
    let mut profile = Profile {
        engines: (0..n0).map(|_| demo_engine_profile()).collect(),
    };
    let split = |r: std::ops::Range<usize>| r.map(|i| i as u32).collect::<Vec<u32>>();
    let meta = Meta {
        policy: cfg.policy.clone(),
        ttft_slo: 2.0,
        tpot_slo: 0.5,
        initial_prefill: (n0 / 2) as u64,
        decode_low_watermark: 0.5,
        tpot_violation_ticks: 2,
        tpot_violation_frac: 0.5,
        class_aware: true,
        instances: n0 as u64,
        // Meaningful for static-split only; harmless for the others.
        split_prefill: split(0..(n0 / 2).max(1)),
        split_decode: split((n0 / 2).max(1)..n0.max(2)),
        profile: profile.clone(),
    };
    let mut policy = build_policy(&meta)?;
    policy.init(&profile.to_fixed());

    let (mut recorder, flusher, stats) = Recorder::create(path, DEFAULT_JOURNAL_CAPACITY)
        .map_err(|e| format!("cannot create journal {}: {e}", path.display()))?;
    recorder.record(&Record::Meta(meta));

    let mut engines: Vec<DemoEngine> = (0..n0).map(|_| DemoEngine::new()).collect();
    let mut inflight: std::collections::BTreeMap<u64, InflightReq> = Default::default();
    let mut rng = Rng::new(cfg.seed ^ 0xA9);
    let mut now = 0.0f64;
    let mut epoch = 0u64;
    let mut next_req = 0u64;
    let max_engines = n0 + 4;

    let snap = |engines: &[DemoEngine], epoch: &mut u64| -> Snap {
        *epoch += 1;
        Snap {
            change_epoch: *epoch,
            engines: engines
                .iter()
                .map(|e| super::EngineRec {
                    queued: e.queued.iter().map(|&(_, l)| (l, l)).collect(),
                    moments: e.moments,
                    chunk_tokens: DEFAULT_CHUNK_TOKENS,
                    running_tokens: e.running.iter().map(|&(_, c)| c as u64).sum(),
                    max_kv_tokens: DEMO_KV,
                    avg_token_interval: e.interval,
                    has_decode_work: !e.running.is_empty(),
                    liveness: liveness_code(e.life),
                })
                .collect(),
        }
    };

    // Dispatch one prefill exactly the way the live coordinator does:
    // snapshot → policy → record raw decision → clamp → apply (skipping
    // Dead targets, which the server fails the request on).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_prefill(
        policy: &mut Box<dyn crate::sched::Policy>,
        recorder: &mut Recorder,
        engines: &mut [DemoEngine],
        epoch: &mut u64,
        snap: &dyn Fn(&[DemoEngine], &mut u64) -> Snap,
        now: f64,
        id: u64,
        fl: &InflightReq,
    ) {
        let s = snap(engines, epoch);
        let view = s.to_server_view();
        let r = Request {
            id: RequestId(id),
            arrival: fl.arrival,
            input_len: fl.input_len,
            output_len: fl.output_len,
            class: SloClass::ALL[fl.class as usize],
        };
        let target = policy.place_prefill(now, &r, &view);
        let out = super::Decision {
            target: Some(target.0 as u32),
            pools: policy.pool_sizes().map(|p| p.map(|v| v as u64)),
            flips: policy.flip_count(),
        };
        recorder.record(&Record::Prefill {
            now,
            req: ReqRec {
                id,
                arrival: fl.arrival,
                input_len: fl.input_len,
                output_len: fl.output_len,
                class: fl.class,
            },
            snap: s,
            out,
        });
        let t = target.0.min(engines.len() - 1);
        if engines[t].life != Liveness::Dead {
            engines[t].queued.push((id, fl.input_len));
            engines[t]
                .moments
                .add_task(fl.input_len, fl.input_len, DEFAULT_CHUNK_TOKENS);
        }
    }

    for _ in 0..cfg.steps {
        now += rng.exp(8.0);
        let any_queued = engines.iter().any(|e| !e.queued.is_empty());
        let any_running = engines.iter().any(|e| !e.running.is_empty());
        let weights = [
            5.0,                                            // submit
            if any_queued { 3.0 } else { 0.0 },             // prefill done
            if any_running { 2.0 } else { 0.0 },            // decode done
            1.5,                                            // monitor tick
            if cfg.membership { 0.4 } else { 0.0 },         // membership
        ];
        match rng.weighted(&weights) {
            0 => {
                let id = next_req;
                next_req += 1;
                let fl = InflightReq {
                    arrival: now,
                    input_len: rng.int_range(16, 4096) as u32,
                    output_len: rng.int_range(1, 256) as u32,
                    class: rng.index(3) as u8,
                };
                dispatch_prefill(
                    &mut policy,
                    &mut recorder,
                    &mut engines,
                    &mut epoch,
                    &snap,
                    now,
                    id,
                    &fl,
                );
                inflight.insert(id, fl);
            }
            1 => {
                // Prefill completes on a random non-empty engine; the
                // coordinator unqueues it, then places the decode phase.
                let pool: Vec<usize> = (0..engines.len())
                    .filter(|&i| !engines[i].queued.is_empty())
                    .collect();
                let from = pool[rng.index(pool.len())];
                let (id, len) = engines[from].queued.remove(0);
                engines[from]
                    .moments
                    .remove_task(len, len, DEFAULT_CHUNK_TOKENS);
                let fl = &inflight[&id];
                let s = snap(&engines, &mut epoch);
                let view = s.to_server_view();
                let r = Request {
                    id: RequestId(id),
                    arrival: fl.arrival,
                    input_len: fl.input_len,
                    output_len: fl.output_len,
                    class: SloClass::ALL[fl.class as usize],
                };
                let target = policy.place_decode(now, &r, InstanceId(from), &view);
                let out = super::Decision {
                    target: Some(target.0 as u32),
                    pools: policy.pool_sizes().map(|p| p.map(|v| v as u64)),
                    flips: policy.flip_count(),
                };
                recorder.record(&Record::Decode {
                    now,
                    req: ReqRec {
                        id,
                        arrival: fl.arrival,
                        input_len: fl.input_len,
                        output_len: fl.output_len,
                        class: fl.class,
                    },
                    from: from as u32,
                    snap: s,
                    out,
                });
                let t = target.0.min(engines.len() - 1);
                if engines[t].life != Liveness::Dead {
                    engines[t].running.push((id, len));
                }
            }
            2 => {
                let pool: Vec<usize> = (0..engines.len())
                    .filter(|&i| !engines[i].running.is_empty())
                    .collect();
                let at = pool[rng.index(pool.len())];
                let (id, _) = engines[at].running.remove(0);
                engines[at].interval = 0.01 + rng.f64() * 0.05;
                inflight.remove(&id);
            }
            3 => {
                let s = snap(&engines, &mut epoch);
                let view = s.to_server_view();
                policy.on_tick(now, &view);
                let out = super::Decision {
                    target: None,
                    pools: policy.pool_sizes().map(|p| p.map(|v| v as u64)),
                    flips: policy.flip_count(),
                };
                recorder.record(&Record::Tick { now, snap: s, out });
            }
            _ => {
                let active: Vec<usize> = (0..engines.len())
                    .filter(|&i| engines[i].life == Liveness::Active)
                    .collect();
                let can_join = engines.len() < max_engines;
                let (kind, engine) = match rng.index(3) {
                    0 if can_join => {
                        engines.push(DemoEngine::new());
                        profile.engines.push(demo_engine_profile());
                        (MEMBER_JOINED, engines.len() - 1)
                    }
                    1 if active.len() > 1 => {
                        let e = active[rng.index(active.len())];
                        engines[e].life = Liveness::Draining;
                        (MEMBER_DRAINING, e)
                    }
                    _ if active.len() > 1 => {
                        let e = active[rng.index(active.len())];
                        engines[e].life = Liveness::Dead;
                        (MEMBER_LOST, e)
                    }
                    _ => continue,
                };
                let s = snap(&engines, &mut epoch);
                let view = s.to_server_view();
                let id = InstanceId(engine);
                let ev = match kind {
                    MEMBER_JOINED => MembershipEvent::InstanceJoined { id },
                    MEMBER_DRAINING => MembershipEvent::InstanceDraining { id },
                    _ => MembershipEvent::InstanceLost { id },
                };
                policy.on_membership(now, ev, &view, &profile.to_fixed());
                let out = super::Decision {
                    target: None,
                    pools: policy.pool_sizes().map(|p| p.map(|v| v as u64)),
                    flips: policy.flip_count(),
                };
                recorder.record(&Record::Membership {
                    now,
                    kind,
                    engine: engine as u32,
                    snap: s,
                    profile: profile.clone(),
                    out,
                });
                if kind == MEMBER_LOST {
                    // Failure re-dispatch, server-style: every prefill the
                    // dead engine held goes back through place_prefill —
                    // each re-dispatch is its own journaled decision.
                    let orphans = std::mem::take(&mut engines[engine].queued);
                    engines[engine].moments = PrefillQueueMoments::default();
                    engines[engine].running.clear();
                    for (id, _) in orphans {
                        let fl = match inflight.get(&id) {
                            Some(f) => InflightReq {
                                arrival: f.arrival,
                                input_len: f.input_len,
                                output_len: f.output_len,
                                class: f.class,
                            },
                            None => continue,
                        };
                        dispatch_prefill(
                            &mut policy,
                            &mut recorder,
                            &mut engines,
                            &mut epoch,
                            &snap,
                            now,
                            id,
                            &fl,
                        );
                    }
                }
            }
        }
    }

    if !flusher.flush_sync() {
        return Err("journal writer thread is gone".into());
    }
    let dropped = stats.dropped();
    if dropped > 0 {
        // With the default capacity and a local disk this never fires;
        // surfacing it keeps the demo honest if it ever does.
        eprintln!("record-demo: {dropped} records dropped under backpressure");
    }
    Ok(stats.events().saturating_sub(1))
}

#[cfg(test)]
mod tests {
    use super::super::verify::{verify_journal, VerifyOptions};
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("arrow-demo-{}-{name}.arwj", std::process::id()))
    }

    /// The demo journal is deterministic: same config, same bytes.
    #[test]
    fn demo_is_byte_deterministic() {
        let cfg = DemoConfig {
            steps: 120,
            ..DemoConfig::default()
        };
        let (a, b) = (tmp("det-a"), tmp("det-b"));
        record_demo(&a, &cfg).unwrap();
        record_demo(&b, &cfg).unwrap();
        let (ba, bb) = (std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
        assert!(!ba.is_empty());
        assert_eq!(ba, bb, "same seed must journal identical bytes");
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    /// End-to-end: scripted session → journal → both replay oracles
    /// reproduce every decision.
    #[test]
    fn demo_round_trips_through_both_oracles() {
        let cfg = DemoConfig {
            steps: 200,
            ..DemoConfig::default()
        };
        let path = tmp("roundtrip");
        let n = record_demo(&path, &cfg).unwrap();
        assert!(n >= cfg.steps / 2, "scripted session too thin: {n} records");
        let report = verify_journal(&path, &VerifyOptions::default()).unwrap();
        assert!(
            report.ok(),
            "replay diverged: {:?} (detail: {:?})",
            report.divergences,
            report.detail
        );
        assert_eq!(report.verified, report.records);
        assert!(report.sim_verified > 0, "sim oracle never engaged");
        assert!(report.stopped_at_gap.is_none());
        let _ = std::fs::remove_file(&path);
    }
}
