//! Deterministic replay of a flight-recorder journal.
//!
//! Two oracles, one contract:
//!
//! 1. **Server replay** — rebuild each recorded [`super::Snap`] as the
//!    exact `ServerView` the live coordinator handed its policy
//!    (recorded `change_epoch` included, so the O(1) epoch fast path
//!    replays as it ran), feed it to a freshly constructed instance of
//!    the same `Box<dyn Policy>`, and assert byte-identical placements,
//!    pool states `[P, D, P→D, D→P]`, and flip counts.
//! 2. **Sim oracle** (`--sim`) — reconstruct each snapshot as a
//!    `SimInstance` table and re-derive the same decision through
//!    `SimView`, the *other* substrate's adapter, with `change_epoch`
//!    unknown (every read fully verified, no fast path). This leans on
//!    the PR-2/PR-4 cross-substrate bit-identity contract: identical
//!    snapshots must produce identical placement keys on both
//!    substrates, so a sim-side divergence indicts the substrate
//!    adapters, not the policy.
//!
//! Replay stops strict verification at the first [`super::Record::Gap`]:
//! records were dropped under backpressure there, so the live policy's
//! internal state beyond that point is unknowable — the report says so
//! loudly instead of manufacturing false divergences.

use std::path::Path;

use super::{
    liveness_from_code, load, Decision, Meta, Record, Snap, TornTail, MEMBER_DRAINING,
    MEMBER_JOINED, MEMBER_LOST,
};
use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use crate::costmodel::CostModel;
use crate::engine::SimInstance;
use crate::request::{InstanceId, Request, RequestId, SloClass};
use crate::sched::{tests_support, MembershipEvent, Policy};
use crate::sim::SimView;

/// Replay options.
#[derive(Debug, Clone)]
pub struct VerifyOptions {
    /// Also re-derive every decision through the `SimView` oracle.
    pub sim_oracle: bool,
    /// Stop collecting divergence details after this many (the count
    /// keeps climbing; only the narrative is capped).
    pub max_reported: usize,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions {
            sim_oracle: true,
            max_reported: 16,
        }
    }
}

/// Outcome of a journal replay.
#[derive(Debug)]
pub struct VerifyReport {
    pub policy: String,
    /// Records in the intact journal after `Meta` (gap markers included).
    pub records: u64,
    /// Decisions strictly re-derived and compared on the server oracle.
    pub verified: u64,
    /// Total decision mismatches (server + sim oracles).
    pub divergences: u64,
    /// Human-readable detail for the first `max_reported` divergences.
    pub detail: Vec<String>,
    /// Decisions additionally confirmed by the sim oracle.
    pub sim_verified: u64,
    /// Records the sim oracle could not represent (e.g. decode KV
    /// resident while no decode work is visible) — skipped, not failed.
    pub sim_skipped: u64,
    /// Verification stopped early at a backpressure gap.
    pub stopped_at_gap: Option<String>,
    /// The journal tail was torn/corrupt; the intact prefix was replayed.
    pub torn: Option<TornTail>,
    /// Total records dropped under backpressure (sum of gap markers).
    pub dropped: u64,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.divergences == 0
    }
}

/// Construct the policy a journal's `Meta` record describes, exactly as
/// the live server constructed it.
pub fn build_policy(meta: &Meta) -> Result<Box<dyn Policy>, String> {
    match meta.policy.as_str() {
        "arrow-slo-aware" => {
            let n = meta.instances as usize;
            let mut cfg = ArrowConfig::new(meta.ttft_slo, meta.tpot_slo, n);
            cfg.initial_prefill = meta.initial_prefill as usize;
            cfg.decode_low_watermark = meta.decode_low_watermark;
            cfg.tpot_violation_ticks = meta.tpot_violation_ticks;
            cfg.tpot_violation_frac = meta.tpot_violation_frac;
            cfg.class_aware = meta.class_aware;
            Ok(Box::new(ArrowPolicy::new(cfg, n)))
        }
        "all-to-one" => Ok(Box::new(tests_support::AllToOne)),
        "static-split" => Ok(Box::new(tests_support::StaticSplit {
            prefill: meta.split_prefill.iter().map(|&i| i as usize).collect(),
            decode: meta.split_decode.iter().map(|&i| i as usize).collect(),
        })),
        other => Err(format!(
            "journal was recorded by policy {other:?}, which has no replay constructor"
        )),
    }
}

fn request_of(r: &super::ReqRec) -> Request {
    // Struct literal, not `Request::new` — the constructor clamps
    // degenerate lengths, and replay must consume the recorded bytes
    // verbatim.
    Request {
        id: RequestId(r.id),
        arrival: r.arrival,
        input_len: r.input_len,
        output_len: r.output_len,
        class: SloClass::ALL[r.class as usize],
    }
}

fn membership_event(kind: u8, engine: u32) -> Result<MembershipEvent, String> {
    let id = InstanceId(engine as usize);
    match kind {
        MEMBER_JOINED => Ok(MembershipEvent::InstanceJoined { id }),
        MEMBER_DRAINING => Ok(MembershipEvent::InstanceDraining { id }),
        MEMBER_LOST => Ok(MembershipEvent::InstanceLost { id }),
        other => Err(format!("unknown membership kind {other}")),
    }
}

/// Capture a policy's observable decision the same way the recorder did.
fn decision_of(policy: &dyn Policy, target: Option<InstanceId>) -> Decision {
    Decision {
        target: target.map(|t| t.0 as u32),
        pools: policy.pool_sizes().map(|p| p.map(|v| v as u64)),
        flips: policy.flip_count(),
    }
}

fn describe(d: &Decision) -> String {
    format!(
        "target={:?} pools={:?} flips={}",
        d.target, d.pools, d.flips
    )
}

/// Reconstruct a recorded snapshot as a `SimInstance` table for the
/// cross-substrate oracle. Returns `None` when the snapshot is not
/// representable in the simulator's state space:
/// * a queued prefill with chunk progress (`remaining != input_len`) —
///   never produced by the live path, which observes no chunk progress;
/// * resident decode KV with `has_decode_work == false` (tokens cached
///   for a request the engine no longer reports) — transiently possible
///   live, meaningless in sim;
/// * reconstructed moments that disagree with the recorded aggregates
///   (would silently verify against different state than was recorded).
fn sim_instances(snap: &Snap) -> Option<Vec<SimInstance>> {
    let mut insts = Vec::with_capacity(snap.engines.len());
    for (i, e) in snap.engines.iter().enumerate() {
        let mut inst = SimInstance::new(InstanceId(i), CostModel::h800_llama8b());
        // Chunk first: enqueue_prefill prices the moments with it.
        inst.chunk_tokens = e.chunk_tokens;
        inst.cost_mut().max_kv_tokens = e.max_kv_tokens;
        let mut synth = 0u64;
        for &(l, r) in &e.queued {
            if l != r {
                return None;
            }
            inst.enqueue_prefill(RequestId(synth), l);
            synth += 1;
        }
        if inst.prefill_queue_moments() != e.moments {
            return None;
        }
        if e.running_tokens > 0 {
            if !e.has_decode_work {
                return None;
            }
            // Split into u32-sized decode contexts; running_tokens and
            // has_decode_work are all the view exposes, so any split
            // reconstructs the observable state exactly.
            let mut left = e.running_tokens;
            while left > 0 {
                let c = left.min(u32::MAX as u64) as u32;
                inst.enqueue_decode(RequestId(synth), c, 1);
                synth += 1;
                left -= c as u64;
            }
        } else if e.has_decode_work {
            // Active slots with zero resident tokens: a just-adopted
            // zero-context decode.
            inst.enqueue_decode(RequestId(synth), 0, 1);
        }
        inst.seed_token_interval(e.avg_token_interval);
        inst.life = liveness_from_code(e.liveness);
        insts.push(inst);
    }
    Some(insts)
}

/// Replay `path` and verify every recorded decision. Errors are reserved
/// for unreplayable journals (unreadable, wrong format, unknown policy);
/// divergences are data, reported in the `VerifyReport`.
pub fn verify_journal(path: &Path, opts: &VerifyOptions) -> Result<VerifyReport, String> {
    let journal = load(path)?;
    let meta = &journal.meta;
    let profile = meta.profile.to_fixed();

    let mut policy = build_policy(meta)?;
    policy.init(&profile);
    // Independent instance for the sim oracle: its internal state must
    // evolve through its own call sequence, never borrow the server
    // replayer's.
    let mut sim_policy = build_policy(meta)?;
    sim_policy.init(&profile);

    let mut report = VerifyReport {
        policy: meta.policy.clone(),
        records: journal.records.len() as u64,
        verified: 0,
        divergences: 0,
        detail: Vec::new(),
        sim_verified: 0,
        sim_skipped: 0,
        stopped_at_gap: None,
        torn: journal.torn.clone(),
        dropped: journal.gaps,
    };

    let mut diverge = |report: &mut VerifyReport, idx: usize, what: &str, rec: &Decision, got: &Decision| {
        report.divergences += 1;
        if report.detail.len() < opts.max_reported {
            report.detail.push(format!(
                "record {idx}: {what}: recorded {} / replayed {}",
                describe(rec),
                describe(got)
            ));
        }
    };

    for (idx, rec) in journal.records.iter().enumerate() {
        // Each record carries the recorded (now, inputs, snapshot); the
        // replayed decision must match the recorded one bit for bit.
        let (snap, recorded): (&Snap, &Decision) = match rec {
            Record::Prefill { snap, out, .. }
            | Record::Decode { snap, out, .. }
            | Record::Tick { snap, out, .. }
            | Record::Membership { snap, out, .. } => (snap, out),
            Record::Gap { dropped } => {
                report.stopped_at_gap = Some(format!(
                    "backpressure gap at record {idx} ({dropped} decisions dropped) — \
                     policy state beyond this point is unknowable; verified {} of {} records",
                    report.verified, report.records
                ));
                break;
            }
            Record::Meta(_) => {
                report.divergences += 1;
                if report.detail.len() < opts.max_reported {
                    report
                        .detail
                        .push(format!("record {idx}: unexpected mid-journal Meta record"));
                }
                break;
            }
        };

        let view = snap.to_server_view();
        let got = match rec {
            Record::Prefill { now, req, .. } => {
                let r = request_of(req);
                let t = policy.place_prefill(*now, &r, &view);
                decision_of(policy.as_ref(), Some(t))
            }
            Record::Decode { now, req, from, .. } => {
                let r = request_of(req);
                let t = policy.place_decode(*now, &r, InstanceId(*from as usize), &view);
                decision_of(policy.as_ref(), Some(t))
            }
            Record::Tick { now, .. } => {
                policy.on_tick(*now, &view);
                decision_of(policy.as_ref(), None)
            }
            Record::Membership {
                now,
                kind,
                engine,
                profile,
                ..
            } => {
                let ev = membership_event(*kind, *engine)?;
                let fixed = profile.to_fixed();
                policy.on_membership(*now, ev, &view, &fixed);
                decision_of(policy.as_ref(), None)
            }
            Record::Gap { .. } | Record::Meta(_) => unreachable!("handled above"),
        };
        report.verified += 1;
        if got != *recorded {
            diverge(&mut report, idx, "server replay diverged", recorded, &got);
        }

        if opts.sim_oracle {
            // The sim policy's state must advance on every record even
            // when the snapshot is sim-unrepresentable — fall back to the
            // server view for state-keeping and count the record skipped
            // rather than letting the oracle drift out of sync.
            let insts = sim_instances(snap);
            let sim_checked = insts.is_some();
            let sview;
            let view_for_sim: &dyn crate::sched::ClusterView = match &insts {
                Some(table) => {
                    sview = SimView(table);
                    &sview
                }
                None => {
                    report.sim_skipped += 1;
                    &view
                }
            };
            let sim_got = match rec {
                Record::Prefill { now, req, .. } => {
                    let r = request_of(req);
                    let t = sim_policy.place_prefill(*now, &r, view_for_sim);
                    decision_of(sim_policy.as_ref(), Some(t))
                }
                Record::Decode { now, req, from, .. } => {
                    let r = request_of(req);
                    let t =
                        sim_policy.place_decode(*now, &r, InstanceId(*from as usize), view_for_sim);
                    decision_of(sim_policy.as_ref(), Some(t))
                }
                Record::Tick { now, .. } => {
                    sim_policy.on_tick(*now, view_for_sim);
                    decision_of(sim_policy.as_ref(), None)
                }
                Record::Membership {
                    now,
                    kind,
                    engine,
                    profile,
                    ..
                } => {
                    let ev = membership_event(*kind, *engine)?;
                    let fixed = profile.to_fixed();
                    sim_policy.on_membership(*now, ev, view_for_sim, &fixed);
                    decision_of(sim_policy.as_ref(), None)
                }
                Record::Gap { .. } | Record::Meta(_) => unreachable!("handled above"),
            };
            if sim_checked {
                if sim_got == *recorded {
                    report.sim_verified += 1;
                } else {
                    diverge(&mut report, idx, "sim oracle diverged", recorded, &sim_got);
                }
            }
        }
    }

    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::ClusterView;

    #[test]
    fn build_policy_covers_all_recordable_policies() {
        let mut meta = Meta {
            policy: "arrow-slo-aware".into(),
            ttft_slo: 2.0,
            tpot_slo: 0.5,
            initial_prefill: 2,
            decode_low_watermark: 0.5,
            tpot_violation_ticks: 2,
            tpot_violation_frac: 0.5,
            class_aware: true,
            instances: 4,
            split_prefill: vec![0, 1],
            split_decode: vec![2, 3],
            profile: super::super::Profile { engines: vec![] },
        };
        assert_eq!(build_policy(&meta).unwrap().name(), "arrow-slo-aware");
        meta.policy = "all-to-one".into();
        assert_eq!(build_policy(&meta).unwrap().name(), "all-to-one");
        meta.policy = "static-split".into();
        assert_eq!(build_policy(&meta).unwrap().name(), "static-split");
        meta.policy = "no-such-policy".into();
        assert!(build_policy(&meta).is_err());
    }

    #[test]
    fn sim_reconstruction_matches_recorded_observables() {
        use crate::sched::PrefillQueueMoments;
        let chunk = crate::sched::DEFAULT_CHUNK_TOKENS;
        let mut moments = PrefillQueueMoments::default();
        moments.add_task(100, 100, chunk);
        moments.add_task(5000, 5000, chunk);
        let snap = Snap {
            change_epoch: 3,
            engines: vec![super::super::EngineRec {
                queued: vec![(100, 100), (5000, 5000)],
                moments,
                chunk_tokens: chunk,
                running_tokens: 640,
                max_kv_tokens: 1 << 20,
                avg_token_interval: 0.0125,
                has_decode_work: true,
                liveness: 0,
            }],
        };
        let insts = sim_instances(&snap).expect("representable");
        let v = SimView(&insts);
        let e = &snap.engines[0];
        assert_eq!(v.prefill_queue_moments(0), e.moments);
        assert_eq!(v.running_tokens(0), e.running_tokens);
        assert_eq!(v.max_kv_tokens(0), e.max_kv_tokens);
        assert_eq!(v.avg_token_interval(0).to_bits(), e.avg_token_interval.to_bits());
        assert!(v.has_decode_work(0) && v.has_prefill_work(0));
        // And the server rebuild serves the identical observables.
        let sv = snap.to_server_view();
        assert_eq!(sv.prefill_queue_moments(0), e.moments);
        assert_eq!(sv.change_epoch(), 3);
    }

    #[test]
    fn unrepresentable_snapshots_are_refused_not_faked() {
        let base = |running, decode| Snap {
            change_epoch: 0,
            engines: vec![super::super::EngineRec {
                queued: vec![],
                moments: Default::default(),
                chunk_tokens: crate::sched::DEFAULT_CHUNK_TOKENS,
                running_tokens: running,
                max_kv_tokens: 1000,
                avg_token_interval: f64::NAN,
                has_decode_work: decode,
                liveness: 0,
            }],
        };
        // Resident decode KV but no visible decode work: sim can't say that.
        assert!(sim_instances(&base(50, false)).is_none());
        assert!(sim_instances(&base(50, true)).is_some());
        assert!(sim_instances(&base(0, true)).is_some());
        // Chunk progress in the queue: live path never records it.
        let mut torn = base(0, false);
        torn.engines[0].queued = vec![(100, 60)];
        assert!(sim_instances(&torn).is_none());
    }
}
