//! Deterministic fault-injection plane (PR 6, ROADMAP "Robustness
//! architecture").
//!
//! Arrow's robustness argument is that *stateless* instances make
//! recovery cheap (paper §5.2): any instance can re-run a prefill or
//! adopt a decode because no scheduler state lives on the instance. The
//! repo's membership machinery (PR 3) only exercised clean, scripted
//! `Join/Drain/Fail` events; this module adds the messy middle — flapping
//! transfer links, stragglers, stalls, crash-and-rejoin cycles — as a
//! *seeded, fully deterministic* [`FaultPlan`] so chaos runs are
//! replayable bit-for-bit and byte-identical across the simulator's
//! cursor and reference event loops.
//!
//! Nothing here reads a wall clock or an OS entropy source: fault times
//! come from [`FaultPlan::seeded`] (xoshiro via [`Rng`]) and retry jitter
//! from [`TransferRetryPolicy::backoff_delay`], a pure function of
//! `(seed, request id, attempt)`.

use crate::util::rng::Rng;

/// Seconds since run start (same clock as [`crate::request::Time`]).
pub type Time = f64;

/// One injectable fault. `Copy`: fault events ride the simulator's event
/// heap, which must stay allocation-free per event (PR-1 invariant).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The transfer channel out of instance `link` rejects/drops KV
    /// copies for `window` seconds (NIC flap, fabric congestion).
    TransferFlap { link: usize, window: f64 },
    /// Instance `inst` runs `slowdown`× slower for `window` seconds
    /// (thermal throttle, noisy neighbor): every iteration's duration is
    /// dilated, which the monitor observes as token-interval outliers.
    Straggler { inst: usize, slowdown: f64, window: f64 },
    /// Instance `inst` freezes for `duration` seconds: no new iterations
    /// start until the stall clears (GC pause, driver hiccup). A
    /// `duration` of 0.0 is the internal end-of-stall wake marker.
    EngineStall { inst: usize, duration: f64 },
    /// Instance `inst` fails hard now and rejoins `downtime` seconds
    /// later (reuses the PR-3 membership machinery: fail re-places live
    /// work, rejoin restores capacity).
    CrashRejoin { inst: usize, downtime: f64 },
}

impl FaultKind {
    /// The instance (or link endpoint) this fault targets.
    pub fn instance(&self) -> usize {
        match *self {
            FaultKind::TransferFlap { link, .. } => link,
            FaultKind::Straggler { inst, .. } => inst,
            FaultKind::EngineStall { inst, .. } => inst,
            FaultKind::CrashRejoin { inst, .. } => inst,
        }
    }
}

/// A time-ordered schedule of faults to inject into one run.
///
/// The plan is *data*, not behavior: the simulator turns each entry into
/// an `EventKind::Fault` on its ordinary `(time, seq)` heap, so a plan
/// perturbs a run exactly like any other event source and an empty plan
/// adds zero events (and zero per-event allocation).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<(Time, FaultKind)>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Append a fault at absolute time `at` (seconds from run start).
    /// Entries may be pushed out of order; `events()` returns them in
    /// schedule order.
    pub fn push(&mut self, at: Time, kind: FaultKind) {
        assert!(at.is_finite() && at >= 0.0, "fault time must be finite and >= 0");
        self.events.push((at, kind));
        // Keep schedule order on insert: plans are tiny (a handful of
        // faults), and sorted order is what run_mode pushes verbatim so
        // cursor/reference seq assignment matches.
        let mut i = self.events.len() - 1;
        while i > 0 && self.events[i - 1].0 > self.events[i].0 {
            self.events.swap(i - 1, i);
            i -= 1;
        }
    }

    /// The schedule, ordered by injection time (ties keep insert order).
    pub fn events(&self) -> &[(Time, FaultKind)] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate a deterministic chaos schedule for an `n_instances`
    /// cluster over a run of `duration` seconds.
    ///
    /// `intensity` scales the number of faults (~4 per unit; 0.0 means an
    /// empty plan — the chaos harness's fault-free baseline). All faults
    /// are injected in `[0.2, 0.55] * duration` and every window/downtime
    /// ends by `0.75 * duration`, so the tail of the run is a clean
    /// recovery region the chaos tier can compare against the fault-free
    /// steady state.
    pub fn seeded(seed: u64, n_instances: usize, duration: f64, intensity: f64) -> FaultPlan {
        assert!(n_instances > 0, "fault plan needs at least one instance");
        assert!(duration > 0.0 && duration.is_finite());
        assert!(intensity >= 0.0);
        let n_events = (intensity * 4.0).round() as usize;
        let mut plan = FaultPlan::new();
        if n_events == 0 {
            return plan;
        }
        let mut rng = Rng::new(seed);
        for _ in 0..n_events {
            let at = (0.2 + 0.35 * rng.f64()) * duration;
            // Longest allowed disruption still ends inside the 0.75
            // recovery horizon.
            let max_window = (0.75 * duration - at).max(1e-6);
            let window = (0.05 + 0.15 * rng.f64()) * duration;
            let window = window.min(max_window);
            let inst = rng.index(n_instances);
            let kind = match rng.index(4) {
                0 => FaultKind::TransferFlap { link: inst, window },
                1 => FaultKind::Straggler {
                    inst,
                    slowdown: 2.0 + 2.0 * rng.f64(),
                    window,
                },
                2 => FaultKind::EngineStall {
                    inst,
                    duration: window,
                },
                _ => FaultKind::CrashRejoin {
                    inst,
                    downtime: window,
                },
            };
            plan.push(at, kind);
        }
        plan
    }
}

/// KV-transfer retry policy: capped exponential backoff with
/// deterministic, seeded jitter (no wall clock — retries must replay
/// bit-for-bit and stay byte-identical across event-loop modes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferRetryPolicy {
    /// Retries before escalating to stateless re-placement (attempt
    /// numbers 1..=max_retries re-enqueue the same route).
    pub max_retries: u32,
    /// Backoff before the first retry (seconds).
    pub base_delay_s: f64,
    /// Backoff cap (seconds).
    pub max_delay_s: f64,
    /// Jitter stream seed; the jitter for a given (request, attempt) is a
    /// pure function of this seed.
    pub seed: u64,
}

impl Default for TransferRetryPolicy {
    fn default() -> TransferRetryPolicy {
        TransferRetryPolicy {
            max_retries: 2,
            base_delay_s: 0.5,
            max_delay_s: 8.0,
            seed: 0x41525257, // "ARRW"
        }
    }
}

impl TransferRetryPolicy {
    /// Delay before retry number `attempt` (1-based) of request `req`.
    ///
    /// `min(base * 2^(attempt-1), cap)`, then scaled into `[0.5, 1.0)` of
    /// itself by a jitter value drawn from an rng keyed on
    /// `(seed, req, attempt)` — decorrelated across requests so a burst
    /// of simultaneous timeouts doesn't retry in lockstep, yet fully
    /// deterministic for replay.
    pub fn backoff_delay(&self, req: u64, attempt: u32) -> f64 {
        debug_assert!(attempt >= 1, "attempts are 1-based");
        let exp = (attempt - 1).min(30);
        let raw = (self.base_delay_s * (1u64 << exp) as f64).min(self.max_delay_s);
        let mut rng = Rng::new(
            self.seed
                ^ req.wrapping_mul(0x9E3779B97F4A7C15)
                ^ (attempt as u64).wrapping_mul(0xBF58476D1CE4E5B9),
        );
        raw * (0.5 + 0.5 * rng.f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(42, 5, 600.0, 1.5);
        let b = FaultPlan::seeded(42, 5, 600.0, 1.5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6); // 1.5 * 4
        let c = FaultPlan::seeded(43, 5, 600.0, 1.5);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn zero_intensity_is_empty() {
        let p = FaultPlan::seeded(7, 4, 300.0, 0.0);
        assert!(p.is_empty());
        assert_eq!(p.len(), 0);
    }

    #[test]
    fn seeded_faults_respect_bounds_and_recovery_horizon() {
        for seed in 0..20 {
            let d = 500.0;
            let p = FaultPlan::seeded(seed, 6, d, 2.0);
            for &(at, kind) in p.events() {
                assert!((0.2 * d..=0.55 * d).contains(&at), "at={at}");
                assert!(kind.instance() < 6);
                let end = match kind {
                    FaultKind::TransferFlap { window, .. } => at + window,
                    FaultKind::Straggler { slowdown, window, .. } => {
                        assert!((2.0..4.0).contains(&slowdown));
                        at + window
                    }
                    FaultKind::EngineStall { duration, .. } => at + duration,
                    FaultKind::CrashRejoin { downtime, .. } => at + downtime,
                };
                assert!(
                    end <= 0.75 * d + 1e-9,
                    "fault {kind:?}@{at} must clear by the recovery horizon"
                );
            }
        }
    }

    #[test]
    fn plan_events_come_out_time_ordered() {
        let mut p = FaultPlan::new();
        p.push(5.0, FaultKind::EngineStall { inst: 0, duration: 1.0 });
        p.push(1.0, FaultKind::TransferFlap { link: 1, window: 2.0 });
        p.push(3.0, FaultKind::CrashRejoin { inst: 2, downtime: 4.0 });
        let times: Vec<f64> = p.events().iter().map(|e| e.0).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let p = TransferRetryPolicy::default();
        // Deterministic: same (req, attempt) -> same delay.
        assert_eq!(p.backoff_delay(9, 1), p.backoff_delay(9, 1));
        // Jitter keeps each delay in [raw/2, raw).
        for attempt in 1..=8u32 {
            let raw = (p.base_delay_s * (1u64 << (attempt - 1)) as f64).min(p.max_delay_s);
            let d = p.backoff_delay(3, attempt);
            assert!(d >= raw * 0.5 && d < raw, "attempt {attempt}: {d} vs raw {raw}");
        }
        // Capped: deep attempts never exceed the cap.
        assert!(p.backoff_delay(3, 30) < p.max_delay_s);
        // Decorrelated across requests.
        assert_ne!(p.backoff_delay(1, 1), p.backoff_delay(2, 1));
    }
}
