//! Minimal CLI argument parser (substrate for the unavailable `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with typed getters and an auto-generated usage
//! block assembled from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ParsedArgs {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parse raw args (without argv[0]). Every `--name` is recorded; a
/// following non-flag token becomes its value, otherwise "true".
pub fn parse(args: &[String]) -> ParsedArgs {
    let mut positional = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), "true".to_string());
            }
        } else {
            positional.push(a.clone());
        }
        i += 1;
    }
    ParsedArgs { positional, flags }
}

impl ParsedArgs {
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected a number, got '{v}'"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected an integer, got '{v}'"))),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.u64_or(name, default as u64)? as usize)
    }

    /// Reject unknown flags (catches typos early).
    pub fn check_known(&self, known: &[&str]) -> Result<(), CliError> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(CliError(format!(
                    "unknown flag --{k} (known: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn positional_and_flags() {
        let p = parse(&args(&["figures", "fig7", "--gpus", "8", "--seed=3", "--verbose"]));
        assert_eq!(p.positional, vec!["figures", "fig7"]);
        assert_eq!(p.flag("gpus"), Some("8"));
        assert_eq!(p.flag("seed"), Some("3"));
        assert_eq!(p.flag("verbose"), Some("true"));
        assert!(p.has("verbose"));
    }

    #[test]
    fn typed_getters() {
        let p = parse(&args(&["--rate", "2.5", "--n", "7"]));
        assert_eq!(p.f64_or("rate", 1.0).unwrap(), 2.5);
        assert_eq!(p.u64_or("n", 0).unwrap(), 7);
        assert_eq!(p.f64_or("missing", 4.0).unwrap(), 4.0);
        assert!(p.f64_or("n", 0.0).is_ok());
        let bad = parse(&args(&["--rate", "abc"]));
        assert!(bad.f64_or("rate", 1.0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let p = parse(&args(&["--gpus", "8", "--typo", "1"]));
        assert!(p.check_known(&["gpus"]).is_err());
        assert!(p.check_known(&["gpus", "typo"]).is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let p = parse(&args(&["--offset", "-5"]));
        // "-5" does not start with "--", so it is the value.
        assert_eq!(p.flag("offset"), Some("-5"));
    }
}
