//! The global-scheduling policy interface.
//!
//! Both Arrow (coordinator::arrow) and the baselines implement [`Policy`].
//! The simulator owns the engine/timing; policies own only *decisions* —
//! which instance prefills a request, which decodes it, and when instances
//! move between pools. This split is the paper's stateless-instance
//! insight (§3.4): roles live in the scheduler's pool bookkeeping, never
//! in the engine.
//!
//! # Contract with the event loop
//!
//! * **Determinism.** A policy must be a pure function of its own state
//!   and the arguments it is handed — no wall clock, no ambient
//!   randomness. The simulator's byte-identical-schedule guarantee
//!   (ROADMAP "Performance architecture") holds only under this contract.
//! * **Hot path.** `place_prefill`/`place_decode` run once per request;
//!   implementations should avoid per-call allocation (see
//!   `Pools::members_iter` / `SimInstance::prefill_queue_iter` for
//!   allocation-free cluster queries) and must never panic on degenerate
//!   float comparisons — use `f64::total_cmp`, not
//!   `partial_cmp().unwrap()`.

use crate::engine::SimInstance;
use crate::request::{InstanceId, Request, Time};

pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Called once before the run with the final instance set (the
    /// paper's startup profiling hook — TTFT predictor fitting).
    fn init(&mut self, _instances: &[SimInstance]) {}

    /// Select the instance that will run `req`'s prefill phase (Alg. 1
    /// for Arrow; trivial for baselines).
    fn place_prefill(
        &mut self,
        now: Time,
        req: &Request,
        instances: &[SimInstance],
    ) -> InstanceId;

    /// Select the instance that will run `req`'s decode phase (Alg. 2).
    fn place_decode(
        &mut self,
        now: Time,
        req: &Request,
        prefill_instance: InstanceId,
        instances: &[SimInstance],
    ) -> InstanceId;

    /// Periodic monitor tick (paper §5.5: TPOT-violation and idle-prefill
    /// instance scheduling happen here).
    fn on_tick(&mut self, _now: Time, _instances: &[SimInstance]) {}

    /// Pool sizes [Prefill, Decode, P→D, D→P] for snapshots, if the
    /// policy maintains elastic pools.
    fn pool_sizes(&self) -> Option<[usize; 4]> {
        None
    }

    /// Number of instance flips performed so far (ablation metric).
    fn flip_count(&self) -> u64 {
        0
    }
}

/// Trivial policies used by simulator unit tests.
pub mod tests_support {
    use super::*;

    /// Everything on instance 0 (colocated single instance).
    pub struct AllToOne;

    impl Policy for AllToOne {
        fn name(&self) -> &'static str {
            "all-to-one"
        }

        fn place_prefill(&mut self, _: Time, _: &Request, _: &[SimInstance]) -> InstanceId {
            InstanceId(0)
        }

        fn place_decode(
            &mut self,
            _: Time,
            _: &Request,
            _prefill: InstanceId,
            _: &[SimInstance],
        ) -> InstanceId {
            InstanceId(0)
        }
    }

    /// Fixed prefill/decode instance sets, round-robin within each.
    pub struct StaticSplit {
        pub prefill: Vec<usize>,
        pub decode: Vec<usize>,
    }

    impl Policy for StaticSplit {
        fn name(&self) -> &'static str {
            "static-split"
        }

        fn place_prefill(&mut self, _: Time, req: &Request, _: &[SimInstance]) -> InstanceId {
            InstanceId(self.prefill[req.id.0 as usize % self.prefill.len()])
        }

        fn place_decode(
            &mut self,
            _: Time,
            req: &Request,
            _prefill: InstanceId,
            _: &[SimInstance],
        ) -> InstanceId {
            InstanceId(self.decode[req.id.0 as usize % self.decode.len()])
        }
    }
}
