//! Re-export shim: the policy interface lives in [`crate::sched`] now.
//!
//! PR 2 moved the [`Policy`] trait out of the simulator into the
//! substrate-agnostic scheduling core (`rust/src/sched/`), so the live
//! PJRT server drives the exact same `ArrowPolicy` object as the
//! simulator. Policies consume [`crate::sched::ClusterView`] snapshots
//! instead of `&[SimInstance]`; the simulator's zero-cost adapter is
//! [`crate::sim::SimView`]. This module keeps the historical
//! `sim::policy::*` paths (used by tests, benches and downstream code)
//! pointing at the new home.

pub use crate::sched::policy::tests_support;
pub use crate::sched::{ClusterView, Policy, ProfileSource};
