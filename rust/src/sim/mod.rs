//! Deterministic discrete-event cluster simulator.
//!
//! Drives a set of [`SimInstance`]s through a workload trace under a
//! pluggable global scheduling [`Policy`] (Arrow or a baseline). Virtual
//! time + the calibrated [`CostModel`] make hour-long 8×H800 traces
//! tractable on CPU while exercising exactly the same policy code the
//! real-mode server runs (DESIGN.md §7).
//!
//! Event flow mirrors the paper's Fig. 3 pipeline:
//! `Arrival → (q1) prefill chunks → PrefillDone/first token → decode
//! placement → (q2) KV fetch queue → transfer (c) → (q3) decode batch →
//! tokens → finish`.
//!
//! # Hot-path architecture
//!
//! The fig7/8/9 sweeps run hundreds of full-trace simulations, so the
//! event loop is engineered for events/s (bench target ≥ 1M events/s,
//! gated by `benches/simulator.rs`):
//!
//! * **Calendar arrivals.** The trace is already sorted by arrival time,
//!   so arrivals are consumed through a cursor (`next_arrival`) merged
//!   against the event heap, instead of pre-pushing all N arrivals as
//!   heap entries. The heap holds only in-flight events
//!   (IterDone/TransferDone/FabricPoll/MonitorTick) — O(instances), not
//!   O(trace) — which shrinks every push/pop from O(log N) to O(log I).
//! * **Determinism via `seq`.** Events are totally ordered by
//!   `(time, seq)` using `f64::total_cmp` (no NaN panic, total order even
//!   for degenerate inputs). Arrivals conceptually carry lower sequence
//!   numbers than any runtime-scheduled event, so the cursor merge breaks
//!   time ties in favour of arrivals — byte-identical to the legacy
//!   pre-pushed-heap schedule (see `run_reference` + the equivalence
//!   property test).
//! * **Zero-clone event handlers.** `Request` is `Copy`; the policy is a
//!   plain `Box<dyn Policy>` field borrowed disjointly from the instance
//!   table (no `Option::take` dance, no per-event `Request` clone).
//! * **Shared cost model.** `Arc<CostModel>` is shared by the instances
//!   and the transfer fabric — `poll_fabric` no longer deep-clones a cost
//!   model per call, and `Cluster::homogeneous` no longer deep-clones one
//!   per instance.
//! * **Buffer reuse.** Iteration completions write into one reusable
//!   `Produced` buffer instead of allocating a `Vec` per iteration.
//! * **Streaming window (PR 7).** Per-request state lives in a sliding
//!   window of [`Slot`]s indexed by global arrival index. In the classic
//!   `run`/`run_reference` modes the window never drains (the records
//!   come back in `SimResult`, byte-identical to the pre-window code).
//!   In `run_streamed` mode arrivals are pulled lazily from an
//!   [`ArrivalSource`], completed records are handed to a sink and their
//!   slots freed, so memory is O(instances + in-flight), not O(trace).

pub mod policy;
pub mod view;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use crate::costmodel::CostModel;
use crate::engine::{IterationPlan, Produced, SimInstance, Transfer, TransferFabric};
use crate::fault::{FaultKind, FaultPlan, TransferRetryPolicy};
use crate::request::{
    InstanceId, Request, RequestId, RequestRecord, RequestState, ShedReason, SloClass, Time,
};
use crate::sched::{Epoched, Liveness, MembershipEvent};
use crate::trace::stream::{ArrivalSource, TraceSource};
use crate::trace::Trace;

pub use policy::Policy;
pub use view::SimView;

/// Interval of the instance-monitor tick (paper Fig. 5 VI).
pub const MONITOR_PERIOD: f64 = 1.0;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// A scheduled cluster-membership change (PR 3 elastic membership).
/// Instances are table slots: `Join` brings a slot to life (first join or
/// rejoin after drain/failure), `Drain` retires it gracefully once its
/// in-flight work finishes, `Fail` kills it immediately — the event loop
/// re-queues everything it held. `Restart` is the rolling-upgrade
/// primitive: a drain whose rejoin fires `downtime` after the drain
/// *completes* — unlike a fixed-time Drain+Join pair, a slow drain can
/// never be silently cancelled by its own rejoin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MembershipChange {
    Join(usize),
    Drain(usize),
    Fail(usize),
    Restart { inst: usize, downtime: f64 },
}

impl MembershipChange {
    pub fn instance(self) -> usize {
        match self {
            MembershipChange::Join(i)
            | MembershipChange::Drain(i)
            | MembershipChange::Fail(i) => i,
            MembershipChange::Restart { inst, .. } => inst,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
enum EventKind {
    /// Only used by the reference (pre-pushed) mode; the production loop
    /// drives arrivals from the trace cursor instead.
    Arrival { idx: usize },
    /// `epoch` guards against completions from a previous life of the
    /// instance: a failure bumps the epoch, so an IterDone scheduled
    /// before the crash is ignored when it fires (the work it carried was
    /// already re-queued).
    IterDone { inst: usize, epoch: u64 },
    TransferDone { req: usize, from: usize, to: usize, kv: u32 },
    FabricPoll,
    MonitorTick,
    Membership(MembershipChange),
    /// Deterministic fault injection (PR 6): the scheduled entries of a
    /// [`FaultPlan`], plus internally scheduled end-of-stall markers.
    /// `Copy` payload — fault events cost the heap nothing extra.
    Fault(FaultKind),
    /// Retry a timed-out KV transfer on the same route after backoff.
    /// `gen` is the request's transfer generation at scheduling time: a
    /// re-placement or restart bumps the generation, making stale retries
    /// recognizably dead (same trick as `IterDone`'s epoch).
    TransferRetry { req: usize, from: usize, to: usize, kv: u32, gen: u32 },
}

#[derive(Debug, Clone)]
struct Event {
    time: Time,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        // Must agree with `Ord` (which uses total_cmp): IEEE `==` would
        // disagree on -0.0/+0.0 and NaN and break the Eq/Ord contract.
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Total order: time, then insertion sequence (determinism).
        // `total_cmp` keeps this a *total* order even for degenerate
        // traces (identical timestamps, or a NaN smuggled in by a broken
        // generator) — `partial_cmp().unwrap()` here was a latent panic.
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

// ---------------------------------------------------------------------------
// Cluster configuration & snapshots
// ---------------------------------------------------------------------------

/// Class-aware admission control (PR 8): gate *fresh* arrivals on the
/// number of requests currently in flight, shedding lax-SLO work first.
/// Batch is refused once in-flight load reaches `batch_headroom ×
/// max_inflight`, Standard at `standard_headroom × max_inflight`, and
/// Interactive only at the full cap. With `class_aware` false every class
/// sheds at the full cap — the class-blind baseline the claims harness
/// compares against. Refused requests fail explicitly with
/// [`ShedReason::NoCapacity`] (the PR-6 no-silent-loss contract); restarts
/// and re-placements of already-admitted requests are never re-gated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionControl {
    /// Hard in-flight cap; every class is refused at or above this.
    pub max_inflight: usize,
    /// Batch sheds at this fraction of the cap (default 0.5).
    pub batch_headroom: f64,
    /// Standard sheds at this fraction of the cap (default 0.8).
    pub standard_headroom: f64,
    /// When false, classes are ignored: one cap for all.
    pub class_aware: bool,
}

impl AdmissionControl {
    pub fn new(max_inflight: usize) -> Self {
        AdmissionControl {
            max_inflight,
            batch_headroom: 0.5,
            standard_headroom: 0.8,
            class_aware: true,
        }
    }

    /// In-flight cap applied to `class`. Fractions floor to an integer
    /// count, never below 1 — a nonzero cap must admit *something* of
    /// every class when the system is empty.
    fn cap_for(&self, class: SloClass) -> usize {
        if !self.class_aware {
            return self.max_inflight;
        }
        let frac = match class {
            SloClass::Interactive => 1.0,
            SloClass::Standard => self.standard_headroom,
            SloClass::Batch => self.batch_headroom,
        };
        ((self.max_inflight as f64 * frac) as usize).max(1)
    }

    /// Would a fresh arrival of `class` be admitted with `inflight`
    /// other requests currently in the system?
    pub fn admits(&self, class: SloClass, inflight: usize) -> bool {
        inflight < self.cap_for(class)
    }
}

/// Per-simulation knobs beyond instance hardware.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Give up on the run this long after the last arrival (guards
    /// against pathological policies stalling the event loop).
    pub drain_timeout: f64,
    /// Record per-tick instance snapshots (Fig. 4 timelines).
    pub record_timeline: bool,
    /// Shared KV transfer buffer cap in tokens (vLLM-disagg quirk).
    pub transfer_buffer_tokens: Option<u64>,
    /// Fail requests whose KV transfer waits longer than this.
    pub transfer_fail_timeout: Option<f64>,
    /// Interval of the instance-monitor tick. Default [`MONITOR_PERIOD`];
    /// the metamorphic cost-scale tier dilates it together with the cost
    /// model so the whole simulation is an exact time dilation (a fixed
    /// 1 s tick would otherwise sample the dilated run at a different
    /// phase and legitimately flip instances at different moments).
    pub monitor_period: f64,
    /// Retry timed-out KV transfers with capped, seeded backoff before
    /// escalating to stateless decode re-placement (PR 6). `None` keeps
    /// the legacy fail-fast semantics byte-identical (golden digests).
    pub transfer_retry: Option<TransferRetryPolicy>,
    /// Straggler detection at the monitor tick: an in-cluster instance
    /// whose token interval exceeds `factor ×` the cluster median turns
    /// `Liveness::Degraded` (deprioritized by the policy) until it
    /// recovers. `None` (default) disables detection entirely — fault-free
    /// scenarios keep their exact schedules.
    pub straggler_factor: Option<f64>,
    /// Class-aware overload admission (PR 8). `None` (default) admits
    /// everything — existing schedules stay byte-identical.
    pub admission: Option<AdmissionControl>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            drain_timeout: 3600.0,
            record_timeline: false,
            transfer_buffer_tokens: None,
            transfer_fail_timeout: None,
            monitor_period: MONITOR_PERIOD,
            transfer_retry: None,
            straggler_factor: None,
            admission: None,
        }
    }
}

/// One monitor-tick snapshot of an instance (Fig. 4 series).
#[derive(Debug, Clone)]
pub struct InstantSnapshot {
    pub time: Time,
    /// Per-instance (prefill requests, decode requests, running tokens).
    pub per_instance: Vec<(usize, usize, u64)>,
    /// Policy pool sizes [P, D, P→D, D→P] if the policy exposes them.
    pub pools: Option<[usize; 4]>,
    /// Instances currently in the cluster (Active + Draining).
    pub live: usize,
}

/// Result of a simulation run.
#[derive(Debug)]
pub struct SimResult {
    pub records: Vec<RequestRecord>,
    pub timeline: Vec<InstantSnapshot>,
    pub sim_time: Time,
    pub events_processed: u64,
    pub total_iterations: u64,
    pub total_flips: u64,
}

// ---------------------------------------------------------------------------
// The cluster
// ---------------------------------------------------------------------------

/// Per-request simulation state, keyed by global arrival index. Slots live
/// in a sliding window (`Cluster::slots` + `Cluster::base`): retained runs
/// never drain the window (so `SimResult::records` comes back whole and
/// byte-identical to the pre-window layout of parallel vectors), while a
/// streamed run pops completed front slots to the sink and frees them.
struct Slot {
    req: Request,
    rec: RequestRecord,
    /// (source epoch, target epoch) captured when a fetch was admitted;
    /// a mismatch at TransferDone means that endpoint failed (and
    /// possibly rejoined) mid-transfer — its parked KV / reservation no
    /// longer exists, even if the slot is Active again.
    fetch_epoch: (u64, u64),
    /// Transfer retry attempts (cumulative across routes: the escalation
    /// ladder retry → re-place → shed is bounded per request).
    transfer_attempts: u32,
    /// Transfer generation, bumped at every fetch admission; a
    /// `TransferRetry` event whose generation is stale is a no-op.
    transfer_gen: u32,
    /// Outstanding external references: fabric-queued transfers plus
    /// in-heap TransferDone/TransferRetry events naming this request.
    /// A slot only drains to the streaming sink at zero — a stale
    /// transfer completion must still find the epochs it needs to
    /// release the right reservations (chaos no-silent-loss contract).
    refs: u32,
}

impl Slot {
    fn new(req: Request, streaming: bool) -> Self {
        Slot {
            rec: if streaming {
                RequestRecord::new_streaming(&req)
            } else {
                RequestRecord::new(&req)
            },
            req,
            fetch_epoch: (0, 0),
            transfer_attempts: 0,
            transfer_gen: 0,
            refs: 0,
        }
    }

    fn settled(&self) -> bool {
        matches!(
            self.rec.state,
            RequestState::Finished | RequestState::Failed
        ) && self.refs == 0
    }
}

pub struct Cluster {
    pub now: Time,
    instances: Vec<SimInstance>,
    fabric: TransferFabric,
    policy: Box<dyn Policy>,
    /// Sliding window of per-request state: `slots[i]` holds global
    /// arrival index `base + i`. Retained modes keep `base == 0`.
    slots: VecDeque<Slot>,
    /// Global arrival index of `slots[0]`.
    base: usize,
    /// Requests admitted from the arrival source so far; the next
    /// admission takes global index `arrived`.
    arrived: usize,
    /// One-ahead arrival peeked from the source but not yet admitted —
    /// the streaming face of the old sorted-slice cursor.
    pending: Option<Request>,
    /// The arrival source has returned `None` (and stays exhausted).
    exhausted: bool,
    events: BinaryHeap<Reverse<Event>>,
    seq: u64,
    /// In-flight iteration plan per instance.
    plans: Vec<Option<IterationPlan>>,
    /// Per-instance life epoch: bumped on failure so completions from a
    /// previous life are recognizably stale.
    epochs: Vec<u64>,
    /// Pending rejoin delays of `Restart` drains: when slot `i` finishes
    /// draining, a Join fires `restart_after[i]` seconds later.
    restart_after: Vec<Option<f64>>,
    /// Instances that start outside the cluster (join later); None means
    /// everyone is live at t=0 (the fixed-membership default).
    initial_live: Option<Vec<bool>>,
    /// Scheduled membership changes, pushed into the event heap at run
    /// start (identically in cursor and reference modes).
    membership_schedule: Vec<(Time, MembershipChange)>,
    /// Scheduled fault injections (PR 6), pushed right after the
    /// membership schedule — empty plan, zero events, zero cost.
    fault_schedule: Vec<(Time, FaultKind)>,
    /// Per-instance stall horizon (`EngineStall`): no new iteration
    /// starts while `now < stall_until[i]`.
    stall_until: Vec<f64>,
    /// Per-instance straggler window (`Straggler`): iteration durations
    /// are dilated by `slow_factor[i]` while `now < slow_until[i]`.
    slow_until: Vec<f64>,
    slow_factor: Vec<f64>,
    /// Scratch for straggler detection (reused across ticks).
    interval_buf: Vec<f64>,
    /// Per-target queues of (req idx, from) waiting for target memory (q2).
    fetch_wait: Vec<VecDeque<(usize, usize)>>,
    /// Reusable buffer for iteration-completion events.
    produced_buf: Vec<Produced>,
    /// Mutation clock (PR 4): bumped whenever any instance's
    /// scheduler-visible load state (prefill queue, decode tokens)
    /// changes. Policy calls receive it through `sched::Epoched`, so a
    /// policy whose last decision saw the same clock value can skip its
    /// argmin-index refresh entirely.
    clock: u64,
    done: usize,
    timeline: Vec<InstantSnapshot>,
    cfg: SimConfig,
    events_processed: u64,
    last_arrival: Time,
}

impl Cluster {
    pub fn new(
        instances: Vec<SimInstance>,
        policy: Box<dyn Policy>,
        cfg: SimConfig,
    ) -> Self {
        let n = instances.len();
        assert!(n > 0, "cluster needs at least one instance");
        // Fabric timing follows instance 0's cost model (homogeneous NIC
        // assumption) — a refcount bump, not a deep clone.
        let mut fabric = TransferFabric::new(n, Arc::clone(&instances[0].cost));
        fabric.buffer_cap_tokens = cfg.transfer_buffer_tokens;
        fabric.fail_timeout = cfg.transfer_fail_timeout;
        // Retry mode needs wakeups at timeout deadlines / flap ends so a
        // blocked transfer is guaranteed to fail into the retry path.
        fabric.timeout_wakeups = cfg.transfer_retry.is_some();
        Cluster {
            now: 0.0,
            instances,
            fabric,
            policy,
            slots: VecDeque::new(),
            base: 0,
            arrived: 0,
            pending: None,
            exhausted: true,
            events: BinaryHeap::new(),
            seq: 0,
            plans: (0..n).map(|_| None).collect(),
            epochs: vec![0; n],
            restart_after: vec![None; n],
            initial_live: None,
            membership_schedule: Vec::new(),
            fault_schedule: Vec::new(),
            stall_until: vec![0.0; n],
            slow_until: vec![0.0; n],
            slow_factor: vec![1.0; n],
            interval_buf: Vec::new(),
            fetch_wait: (0..n).map(|_| VecDeque::new()).collect(),
            produced_buf: Vec::new(),
            clock: 0,
            done: 0,
            timeline: Vec::new(),
            cfg,
            events_processed: 0,
            last_arrival: 0.0,
        }
    }

    /// Convenience: n identical instances sharing one cost model.
    pub fn homogeneous(n: usize, cost: CostModel, policy: Box<dyn Policy>, cfg: SimConfig) -> Self {
        let cost = Arc::new(cost);
        let instances = (0..n)
            .map(|i| SimInstance::new(InstanceId(i), Arc::clone(&cost)))
            .collect();
        Cluster::new(instances, policy, cfg)
    }

    fn push(&mut self, time: Time, kind: EventKind) {
        self.seq += 1;
        self.events.push(Reverse(Event {
            time,
            seq: self.seq,
            kind,
        }));
    }

    /// Record that instance load state changed. Call sites are exactly
    /// the mutations a placement key can depend on (enqueue, adopt,
    /// iteration completion, failure teardown); a missed bump would let a
    /// policy act on a stale argmin index, so when in doubt, bump — a
    /// spurious bump only costs one aggregate-compare scan.
    fn touch(&mut self) {
        self.clock += 1;
    }

    // Policy-facing views are built inline as
    // `Epoched(SimView(&self.instances), self.clock)` at each call site:
    // a helper method would borrow the whole `Cluster` and collide with
    // the `&mut self.policy` receiver (the disjoint-field-borrow pattern
    // from PR 1).

    /// Mark which instances are live at t=0 (the rest join later via the
    /// membership schedule). Must cover the whole table.
    pub fn set_initial_live(&mut self, live: Vec<bool>) {
        assert_eq!(live.len(), self.instances.len(), "initial_live must cover the table");
        assert!(live.iter().any(|&l| l), "at least one instance must start live");
        self.initial_live = Some(live);
    }

    /// Schedule a membership change at simulated time `at`. Same-time
    /// changes fire in schedule order; ties with an arrival resolve to
    /// the arrival first (the same rule every runtime event follows).
    pub fn schedule_membership(&mut self, at: Time, change: MembershipChange) {
        assert!(change.instance() < self.instances.len(), "unknown instance");
        self.membership_schedule.push((at, change));
    }

    /// Schedule a fault injection at simulated time `at` (PR 6). Faults
    /// enter the heap in schedule order right after the membership
    /// schedule, identically in cursor and reference modes.
    pub fn schedule_fault(&mut self, at: Time, kind: FaultKind) {
        assert!(kind.instance() < self.instances.len(), "unknown instance");
        self.fault_schedule.push((at, kind));
    }

    /// Schedule every entry of a [`FaultPlan`].
    pub fn schedule_fault_plan(&mut self, plan: &FaultPlan) {
        for &(at, kind) in plan.events() {
            self.schedule_fault(at, kind);
        }
    }

    /// Window accessors: global arrival index → resident slot. Retained
    /// modes keep `base == 0`, so these are plain vector indexing there.
    #[inline]
    fn slot(&self, idx: usize) -> &Slot {
        &self.slots[idx - self.base]
    }

    #[inline]
    fn slot_mut(&mut self, idx: usize) -> &mut Slot {
        &mut self.slots[idx - self.base]
    }

    /// Admit the next arrival: normalize its id to the global arrival
    /// index (traces and sources may carry arbitrary ids) and open its
    /// slot. Returns the index.
    fn admit(&mut self, raw: Request, streaming: bool) -> usize {
        let idx = self.arrived;
        let req = Request {
            id: RequestId(idx as u64),
            ..raw
        };
        self.slots.push_back(Slot::new(req, streaming));
        self.arrived += 1;
        idx
    }

    /// Run the trace to completion; consumes the cluster.
    pub fn run(self, trace: &Trace) -> SimResult {
        let mut src = TraceSource::new(trace);
        self.run_core(&mut src, Some(trace.duration()), false, None)
    }

    /// Legacy semantics: pre-push every arrival into the event heap (the
    /// seed implementation). Kept as the reference for the
    /// calendar-vs-heap equivalence property test; O(N) heap, slow.
    #[doc(hidden)]
    pub fn run_reference(self, trace: &Trace) -> SimResult {
        // The admission gate counts in-flight work as `arrived - done`,
        // which only holds when arrivals are admitted one at a time; the
        // pre-pushed reference heap admits them all up front.
        assert!(
            self.cfg.admission.is_none(),
            "run_reference predates admission control; use run()"
        );
        let mut src = TraceSource::new(trace);
        self.run_core(&mut src, Some(trace.duration()), true, None)
    }

    /// Streaming sweep entry point (PR 7): arrivals are pulled lazily
    /// from `source`, each completed [`RequestRecord`] is handed to
    /// `sink` (in arrival order) and its slot freed, and records skip
    /// `token_times` retention entirely — memory stays
    /// O(instances + in-flight) instead of O(trace).
    /// `SimResult::records` comes back empty; everything else
    /// (`events_processed`, `sim_time`, …) is the same as a materialized
    /// run of the same arrivals — byte-identical, pinned by
    /// `tests/streaming.rs`.
    pub fn run_streamed(
        self,
        source: &mut dyn ArrivalSource,
        sink: &mut dyn FnMut(RequestRecord),
    ) -> SimResult {
        self.run_core(source, None, false, Some(sink))
    }

    fn run_core(
        mut self,
        source: &mut dyn ArrivalSource,
        known_duration: Option<Time>,
        prepush_arrivals: bool,
        mut sink: Option<&mut dyn FnMut(RequestRecord)>,
    ) -> SimResult {
        let streaming = sink.is_some();
        if !streaming {
            if let Some(hint) = source.len_hint() {
                self.slots.reserve(hint);
            }
        }
        // With a materialized trace the drain deadline is known up front;
        // a true stream pins it only once the source runs dry (below) —
        // equivalent, because arrivals always precede the deadline.
        if let Some(d) = known_duration {
            self.last_arrival = d;
        }
        self.exhausted = false;

        self.policy.init(&SimView(&self.instances));

        if prepush_arrivals {
            // Reference mode: drain the source up front; arrivals occupy
            // seqs 1..=N, exactly like the seed implementation, so ties
            // resolve identically.
            while let Some(r) = source.next_request() {
                if known_duration.is_none() {
                    self.last_arrival = r.arrival;
                }
                let idx = self.admit(r, streaming);
                let t = self.slot(idx).req.arrival;
                self.push(t, EventKind::Arrival { idx });
            }
            self.exhausted = true;
        }
        // Elastic membership: instances configured to join later start
        // outside the cluster, expressed as InstanceLost notifications
        // before any placement — the policy's pools then cover exactly
        // the live set. The scheduled changes enter the heap here, before
        // the first MonitorTick, so their sequence numbers (and therefore
        // all tie-breaks) are identical in cursor and reference modes.
        if let Some(live) = self.initial_live.take() {
            for (i, &is_live) in live.iter().enumerate() {
                if !is_live {
                    self.instances[i].life = Liveness::Dead;
                    self.notify_membership(MembershipEvent::InstanceLost {
                        id: InstanceId(i),
                    });
                }
            }
        }
        let schedule = std::mem::take(&mut self.membership_schedule);
        for (t, change) in schedule {
            self.push(t, EventKind::Membership(change));
        }
        // Fault schedule next: fixed position in the seq assignment, so
        // cursor and reference modes agree on every tie-break. An empty
        // plan pushes nothing — the fault plane is free when unused.
        let faults = std::mem::take(&mut self.fault_schedule);
        for (t, kind) in faults {
            self.push(t, EventKind::Fault(kind));
        }
        self.push(0.0, EventKind::MonitorTick);

        let known_deadline = known_duration.map(|d| d + self.cfg.drain_timeout);
        loop {
            // One-ahead peek: the streaming face of the sorted-slice
            // cursor. `pending` holds the next arrival until the merge
            // below admits it.
            if self.pending.is_none() && !self.exhausted {
                match source.next_request() {
                    Some(r) => {
                        if known_duration.is_none() {
                            self.last_arrival = r.arrival;
                        }
                        self.pending = Some(r);
                    }
                    None => self.exhausted = true,
                }
            }
            // The drain deadline only binds after the last arrival (the
            // static deadline of a materialized run can never fire while
            // arrivals remain, since every arrival precedes it), so a
            // true stream may leave it open until the source runs dry.
            let deadline = match known_deadline {
                Some(d) => d,
                None if self.exhausted => self.last_arrival + self.cfg.drain_timeout,
                None => f64::INFINITY,
            };
            // Merge the arrival calendar with the event heap. Time ties go
            // to the arrival: in the reference ordering every arrival's
            // seq precedes every runtime-scheduled event's seq.
            let next_arrival_t = self.pending.as_ref().map(|r| r.arrival);
            let next_heap_t = self.events.peek().map(|r| r.0.time);
            let take_arrival = match (next_arrival_t, next_heap_t) {
                (Some(a), Some(h)) => a <= h,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (None, None) => break,
            };

            if take_arrival {
                let raw = self.pending.take().unwrap();
                let idx = self.admit(raw, streaming);
                self.now = self.slot(idx).req.arrival.max(self.now);
                self.events_processed += 1;
                if self.now > deadline {
                    break;
                }
                // Overload admission (PR 8): fresh arrivals only. Heap
                // Arrival events (reference mode, restarts) never pass
                // through here, so already-admitted work is not re-gated.
                let admitted = match self.cfg.admission {
                    Some(ac) => {
                        // `arrived` already counts this request; in-flight
                        // is everyone else still in the system.
                        let inflight = self.arrived - self.done - 1;
                        ac.admits(self.slot(idx).req.class, inflight)
                    }
                    None => true,
                };
                if admitted {
                    self.on_arrival(idx);
                } else {
                    self.shed(idx, ShedReason::NoCapacity);
                }
            } else {
                let Reverse(ev) = self.events.pop().unwrap();
                debug_assert!(ev.time >= self.now - 1e-9, "time went backwards");
                self.now = ev.time.max(self.now);
                self.events_processed += 1;
                if self.now > deadline {
                    break;
                }
                match ev.kind {
                    EventKind::Arrival { idx } => self.on_arrival(idx),
                    EventKind::IterDone { inst, epoch } => self.on_iter_done(inst, epoch),
                    EventKind::TransferDone { req, from, to, kv } => {
                        self.on_transfer_done(req, from, to, kv)
                    }
                    EventKind::FabricPoll => self.poll_fabric(),
                    EventKind::MonitorTick => self.on_monitor_tick(),
                    EventKind::Membership(change) => self.on_membership_change(change),
                    EventKind::Fault(kind) => self.on_fault(kind),
                    EventKind::TransferRetry { req, from, to, kv, gen } => {
                        self.on_transfer_retry(req, from, to, kv, gen)
                    }
                }
            }
            // Streaming: completed front slots leave the window in
            // arrival order. O(1) amortized — each slot drains once.
            if let Some(s) = sink.as_mut() {
                while matches!(self.slots.front(), Some(slot) if slot.settled()) {
                    let slot = self.slots.pop_front().unwrap();
                    self.base += 1;
                    s(slot.rec);
                }
            }
            if self.exhausted && self.pending.is_none() && self.done == self.arrived {
                break;
            }
        }

        // Anything not finished at the deadline is a failure — an
        // *explicit* one: the chaos no-silent-loss contract requires every
        // failed record to carry its reason.
        for slot in self.slots.iter_mut() {
            if !matches!(
                slot.rec.state,
                RequestState::Finished | RequestState::Failed
            ) {
                slot.rec.state = RequestState::Failed;
                slot.rec.shed = Some(ShedReason::DeadlineExceeded);
            }
        }

        let total_iterations = self.instances.iter().map(|i| i.iterations).sum();
        let total_flips = self.policy.flip_count();

        // Flush the window, then any arrivals the deadline cut off before
        // admission — those still owe (failed) records, exactly like the
        // pre-window code that materialized every record up front.
        let mut fail_leftover = |raw: Request, idx: usize| {
            let req = Request {
                id: RequestId(idx as u64),
                ..raw
            };
            let mut rec = if streaming {
                RequestRecord::new_streaming(&req)
            } else {
                RequestRecord::new(&req)
            };
            rec.state = RequestState::Failed;
            rec.shed = Some(ShedReason::DeadlineExceeded);
            rec
        };
        let mut records = Vec::new();
        if !streaming {
            records.reserve(self.arrived);
        }
        let mut emit = |rec: RequestRecord| match sink.as_mut() {
            Some(s) => s(rec),
            None => records.push(rec),
        };
        for slot in std::mem::take(&mut self.slots) {
            emit(slot.rec);
        }
        let mut next_idx = self.arrived;
        if let Some(raw) = self.pending.take() {
            emit(fail_leftover(raw, next_idx));
            next_idx += 1;
        }
        while !self.exhausted {
            match source.next_request() {
                Some(raw) => {
                    emit(fail_leftover(raw, next_idx));
                    next_idx += 1;
                }
                None => self.exhausted = true,
            }
        }

        SimResult {
            records,
            timeline: self.timeline,
            sim_time: self.now,
            events_processed: self.events_processed,
            total_iterations,
            total_flips,
        }
    }

    // ------------------------------------------------------------- events

    fn on_arrival(&mut self, idx: usize) {
        let req = self.slot(idx).req;
        // Disjoint field borrows: the policy reads the instance table
        // (through the zero-cost SimView adapter) while being mutated
        // itself — no take()/put-back, no clone.
        let target = self.policy.place_prefill(
            self.now,
            &req,
            &Epoched(SimView(&self.instances), self.clock),
        );

        let inst = &self.instances[target.0];
        if !inst.life.in_cluster() {
            // The policy only names a departed slot when nothing
            // placeable remains (its last-ditch fallback). Fail the
            // request now instead of parking it on a corpse: a stranded
            // queue entry would sit out the whole drain timeout, and a
            // later rejoin of the slot must never execute work placed
            // while it was dead.
            self.shed(idx, ShedReason::NoCapacity);
            return;
        }
        if req.input_len as u64 + 1 > inst.cost.max_kv_tokens {
            // Cannot ever fit (paper: DistServe OOM on long context).
            self.shed(idx, ShedReason::Oversized);
            return;
        }
        {
            let rec = &mut self.slot_mut(idx).rec;
            rec.prefill_instance = Some(target);
            rec.state = RequestState::Prefilling;
        }
        // Priority enqueue (PR 8): strict-SLO classes jump ahead of lax
        // ones in the prefill queue; equal ranks keep FIFO order, so an
        // all-Standard trace reproduces the plain push_back schedule
        // bit for bit.
        self.instances[target.0].enqueue_prefill_ranked(
            req.id,
            req.input_len,
            req.class.priority_rank(),
        );
        self.touch();
        self.kick(target.0);
    }

    fn on_iter_done(&mut self, i: usize, epoch: u64) {
        if epoch != self.epochs[i] {
            // Completion from a previous life of the instance: it failed
            // after this event was scheduled, and everything the
            // iteration carried was already re-queued.
            return;
        }
        let plan = self.plans[i].take().expect("IterDone without plan");
        // Reuse one Produced buffer across iterations; it is moved out of
        // `self` while handlers below re-borrow `self` mutably.
        let mut produced = std::mem::take(&mut self.produced_buf);
        self.instances[i].finish_iteration_into(&plan, self.now, &mut produced);
        self.touch();
        let mut freed_memory = false;
        let now = self.now;
        for p in produced.drain(..) {
            match p {
                Produced::Token { id } => {
                    self.slot_mut(id.0 as usize).rec.push_token(now);
                }
                Produced::FinalToken { id, .. } => {
                    let rec = &mut self.slot_mut(id.0 as usize).rec;
                    rec.push_token(now);
                    rec.state = RequestState::Finished;
                    self.done += 1;
                    freed_memory = true;
                }
                Produced::PrefillDone { id, kv_tokens } => {
                    self.on_prefill_done(id.0 as usize, i, kv_tokens);
                }
            }
        }
        self.produced_buf = produced;
        if freed_memory {
            self.start_fetches(i);
        }
        self.kick(i);
        self.maybe_finish_drain(i);
    }

    /// First token is emitted at prefill completion (paper Fig. 6 step c);
    /// then the decode sub-request is placed (step d).
    fn on_prefill_done(&mut self, idx: usize, prefill_inst: usize, kv_tokens: u32) {
        let req = self.slot(idx).req;
        let now = self.now;
        // push_token sets `first_token` (the record was reset if this is
        // a post-restart prefill) and folds the gap/ttft incrementally.
        self.slot_mut(idx).rec.push_token(now);

        if req.output_len <= 1 {
            // Entire output was the first token: done, free the KV.
            self.instances[prefill_inst].migration_out_done(kv_tokens);
            {
                let rec = &mut self.slot_mut(idx).rec;
                rec.state = RequestState::Finished;
                rec.decode_instance = Some(InstanceId(prefill_inst));
            }
            self.done += 1;
            self.start_fetches(prefill_inst);
            self.kick(prefill_inst);
            return;
        }

        let target = self.policy.place_decode(
            self.now,
            &req,
            InstanceId(prefill_inst),
            &Epoched(SimView(&self.instances), self.clock),
        );
        self.slot_mut(idx).rec.decode_instance = Some(target);

        let remaining = req.output_len - 1;
        if target.0 == prefill_inst {
            // Local handoff — no KV migration (paper §5.3).
            self.instances[prefill_inst].adopt_local_decode(req.id, kv_tokens, remaining);
            self.touch();
            self.slot_mut(idx).rec.state = RequestState::DecodeQueued;
            self.kick(prefill_inst);
        } else {
            // Queue for the decode instance to fetch (q2).
            self.slot_mut(idx).rec.state = RequestState::Migrating;
            self.fetch_wait[target.0].push_back((idx, prefill_inst));
            self.start_fetches(target.0);
        }
    }

    /// Admit queued fetches whose target now has memory (q2 → transfer).
    fn start_fetches(&mut self, target: usize) {
        let mut admitted_any = false;
        while let Some(&(idx, from)) = self.fetch_wait[target].front() {
            let kv = self.slot(idx).req.input_len;
            if !self.instances[target].try_reserve_kv(kv as u64 + 1) {
                break;
            }
            self.fetch_wait[target].pop_front();
            let epochs = (self.epochs[from], self.epochs[target]);
            let rid = {
                let slot = self.slot_mut(idx);
                slot.fetch_epoch = epochs;
                // New admission supersedes any in-flight retry of an
                // older route for this request.
                slot.transfer_gen = slot.transfer_gen.wrapping_add(1);
                // The fabric now holds a reference until the transfer
                // starts or times out.
                slot.refs += 1;
                slot.req.id
            };
            let now = self.now;
            self.fabric.request(Transfer {
                req: rid,
                from: InstanceId(from),
                to: InstanceId(target),
                kv_tokens: kv,
                requested_at: now,
            });
            admitted_any = true;
        }
        if admitted_any {
            self.poll_fabric();
        }
    }

    fn poll_fabric(&mut self) {
        // The fabric owns its (shared) cost model — nothing cloned here.
        let (started, failed) = self.fabric.poll(self.now);
        for s in started {
            self.push(
                s.completes_at,
                EventKind::TransferDone {
                    req: s.transfer.req.0 as usize,
                    from: s.transfer.from.0,
                    to: s.transfer.to.0,
                    kv: s.transfer.kv_tokens,
                },
            );
        }
        for t in failed {
            self.on_transfer_timeout(t);
        }
        if self.fabric.timeout_wakeups {
            // Retry mode: wakeups also cover timeout deadlines and flap
            // ends (already filtered to strictly-future times).
            if let Some(t) = self.fabric.next_wakeup_after(self.now) {
                self.push(t, EventKind::FabricPoll);
            }
        } else if let Some(t) = self.fabric.next_wakeup() {
            if t > self.now {
                self.push(t, EventKind::FabricPoll);
            }
        }
    }

    /// Explicitly shed request `idx`: failed *with a recorded reason*.
    /// The chaos tier's no-silent-loss invariant keys off `shed`.
    fn shed(&mut self, idx: usize, why: ShedReason) {
        let rec = &mut self.slot_mut(idx).rec;
        if matches!(rec.state, RequestState::Finished | RequestState::Failed) {
            return;
        }
        rec.state = RequestState::Failed;
        rec.shed = Some(why);
        self.done += 1;
    }

    /// A KV transfer waited out `transfer_fail_timeout`. Without a retry
    /// policy this is the legacy fail-fast path (byte-identical event
    /// schedule, now with the reason recorded; the stuck reservations are
    /// deliberately left in place — that *is* the vLLM v0.7.3 buffer bug
    /// this knob models). With a retry policy the request climbs an
    /// escalation ladder: seeded-backoff retries on the same route, then
    /// one stateless decode re-placement, then an explicit shed that
    /// frees both endpoints.
    fn on_transfer_timeout(&mut self, t: Transfer) {
        let idx = t.req.0 as usize;
        // The fabric's reference on this slot dies with the timed-out
        // queue entry (a scheduled retry takes a fresh one below).
        self.slot_mut(idx).refs -= 1;
        if matches!(
            self.slot(idx).rec.state,
            RequestState::Finished | RequestState::Failed
        ) {
            return;
        }
        let Some(policy) = self.cfg.transfer_retry else {
            self.shed(idx, ShedReason::TransferTimeout);
            return;
        };
        let (from, to, kv) = (t.from.0, t.to.0, t.kv_tokens);
        let (attempt, gen) = {
            let slot = self.slot_mut(idx);
            slot.transfer_attempts = slot.transfer_attempts.saturating_add(1);
            (slot.transfer_attempts, slot.transfer_gen)
        };
        if attempt <= policy.max_retries {
            let delay = policy.backoff_delay(t.req.0, attempt);
            self.slot_mut(idx).refs += 1;
            self.push(
                self.now + delay,
                EventKind::TransferRetry {
                    req: idx,
                    from,
                    to,
                    kv,
                    gen,
                },
            );
            return;
        }
        // Retries exhausted: free the target's reservation (if that
        // endpoint still exists as admitted) — both escalation rungs
        // abandon this route.
        let (src_epoch, dst_epoch) = self.slot(idx).fetch_epoch;
        let to_ok =
            self.instances[to].life.in_cluster() && dst_epoch == self.epochs[to];
        if to_ok {
            self.instances[to].release_kv(kv as u64 + 1);
            self.start_fetches(to);
            self.kick(to);
        }
        if attempt == policy.max_retries + 1 {
            // Stateless re-placement: the KV still parks on the source;
            // only the decode placement redoes (paper §5.2 — any
            // instance can adopt the decode).
            self.replace_decode(idx, from);
            return;
        }
        // The re-placed route timed out too: shed explicitly, freeing the
        // source's parked KV so the failure doesn't leak capacity.
        let from_ok =
            self.instances[from].life.in_cluster() && src_epoch == self.epochs[from];
        if from_ok {
            self.instances[from].migration_out_done(kv);
            self.start_fetches(from);
            self.kick(from);
            self.maybe_finish_drain(from);
        }
        self.shed(idx, ShedReason::TransferTimeout);
    }

    /// A scheduled retry fires: if the request still waits on this exact
    /// route (generation match) and both endpoints still hold their
    /// admitted state, re-enqueue the transfer with a fresh timeout
    /// clock; otherwise fall back to the same recovery moves a stale
    /// `TransferDone` would make.
    fn on_transfer_retry(&mut self, idx: usize, from: usize, to: usize, kv: u32, gen: u32) {
        // The retry event's reference on this slot is consumed here.
        self.slot_mut(idx).refs -= 1;
        {
            let slot = self.slot(idx);
            if gen != slot.transfer_gen
                || slot.rec.state != RequestState::Migrating
                || slot.rec.decode_instance != Some(InstanceId(to))
            {
                return; // superseded: re-placed, restarted, finished, or shed
            }
        }
        let (src_epoch, dst_epoch) = self.slot(idx).fetch_epoch;
        let from_ok =
            self.instances[from].life.in_cluster() && src_epoch == self.epochs[from];
        let to_ok = self.instances[to].life.in_cluster() && dst_epoch == self.epochs[to];
        if !from_ok {
            // The parked KV died with the source: restart from scratch
            // (and release the target's reservation if it survives).
            if to_ok {
                self.instances[to].release_kv(kv as u64 + 1);
                self.start_fetches(to);
                self.kick(to);
            }
            self.restart_request(idx);
            return;
        }
        if !to_ok {
            self.replace_decode(idx, from);
            return;
        }
        let rid = self.slot(idx).req.id;
        self.slot_mut(idx).refs += 1;
        let now = self.now;
        self.fabric.request(Transfer {
            req: rid,
            from: InstanceId(from),
            to: InstanceId(to),
            kv_tokens: kv,
            requested_at: now,
        });
        self.poll_fabric();
    }

    /// Dispatch one injected fault (PR 6 fault plane).
    fn on_fault(&mut self, kind: FaultKind) {
        match kind {
            FaultKind::TransferFlap { link, window } => {
                self.fabric.flap_link(link, self.now + window);
                // Guaranteed wakeup at flap end, even without retry mode.
                self.push(self.now + window, EventKind::FabricPoll);
            }
            FaultKind::Straggler { inst, slowdown, window } => {
                self.slow_factor[inst] = slowdown.max(1.0);
                self.slow_until[inst] = self.now + window;
            }
            FaultKind::EngineStall { inst, duration } => {
                if duration > 0.0 {
                    self.stall_until[inst] = self.stall_until[inst].max(self.now + duration);
                    // End-of-stall wake marker (duration 0) re-kicks.
                    self.push(
                        self.stall_until[inst],
                        EventKind::Fault(FaultKind::EngineStall { inst, duration: 0.0 }),
                    );
                } else {
                    self.kick(inst);
                }
            }
            FaultKind::CrashRejoin { inst, downtime } => {
                self.on_instance_fail(inst);
                self.push(
                    self.now + downtime,
                    EventKind::Membership(MembershipChange::Join(inst)),
                );
            }
        }
    }

    /// Monitor-tick straggler detection: an in-cluster instance whose
    /// observed token interval is a `factor ×`-median outlier turns
    /// [`Liveness::Degraded`]; it recovers to Active once back under (or
    /// once it has no evidence at all). No membership event fires — the
    /// instance never leaves the cluster, the policy simply sees the
    /// state through `ClusterView::liveness` and deprioritizes it.
    fn detect_stragglers(&mut self, factor: f64) {
        let mut buf = std::mem::take(&mut self.interval_buf);
        buf.clear();
        for inst in &self.instances {
            if inst.life.in_cluster() {
                let v = inst.avg_token_interval();
                if v.is_finite() {
                    buf.push(v);
                }
            }
        }
        // Need a quorum of evidence: with < 3 samples an outlier *is* the
        // median and everything reads healthy.
        if buf.len() >= 3 {
            buf.sort_unstable_by(|a, b| a.total_cmp(b));
            let median = buf[buf.len() / 2];
            if median.is_finite() && median > 0.0 {
                for i in 0..self.instances.len() {
                    let v = self.instances[i].avg_token_interval();
                    match self.instances[i].life {
                        Liveness::Active => {
                            if v.is_finite() && v > factor * median {
                                self.instances[i].life = Liveness::Degraded;
                            }
                        }
                        Liveness::Degraded => {
                            if !v.is_finite() || v <= factor * median {
                                self.instances[i].life = Liveness::Active;
                            }
                        }
                        _ => {}
                    }
                }
            }
        }
        self.interval_buf = buf;
    }

    fn on_transfer_done(&mut self, idx: usize, from: usize, to: usize, kv: u32) {
        // The TransferDone event's reference on this slot is consumed.
        self.slot_mut(idx).refs -= 1;
        self.fabric.complete(kv);
        // Both endpoints must have lived through the whole copy: a
        // failure wipes parked KV and reservations, and a rejoined slot
        // is a *fresh* instance that never held this transfer's state —
        // liveness alone can't tell, the admission-time epochs can.
        let (src_epoch, dst_epoch) = self.slot(idx).fetch_epoch;
        let from_ok =
            self.instances[from].life.in_cluster() && src_epoch == self.epochs[from];
        let to_ok = self.instances[to].life.in_cluster() && dst_epoch == self.epochs[to];
        if !from_ok {
            // Source failed mid-copy: the KV never fully arrived. Release
            // the target's reservation (if it still exists) and restart
            // the request from scratch on live capacity.
            if to_ok {
                self.instances[to].release_kv(kv as u64 + 1);
                self.start_fetches(to);
                self.kick(to);
            }
            self.restart_request(idx);
            self.poll_fabric();
            return;
        }
        if !to_ok {
            // Target failed while the copy was in flight (its reservation
            // vanished with its state), but the source still parks the
            // KV: only the decode placement needs redoing.
            self.replace_decode(idx, from);
            self.poll_fabric();
            return;
        }
        let req = self.slot(idx).req;
        // Source frees its parked copy.
        self.instances[from].migration_out_done(kv);
        // Target's reservation was made at fetch admission; release the
        // reservation and enqueue the real decode task (same tokens).
        self.instances[to].release_kv(kv as u64 + 1);
        let ok = self.instances[to].try_reserve_kv(kv as u64);
        debug_assert!(ok, "reservation accounting broken");
        self.instances[to].enqueue_decode(req.id, kv, req.output_len - 1);
        self.touch();
        self.slot_mut(idx).rec.state = RequestState::DecodeQueued;
        // Source memory freed: it can admit fetches/prefill again.
        self.start_fetches(from);
        self.kick(from);
        self.kick(to);
        self.maybe_finish_drain(from);
        self.poll_fabric();
    }

    // -------------------------------------------------- membership (PR 3)

    /// Forward a membership event to the policy (pools re-seed + flip
    /// re-run happen inside the policy; the view already shows the new
    /// state and doubles as the profile source for joiners).
    fn notify_membership(&mut self, ev: MembershipEvent) {
        self.policy.on_membership(
            self.now,
            ev,
            &Epoched(SimView(&self.instances), self.clock),
            &SimView(&self.instances),
        );
    }

    fn on_membership_change(&mut self, change: MembershipChange) {
        match change {
            MembershipChange::Join(i) => {
                if self.instances[i].life == Liveness::Active {
                    return; // duplicate join
                }
                if self.instances[i].life == Liveness::Degraded {
                    // A degraded instance never left the cluster (no
                    // membership event fired), so a Join merely clears
                    // the degradation — notifying the policy of a join
                    // it never saw leave would double-count the slot.
                    self.instances[i].life = Liveness::Active;
                    return;
                }
                // A rejoin supersedes any armed restart-drill rejoin: a
                // later plain Drain must retire the slot for good, not
                // inherit a stale auto-rejoin.
                self.restart_after[i] = None;
                if self.instances[i].life == Liveness::Dead {
                    // A dead slot rejoins as a fresh process: stale
                    // monitor evidence (the idle gap across its downtime)
                    // must not read as a giant token interval. A
                    // Draining→Active rejoin keeps its state — it never
                    // stopped running.
                    self.instances[i].reset_monitor();
                }
                self.instances[i].life = Liveness::Active;
                self.notify_membership(MembershipEvent::InstanceJoined {
                    id: InstanceId(i),
                });
                self.kick(i);
            }
            MembershipChange::Drain(i) => self.begin_drain(i),
            MembershipChange::Restart { inst, downtime } => {
                if self.instances[inst].life != Liveness::Active {
                    return;
                }
                // Rolling-upgrade drill: an ordinary drain whose rejoin
                // is armed by drain *completion* (see maybe_finish_drain)
                // — a slow drain is waited out, never cancelled.
                self.restart_after[inst] = Some(downtime);
                self.begin_drain(inst);
            }
            MembershipChange::Fail(i) => self.on_instance_fail(i),
        }
    }

    fn begin_drain(&mut self, i: usize) {
        if self.instances[i].life != Liveness::Active {
            return;
        }
        self.instances[i].life = Liveness::Draining;
        self.notify_membership(MembershipEvent::InstanceDraining { id: InstanceId(i) });
        // An idle instance drains instantly.
        self.maybe_finish_drain(i);
    }

    /// Immediate instance loss: the policy drops it from its pools, and
    /// every request it held (or whose parked KV it held) is re-queued —
    /// prefill restarts from scratch, decode-in-waiting re-places. All
    /// recovery runs through the policy at `self.now`, so reference and
    /// cursor modes stay byte-identical.
    fn on_instance_fail(&mut self, i: usize) {
        if !self.instances[i].life.in_cluster() {
            return; // already gone
        }
        self.instances[i].life = Liveness::Dead;
        // Scheduling first: re-placements below must see the shrunk pool.
        self.notify_membership(MembershipEvent::InstanceLost { id: InstanceId(i) });
        // In-flight completions of the dead instance are now stale, and a
        // pending restart-drill rejoin is moot — the crash superseded it.
        self.epochs[i] += 1;
        self.plans[i] = None;
        self.restart_after[i] = None;
        // 1. Work resident on the dead instance: prefill progress and
        //    decode KV are lost — those requests restart from scratch.
        let mut lost: Vec<RequestId> = Vec::new();
        self.instances[i].drain_request_ids(&mut lost);
        self.touch();
        // 2. Requests elsewhere waiting to fetch KV *out of* the dead
        //    instance: their parked KV is gone — restart too.
        let mut lost_sources: Vec<usize> = Vec::new();
        for t in 0..self.fetch_wait.len() {
            self.fetch_wait[t].retain(|&(idx, from)| {
                if from == i {
                    lost_sources.push(idx);
                    false
                } else {
                    true
                }
            });
        }
        // 3. Requests queued to fetch *into* the dead instance still park
        //    their KV on a live source: only the decode placement redoes.
        let waiting: Vec<(usize, usize)> = self.fetch_wait[i].drain(..).collect();
        for id in lost {
            self.restart_request(id.0 as usize);
        }
        for idx in lost_sources {
            self.restart_request(idx);
        }
        for (idx, from) in waiting {
            self.replace_decode(idx, from);
        }
    }

    /// A draining instance with nothing left — no queued/running work, no
    /// parked or reserved KV, no inbound fetches — leaves the cluster.
    /// If the drain was a `Restart`, the rejoin arms here, off the actual
    /// completion time.
    fn maybe_finish_drain(&mut self, i: usize) {
        if self.instances[i].life == Liveness::Draining
            && self.instances[i].is_idle()
            && self.instances[i].kv_used() == 0
            && self.fetch_wait[i].is_empty()
        {
            self.instances[i].life = Liveness::Dead;
            if let Some(downtime) = self.restart_after[i].take() {
                self.push(
                    self.now + downtime,
                    EventKind::Membership(MembershipChange::Join(i)),
                );
            }
        }
    }

    /// Re-queue a request from scratch (its prefill progress and/or KV
    /// was lost with a failed instance). Token bookkeeping resets so a
    /// finished record still holds exactly `output_len` token times.
    fn restart_request(&mut self, idx: usize) {
        {
            let slot = self.slot_mut(idx);
            if matches!(
                slot.rec.state,
                RequestState::Finished | RequestState::Failed
            ) {
                return;
            }
            slot.rec.reset_tokens();
            slot.rec.prefill_instance = None;
            slot.rec.decode_instance = None;
            slot.rec.state = RequestState::PrefillQueued;
            // Any in-flight transfer retry for the old life is now stale,
            // and the fresh life starts its escalation ladder from the
            // bottom.
            slot.transfer_gen = slot.transfer_gen.wrapping_add(1);
            slot.transfer_attempts = 0;
        }
        self.on_arrival(idx);
    }

    /// Re-place the decode phase of request `idx`, whose first token is
    /// out and whose KV sits parked on live instance `from` (the decode
    /// target it was originally bound for is gone).
    fn replace_decode(&mut self, idx: usize, from: usize) {
        if !self.instances[from].life.in_cluster() {
            // Source died too (correlated failure): full restart.
            self.restart_request(idx);
            return;
        }
        // The old route (and any retry scheduled against it) is dead.
        let req = {
            let slot = self.slot_mut(idx);
            slot.transfer_gen = slot.transfer_gen.wrapping_add(1);
            slot.req
        };
        let target = self.policy.place_decode(
            self.now,
            &req,
            InstanceId(from),
            &Epoched(SimView(&self.instances), self.clock),
        );
        self.slot_mut(idx).rec.decode_instance = Some(target);
        if target.0 == from {
            // The KV is parked right here — local adoption.
            self.instances[from].adopt_local_decode(req.id, req.input_len, req.output_len - 1);
            self.touch();
            self.slot_mut(idx).rec.state = RequestState::DecodeQueued;
            self.kick(from);
        } else {
            self.slot_mut(idx).rec.state = RequestState::Migrating;
            self.fetch_wait[target.0].push_back((idx, from));
            self.start_fetches(target.0);
        }
    }

    fn on_monitor_tick(&mut self) {
        // Straggler detection first: the policy's tick should see the
        // fresh liveness picture (paper Fig. 5 VI — the monitor feeds
        // the scheduler, not the other way round).
        if let Some(factor) = self.cfg.straggler_factor {
            self.detect_stragglers(factor);
        }
        self.policy
            .on_tick(self.now, &Epoched(SimView(&self.instances), self.clock));

        if self.cfg.record_timeline {
            let pools = self.policy.pool_sizes();
            self.timeline.push(InstantSnapshot {
                time: self.now,
                per_instance: self
                    .instances
                    .iter()
                    .map(|i| (i.prefill_req_count(), i.decode_req_count(), i.running_tokens()))
                    .collect(),
                pools,
                live: self
                    .instances
                    .iter()
                    .filter(|i| i.life.in_cluster())
                    .count(),
            });
        }
        // Policy moves may have made work schedulable; kick everyone idle.
        // The sweep also settles drains that finished between events.
        for i in 0..self.instances.len() {
            self.kick(i);
            self.maybe_finish_drain(i);
        }
        // Re-arm while any admitted request is unfinished *or* more
        // arrivals are still due — the streaming equivalent of the old
        // `done < records.len()` (un-arrived requests can't be done).
        if self.done < self.arrived || !self.exhausted || self.pending.is_some() {
            self.push(self.now + self.cfg.monitor_period, EventKind::MonitorTick);
        }
    }

    /// Start the next iteration on instance `i` if it is idle and has work.
    fn kick(&mut self, i: usize) {
        if self.instances[i].busy || !self.instances[i].life.in_cluster() {
            return;
        }
        if self.now < self.stall_until[i] {
            // Stalled engine (`EngineStall`): frozen until the stall
            // clears — the end-of-stall wake marker re-kicks it.
            return;
        }
        if let Some(plan) = self.instances[i].plan_iteration() {
            // A straggler window dilates wall-clock duration (the planned
            // work is unchanged — the instance is just slow), which the
            // monitor observes as token-interval outliers.
            let mut d = plan.duration;
            if self.now < self.slow_until[i] {
                d *= self.slow_factor[i];
            }
            let t = self.now + d;
            self.plans[i] = Some(plan);
            self.push(
                t,
                EventKind::IterDone {
                    inst: i,
                    epoch: self.epochs[i],
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::policy::tests_support::{AllToOne, StaticSplit};
    use super::*;
    use crate::trace::synthetic::smoke;

    fn small_cost() -> CostModel {
        CostModel::h800_llama8b()
    }

    #[test]
    fn single_instance_completes_all() {
        let trace = smoke(50, 1).generate(3);
        let cl = Cluster::homogeneous(
            1,
            small_cost(),
            Box::new(AllToOne),
            SimConfig::default(),
        );
        let res = cl.run(&trace);
        assert_eq!(res.records.len(), trace.len());
        assert!(res.records.iter().all(|r| r.finished()), "all finish");
        // Tokens counted: every record has exactly output_len tokens.
        for (rec, req) in res.records.iter().zip(&trace.requests) {
            assert_eq!(rec.token_times.len(), req.output_len as usize);
            assert!(rec.ttft().unwrap() > 0.0);
        }
    }

    #[test]
    fn static_split_transfers_kv() {
        let trace = smoke(50, 1).generate(4);
        let cl = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
            SimConfig::default(),
        );
        let res = cl.run(&trace);
        assert!(res.records.iter().all(|r| r.finished()));
        // Decode ran on instance 1 (except single-token outputs that
        // finish on the prefill instance).
        for (rec, req) in res.records.iter().zip(&trace.requests) {
            assert_eq!(rec.prefill_instance, Some(InstanceId(0)));
            if req.output_len > 1 {
                assert_eq!(rec.decode_instance, Some(InstanceId(1)));
            }
        }
    }

    #[test]
    fn deterministic_runs() {
        let trace = smoke(100, 2).generate(5);
        let run = || {
            Cluster::homogeneous(
                2,
                small_cost(),
                Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
                SimConfig::default(),
            )
            .run(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.events_processed, b.events_processed);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.token_times, y.token_times);
        }
    }

    /// The calendar-arrival (cursor) loop must reproduce the legacy
    /// pre-pushed-heap schedule *exactly*: same event count, same
    /// per-request token timestamps — across seeds and policies.
    #[test]
    fn calendar_arrivals_match_heap_reference() {
        use crate::util::rng::Rng;
        for seed in 3..=10u64 {
            // Vary the workload shape with the seed so the equivalence is
            // exercised on different burst structures.
            let mut rng = Rng::new(seed);
            let n = 60 + rng.index(80);
            let trace = smoke(n, 1 + rng.index(3)).generate(seed);
            fn mk(kind: usize) -> Box<dyn Policy> {
                if kind == 0 {
                    Box::new(AllToOne)
                } else {
                    Box::new(StaticSplit { prefill: vec![0], decode: vec![1] })
                }
            }
            for policy_kind in 0..2 {
                let cursor =
                    Cluster::homogeneous(2, small_cost(), mk(policy_kind), SimConfig::default())
                        .run(&trace);
                let heap =
                    Cluster::homogeneous(2, small_cost(), mk(policy_kind), SimConfig::default())
                        .run_reference(&trace);
                assert_eq!(
                    cursor.events_processed, heap.events_processed,
                    "seed {seed} policy {policy_kind}: event counts diverge"
                );
                assert_eq!(cursor.total_iterations, heap.total_iterations);
                for (x, y) in cursor.records.iter().zip(&heap.records) {
                    assert_eq!(
                        x.token_times, y.token_times,
                        "seed {seed} policy {policy_kind} req {}: schedules diverge",
                        x.id
                    );
                    assert_eq!(x.state, y.state);
                }
            }
        }
    }

    /// A non-binding admission gate must not perturb the schedule: the
    /// gate only decides admit/shed, it never reorders events.
    #[test]
    fn slack_admission_gate_is_transparent() {
        let trace = smoke(60, 2).generate(7);
        let base = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(AllToOne),
            SimConfig::default(),
        )
        .run(&trace);
        let gated = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(AllToOne),
            SimConfig {
                admission: Some(AdmissionControl::new(10_000)),
                ..SimConfig::default()
            },
        )
        .run(&trace);
        assert_eq!(base.events_processed, gated.events_processed);
        for (x, y) in base.records.iter().zip(&gated.records) {
            assert_eq!(x.token_times, y.token_times);
            assert_eq!(x.state, y.state);
        }
    }

    /// Deterministic overload burst: 12 simultaneous arrivals against an
    /// in-flight cap of 8 (batch headroom 4, standard 6). The gate must
    /// shed exactly the arrivals whose class cap is full — batch first —
    /// and every shed must carry an explicit reason (no silent loss).
    #[test]
    fn admission_sheds_batch_first_under_burst() {
        let classes = [
            SloClass::Batch,
            SloClass::Batch,
            SloClass::Batch,
            SloClass::Batch,
            SloClass::Batch,
            SloClass::Standard,
            SloClass::Standard,
            SloClass::Standard,
            SloClass::Interactive,
            SloClass::Interactive,
            SloClass::Interactive,
            SloClass::Interactive,
        ];
        let burst = |i: usize, class: SloClass| {
            Request::new(i as u64, 0.0, 64, 4).with_class(class)
        };
        let trace = Trace::new(
            "burst",
            classes
                .iter()
                .enumerate()
                .map(|(i, &c)| burst(i, c))
                .collect(),
        );
        let run = |class_aware: bool| {
            let mut ac = AdmissionControl::new(8);
            ac.class_aware = class_aware;
            Cluster::homogeneous(
                1,
                small_cost(),
                Box::new(AllToOne),
                SimConfig {
                    admission: Some(ac),
                    ..SimConfig::default()
                },
            )
            .run(&trace)
        };

        let aware = run(true);
        let shed_idx: Vec<usize> = aware
            .records
            .iter()
            .enumerate()
            .filter(|(_, r)| r.state == RequestState::Failed)
            .map(|(i, _)| i)
            .collect();
        // Walk the burst: batch admits while <4 in flight (indices 0–3),
        // standard while <6 (5, 6), interactive while <8 (8, 9).
        assert_eq!(shed_idx, vec![4, 7, 10, 11]);
        for rec in &aware.records {
            if rec.state == RequestState::Failed {
                assert_eq!(rec.shed, Some(ShedReason::NoCapacity));
            } else {
                assert!(rec.finished());
            }
        }

        // Class-blind baseline: one cap of 8 for everyone — the first 8
        // arrivals (all batch + standard) squeeze out every interactive.
        let blind = run(false);
        let blind_interactive_shed = blind
            .records
            .iter()
            .filter(|r| {
                r.class == SloClass::Interactive && r.state == RequestState::Failed
            })
            .count();
        let aware_interactive_shed = aware
            .records
            .iter()
            .filter(|r| {
                r.class == SloClass::Interactive && r.state == RequestState::Failed
            })
            .count();
        assert_eq!(blind_interactive_shed, 4);
        assert_eq!(aware_interactive_shed, 2);
    }

    /// Regression for the latent `partial_cmp().unwrap()` panic: events
    /// must stay totally ordered even for NaN / identical timestamps.
    #[test]
    fn event_order_is_total_even_for_degenerate_times() {
        let e = |time: f64, seq: u64| Event {
            time,
            seq,
            kind: EventKind::FabricPoll,
        };
        use std::cmp::Ordering;
        // Identical time: seq breaks the tie.
        assert_eq!(e(1.0, 1).cmp(&e(1.0, 2)), Ordering::Less);
        // NaN orders after every real number under total_cmp — no panic.
        assert_eq!(e(f64::NAN, 1).cmp(&e(1e300, 2)), Ordering::Greater);
        assert_eq!(e(f64::NAN, 1).cmp(&e(f64::NAN, 1)), Ordering::Equal);
        // -0.0 < +0.0 under total_cmp; ordering stays consistent.
        assert_eq!(e(-0.0, 5).cmp(&e(0.0, 1)), Ordering::Less);
    }

    /// A degenerate burst — every request arriving at the same instant
    /// (0-length burst window) — must order deterministically and finish.
    #[test]
    fn identical_timestamp_burst_orders_deterministically() {
        let reqs: Vec<Request> = (0..40)
            .map(|i| Request::new(i, if i == 0 { 0.0 } else { 5.0 }, 64, 4))
            .collect();
        let trace = Trace::new("burst", reqs);
        let run = || {
            Cluster::homogeneous(
                2,
                small_cost(),
                Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
                SimConfig::default(),
            )
            .run(&trace)
        };
        let a = run();
        let b = run();
        assert!(a.records.iter().all(|r| r.finished()), "burst completes");
        assert_eq!(a.events_processed, b.events_processed);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.token_times, y.token_times);
        }
        // The cursor loop also matches the heap reference on ties.
        let c = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
            SimConfig::default(),
        )
        .run_reference(&trace);
        assert_eq!(a.events_processed, c.events_processed);
        for (x, y) in a.records.iter().zip(&c.records) {
            assert_eq!(x.token_times, y.token_times);
        }
    }

    #[test]
    fn token_times_monotone_per_request() {
        let trace = smoke(80, 1).generate(6);
        let res = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
            SimConfig::default(),
        )
        .run(&trace);
        for rec in &res.records {
            assert!(rec
                .token_times
                .windows(2)
                .all(|w| w[1] >= w[0] - 1e-12));
            // First recorded token == first_token field.
            assert_eq!(rec.token_times.first().copied(), rec.first_token);
        }
    }

    #[test]
    fn oversized_request_fails_cleanly() {
        let mut trace = smoke(5, 1).generate(7);
        trace.requests[0].input_len = 10_000_000; // > max_kv_tokens
        let res = Cluster::homogeneous(
            1,
            small_cost(),
            Box::new(AllToOne),
            SimConfig::default(),
        )
        .run(&trace);
        let failed: Vec<_> = res
            .records
            .iter()
            .filter(|r| r.state == RequestState::Failed)
            .collect();
        assert_eq!(failed.len(), 1);
        assert!(res.records.iter().filter(|r| r.finished()).count() == 4);
    }

    #[test]
    fn timeline_recorded_when_enabled() {
        let trace = smoke(50, 1).generate(8);
        let cfg = SimConfig {
            record_timeline: true,
            ..Default::default()
        };
        let res = Cluster::homogeneous(2, small_cost(), Box::new(AllToOne), cfg).run(&trace);
        assert!(!res.timeline.is_empty());
        let snap = &res.timeline[0];
        assert_eq!(snap.per_instance.len(), 2);
    }

    #[test]
    fn transfer_buffer_timeout_fails_requests() {
        // Tiny shared buffer + short timeout: transfers of large KV fail.
        let mut trace = smoke(20, 1).generate(9);
        for r in &mut trace.requests {
            r.input_len = 5_000;
            r.output_len = 8;
        }
        let cfg = SimConfig {
            transfer_buffer_tokens: Some(1_000), // < any single KV
            transfer_fail_timeout: Some(5.0),
            ..Default::default()
        };
        let res = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
            cfg,
        )
        .run(&trace);
        assert!(
            res.records.iter().any(|r| r.state == RequestState::Failed),
            "buffer-capped transfers should fail"
        );
        // PR 6: even the legacy fail-fast path records *why* (no silent
        // loss — the timeline sweep or the timeout names every failure).
        for r in res.records.iter().filter(|r| r.state == RequestState::Failed) {
            assert!(
                matches!(
                    r.shed,
                    Some(ShedReason::TransferTimeout) | Some(ShedReason::DeadlineExceeded)
                ),
                "failed request {} has no shed reason",
                r.id
            );
        }
    }

    #[test]
    fn transfer_retry_escalates_and_never_silently_loses() {
        // Permanent buffer starvation: every migration times out. With a
        // retry policy the request climbs the full ladder — backoff
        // retries, one stateless re-placement, then an explicit shed.
        let mut trace = smoke(20, 1).generate(9);
        for r in &mut trace.requests {
            r.input_len = 5_000;
            r.output_len = 8;
        }
        let cfg = SimConfig {
            transfer_buffer_tokens: Some(1_000), // < any single KV
            transfer_fail_timeout: Some(5.0),
            transfer_retry: Some(TransferRetryPolicy::default()),
            ..Default::default()
        };
        let run = |cfg: SimConfig| {
            Cluster::homogeneous(
                2,
                small_cost(),
                Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
                cfg,
            )
            .run(&trace)
        };
        let res = run(cfg.clone());
        for r in &res.records {
            assert!(r.finished() || r.shed.is_some(), "req {} silently lost", r.id);
        }
        assert!(
            res.records.iter().any(|r| r.shed == Some(ShedReason::TransferTimeout)),
            "the exhausted ladder must shed explicitly"
        );
        // Seeded backoff: the retry schedule replays bit-for-bit.
        let res2 = run(cfg);
        assert_eq!(res.events_processed, res2.events_processed);
        for (x, y) in res.records.iter().zip(&res2.records) {
            assert_eq!(x.token_times, y.token_times);
            assert_eq!(x.shed, y.shed);
        }
    }

    #[test]
    fn engine_stall_freezes_then_recovers_without_loss() {
        let trace = smoke(60, 1).generate(14);
        let d = trace.duration();
        let mut cl = Cluster::homogeneous(
            2,
            small_cost(),
            Box::new(StaticSplit { prefill: vec![0], decode: vec![1] }),
            SimConfig::default(),
        );
        cl.schedule_fault(0.3 * d, FaultKind::EngineStall { inst: 0, duration: 5.0 });
        let res = cl.run(&trace);
        assert!(
            res.records.iter().all(|r| r.finished()),
            "a stall delays work, it must not lose any"
        );
    }

    #[test]
    fn seeded_fault_plan_is_deterministic_and_never_silently_loses() {
        use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
        let trace = smoke(120, 2).generate(15);
        let plan = FaultPlan::seeded(99, 4, trace.duration(), 1.5);
        assert!(!plan.is_empty());
        let run = || {
            let policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, 4), 4);
            let cfg = SimConfig {
                transfer_retry: Some(TransferRetryPolicy::default()),
                straggler_factor: Some(3.0),
                ..Default::default()
            };
            let mut cl = Cluster::homogeneous(4, small_cost(), Box::new(policy), cfg);
            cl.schedule_fault_plan(&plan);
            cl.run(&trace)
        };
        let a = run();
        let b = run();
        assert_eq!(a.events_processed, b.events_processed);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.token_times, y.token_times, "req {}: chaos diverges", x.id);
            assert_eq!(x.shed, y.shed);
            assert!(x.finished() || x.shed.is_some(), "req {} silently lost", x.id);
        }
    }

    fn arrow_cluster(n_total: usize, n_live: usize) -> Cluster {
        use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
        let policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, n_live), n_total);
        Cluster::homogeneous(n_total, small_cost(), Box::new(policy), SimConfig::default())
    }

    #[test]
    fn failed_instance_work_is_requeued_and_finishes() {
        let trace = smoke(120, 2).generate(11);
        let t_fail = trace.duration() * 0.4;
        let mut cl = arrow_cluster(4, 4);
        // Kill the last instance (initial decode pool) mid-trace.
        cl.schedule_membership(t_fail, MembershipChange::Fail(3));
        let res = cl.run(&trace);
        assert!(
            res.records.iter().all(|r| r.finished()),
            "all requests must finish after the failure (re-queued work completes)"
        );
        // The dead instance never received post-failure work: anything
        // recorded against it completed before the failure (restarted
        // requests overwrite their placement fields).
        for rec in &res.records {
            if rec.decode_instance == Some(InstanceId(3)) {
                let last = *rec.token_times.last().unwrap();
                assert!(last <= t_fail + 1e-9, "decode on dead instance at {last}");
            }
            if rec.prefill_instance == Some(InstanceId(3)) {
                let ft = rec.first_token.unwrap();
                assert!(ft <= t_fail + 1e-9, "prefill on dead instance at {ft}");
            }
            assert_eq!(rec.token_times.len(), rec.output_len as usize);
        }
    }

    #[test]
    fn drained_instance_gets_no_new_work_and_leaves() {
        let trace = smoke(100, 2).generate(12);
        let t_drain = trace.duration() * 0.3;
        let mut cl = arrow_cluster(4, 4);
        cl.schedule_membership(t_drain, MembershipChange::Drain(0));
        let res = cl.run(&trace);
        assert!(res.records.iter().all(|r| r.finished()), "drain loses no work");
        // Prefill placement happens at arrival; no failures occur, so a
        // request prefilled on the draining instance must have arrived
        // before the drain began.
        for rec in res
            .records
            .iter()
            .filter(|r| r.prefill_instance == Some(InstanceId(0)))
        {
            assert!(
                rec.arrival <= t_drain + 1e-9,
                "req {} placed on draining instance (arrived {})",
                rec.id,
                rec.arrival
            );
        }
    }

    #[test]
    fn late_joiner_takes_work() {
        let trace = smoke(150, 2).generate(13);
        let t_join = trace.duration() * 0.2;
        let mut cl = arrow_cluster(3, 2);
        cl.set_initial_live(vec![true, true, false]);
        cl.schedule_membership(t_join, MembershipChange::Join(2));
        let res = cl.run(&trace);
        assert!(res.records.iter().all(|r| r.finished()));
        let used_joiner = res.records.iter().any(|r| {
            r.prefill_instance == Some(InstanceId(2)) || r.decode_instance == Some(InstanceId(2))
        });
        assert!(used_joiner, "the joined instance must receive work");
        // And nothing touched it before it joined.
        for rec in &res.records {
            if rec.prefill_instance == Some(InstanceId(2)) {
                assert!(rec.first_token.unwrap() >= t_join - 1e-9);
            }
        }
    }

    /// Cursor and heap-reference modes must stay byte-identical under a
    /// full membership schedule (join + drain + failure).
    #[test]
    fn membership_schedule_matches_heap_reference() {
        use crate::coordinator::arrow::{ArrowConfig, ArrowPolicy};
        for seed in 3..=6u64 {
            let trace = smoke(80, 2).generate(seed);
            let d = trace.duration();
            let mk = || {
                let policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, 4), 5);
                let mut cl = Cluster::homogeneous(
                    5,
                    small_cost(),
                    Box::new(policy),
                    SimConfig::default(),
                );
                cl.set_initial_live(vec![true, true, true, true, false]);
                cl.schedule_membership(0.3 * d, MembershipChange::Join(4));
                cl.schedule_membership(0.5 * d, MembershipChange::Drain(0));
                cl.schedule_membership(0.7 * d, MembershipChange::Fail(3));
                cl
            };
            let cursor = mk().run(&trace);
            let heap = mk().run_reference(&trace);
            assert_eq!(
                cursor.events_processed, heap.events_processed,
                "seed {seed}: event counts diverge under membership"
            );
            assert_eq!(cursor.total_iterations, heap.total_iterations);
            for (x, y) in cursor.records.iter().zip(&heap.records) {
                assert_eq!(
                    x.token_times, y.token_times,
                    "seed {seed} req {}: membership schedules diverge",
                    x.id
                );
                assert_eq!(x.state, y.state);
                assert_eq!(x.prefill_instance, y.prefill_instance);
                assert_eq!(x.decode_instance, y.decode_instance);
            }
        }
    }

    #[test]
    fn drain_timeout_bounds_runtime() {
        // A pathological policy that sends everything to instance 0 while
        // instance 0 has tiny memory => some requests can never run.
        let mut cost = small_cost();
        cost.max_kv_tokens = 10; // nothing fits
        let trace = smoke(10, 1).generate(10);
        let cfg = SimConfig {
            drain_timeout: 30.0,
            ..Default::default()
        };
        let res = Cluster::homogeneous(1, cost, Box::new(AllToOne), cfg).run(&trace);
        // All marked failed, simulation terminated.
        assert!(res.records.iter().all(|r| r.state == RequestState::Failed));
    }
}
