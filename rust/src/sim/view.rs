//! The simulator's [`ClusterView`] adapter: a zero-cost borrow of the
//! `SimInstance` table.
//!
//! `SimView` is a transparent newtype over `&[SimInstance]` — every
//! accessor forwards to the instance's own allocation-free query
//! (`prefill_queue_iter`, `running_tokens`, …), so routing policy calls
//! through the view adds one virtual dispatch and nothing else. The
//! PR-1 hot-path invariants (ROADMAP "Performance architecture": no
//! per-event allocation, streamed queue views) are preserved verbatim.
//!
//! `SimView` also implements [`ProfileSource`]: startup profiling in the
//! simulator queries each instance's cost model, standing in for the
//! real system's timed probe prompts (paper §5.3).

use crate::coordinator::predictor::TtftPredictor;
use crate::engine::SimInstance;
use crate::sched::{ClusterView, Liveness, PrefillQueueMoments, ProfileSource};

/// Zero-cost [`ClusterView`] over the simulator's instance table.
pub struct SimView<'a>(pub &'a [SimInstance]);

impl ClusterView for SimView<'_> {
    fn n_instances(&self) -> usize {
        self.0.len()
    }

    fn for_each_queued_prefill(&self, inst: usize, f: &mut dyn FnMut(u32, u32)) {
        for (input_len, remaining) in self.0[inst].prefill_queue_iter() {
            f(input_len, remaining);
        }
    }

    fn prefill_queue_moments(&self, inst: usize) -> PrefillQueueMoments {
        // O(1): the instance maintains the aggregates at event time
        // (PR 4); the trait's walk-derived default must never run here.
        self.0[inst].prefill_queue_moments()
    }

    fn prefill_chunk_tokens(&self, inst: usize) -> u32 {
        self.0[inst].chunk_tokens
    }

    // change_epoch: deliberately the default (EPOCH_UNKNOWN). A bare
    // borrow of the instance table can't prove change history; the event
    // loop wraps SimView in `sched::Epoched` with its mutation clock to
    // unlock the O(1) no-change fast path.

    fn running_tokens(&self, inst: usize) -> u64 {
        self.0[inst].running_tokens()
    }

    fn max_kv_tokens(&self, inst: usize) -> u64 {
        self.0[inst].cost.max_kv_tokens
    }

    fn avg_token_interval(&self, inst: usize) -> f64 {
        self.0[inst].avg_token_interval()
    }

    fn has_prefill_work(&self, inst: usize) -> bool {
        self.0[inst].has_prefill_work()
    }

    fn has_decode_work(&self, inst: usize) -> bool {
        self.0[inst].has_decode_work()
    }

    fn liveness(&self, inst: usize) -> Liveness {
        self.0[inst].life
    }
}

impl ProfileSource for SimView<'_> {
    fn n_instances(&self) -> usize {
        self.0.len()
    }

    fn fit_predictor(&self, i: usize) -> TtftPredictor {
        TtftPredictor::profile(&self.0[i].cost, self.0[i].chunk_tokens)
    }

    fn max_running_tokens(&self, i: usize, tpot_slo: f64) -> u64 {
        self.0[i].cost.max_running_tokens(tpot_slo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::request::{InstanceId, RequestId};

    fn inst(i: usize) -> SimInstance {
        SimInstance::new(InstanceId(i), CostModel::h800_llama8b())
    }

    #[test]
    fn view_mirrors_instance_state() {
        let mut insts = vec![inst(0), inst(1)];
        insts[0].enqueue_prefill(RequestId(1), 4000);
        insts[0].enqueue_prefill(RequestId(2), 600);
        assert!(insts[1].try_reserve_kv(500));
        insts[1].enqueue_decode(RequestId(3), 500, 10);

        let v = SimView(&insts);
        assert_eq!(ClusterView::n_instances(&v), 2);
        assert_eq!(v.queued_prefill_tokens(0), 4600);
        assert_eq!(v.queued_prefill_tokens(1), 0);
        assert_eq!(v.running_tokens(1), 500);
        assert!(v.has_prefill_work(0) && !v.has_decode_work(0));
        assert!(!v.has_prefill_work(1) && v.has_decode_work(1));
        assert!(!v.is_idle(0) && !v.is_idle(1));
        assert!(v.avg_token_interval(0).is_nan(), "no tokens yet");
        assert_eq!(v.max_kv_tokens(0), insts[0].cost.max_kv_tokens);

        // Queue visit order matches the instance's own iterator.
        let mut seen = Vec::new();
        v.for_each_queued_prefill(0, &mut |l, r| seen.push((l, r)));
        let direct: Vec<(u32, u32)> = insts[0].prefill_queue_iter().collect();
        assert_eq!(seen, direct);

        // The O(1) moment override equals the walk-derived oracle, and
        // the chunk the moments price with is the instance's own.
        assert_eq!(
            v.prefill_queue_moments(0),
            PrefillQueueMoments::derive_walk(&v, 0)
        );
        assert_eq!(v.prefill_chunk_tokens(0), insts[0].chunk_tokens);
        assert_eq!(v.change_epoch(), crate::sched::EPOCH_UNKNOWN);
    }

    #[test]
    fn profile_source_uses_each_instances_cost_model() {
        let base = CostModel::h800_llama8b();
        let fast = base.with_tensor_parallel(2, 0.9);
        let insts = vec![
            SimInstance::new(InstanceId(0), fast.clone()),
            SimInstance::new(InstanceId(1), base.clone()),
        ];
        let v = SimView(&insts);
        let t_fast = v.fit_predictor(0).prefill_seconds(20_000);
        let t_slow = v.fit_predictor(1).prefill_seconds(20_000);
        assert!(t_fast < t_slow, "fast instance must profile faster");
        assert_eq!(v.max_running_tokens(1, 0.1), base.max_running_tokens(0.1));
    }
}
