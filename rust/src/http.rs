//! Minimal HTTP/1.1 server substrate (no axum/hyper offline).
//!
//! Thread-per-connection, request-line + headers + Content-Length body
//! parsing, keep-alive off (Connection: close) for simplicity. Enough for
//! the OpenAI-style JSON frontend in `server/`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn json(status: u16, body: &str) -> Self {
        HttpResponse {
            status,
            content_type: "application/json".into(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        HttpResponse {
            status,
            content_type: "text/plain".into(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn not_found() -> Self {
        Self::json(404, "{\"error\":\"not found\"}")
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Hard limits on inbound requests (PR 6 hardening): the server binds
/// 0.0.0.0, so one socket must never be able to balloon memory with an
/// unbounded header section or a huge declared body.
pub const MAX_HEADERS: usize = 128;
pub const MAX_HEADER_LINE_BYTES: u64 = 8 * 1024;
pub const MAX_BODY_BYTES: usize = 64 << 20;

/// Why a request could not be parsed — `serve` maps each variant to a
/// status instead of the blanket 400 (and, before PR 6, the silent
/// truncation) it used to answer with.
#[derive(Debug)]
pub enum ParseError {
    /// Socket error or malformed request line (400).
    Bad(std::io::Error),
    /// Header section exceeds `MAX_HEADERS` / `MAX_HEADER_LINE_BYTES` (400).
    HeadersTooLarge,
    /// Declared `Content-Length` exceeds `MAX_BODY_BYTES` (413).
    BodyTooLarge,
}

impl From<std::io::Error> for ParseError {
    fn from(e: std::io::Error) -> Self {
        ParseError::Bad(e)
    }
}

/// One `\n`-terminated line, refusing lines past the cap. None = EOF.
fn read_line_capped(reader: &mut BufReader<TcpStream>) -> Result<Option<String>, ParseError> {
    let mut buf = Vec::new();
    let n = reader
        .by_ref()
        .take(MAX_HEADER_LINE_BYTES)
        .read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    // Cap hit without a terminator: the line keeps going — reject rather
    // than mis-parse the tail as further headers.
    if n as u64 == MAX_HEADER_LINE_BYTES && buf.last() != Some(&b'\n') {
        return Err(ParseError::HeadersTooLarge);
    }
    Ok(Some(String::from_utf8_lossy(&buf).into_owned()))
}

/// Parse one request from a stream. Returns None on clean EOF.
pub fn parse_request(stream: &mut TcpStream) -> Result<Option<HttpRequest>, ParseError> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let Some(line) = read_line_capped(&mut reader)? else {
        return Ok(None);
    };
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad request line",
        )
        .into());
    }
    let mut headers = BTreeMap::new();
    let mut n_headers = 0usize;
    loop {
        let Some(h) = read_line_capped(&mut reader)? else { break };
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(ParseError::HeadersTooLarge);
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(ParseError::BodyTooLarge);
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Some(HttpRequest {
        method,
        path,
        headers,
        body,
    }))
}

/// Serve until `shutdown` flips true. `handler` runs on a per-connection
/// thread; panics in handlers are converted to 500s.
pub fn serve<F>(addr: &str, shutdown: Arc<AtomicBool>, handler: F) -> std::io::Result<()>
where
    F: Fn(&HttpRequest) -> HttpResponse + Send + Sync + 'static,
{
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let handler = Arc::new(handler);
    println!("http: listening on {addr}");
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return Ok(());
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    stream.set_nonblocking(false).ok();
                    let resp = match parse_request(&mut stream) {
                        Ok(Some(req)) => {
                            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || handler(&req),
                            )) {
                                Ok(r) => r,
                                Err(_) => HttpResponse::json(
                                    500,
                                    "{\"error\":\"internal handler panic\"}",
                                ),
                            }
                        }
                        Ok(None) => return,
                        Err(ParseError::BodyTooLarge) => HttpResponse::json(
                            413,
                            "{\"error\":\"request body exceeds limit\"}",
                        ),
                        Err(ParseError::HeadersTooLarge) => HttpResponse::json(
                            400,
                            "{\"error\":\"header section exceeds limit\"}",
                        ),
                        Err(ParseError::Bad(_)) => {
                            HttpResponse::json(400, "{\"error\":\"bad request\"}")
                        }
                    };
                    let _ = resp.write_to(&mut stream);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    fn start(
        handler: impl Fn(&HttpRequest) -> HttpResponse + Send + Sync + 'static,
    ) -> (String, Arc<AtomicBool>) {
        // Bind on port 0 to get a free port, then serve on it.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let a = addr.clone();
        std::thread::spawn(move || serve(&a, sd, handler));
        std::thread::sleep(std::time::Duration::from_millis(50));
        (addr, shutdown)
    }

    fn roundtrip(addr: &str, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn get_and_post_roundtrip() {
        let (addr, shutdown) = start(|req| match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => HttpResponse::text(200, "ok"),
            ("POST", "/echo") => {
                HttpResponse::json(200, &format!("{{\"len\":{}}}", req.body.len()))
            }
            _ => HttpResponse::not_found(),
        });

        let get = roundtrip(&addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(get.starts_with("HTTP/1.1 200"), "{get}");
        assert!(get.ends_with("ok"), "{get}");

        let body = "{\"a\":1}";
        let post = roundtrip(
            &addr,
            &format!(
                "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            ),
        );
        assert!(post.contains("\"len\":7"), "{post}");

        let missing = roundtrip(&addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn handler_panic_returns_500() {
        let (addr, shutdown) = start(|_req| panic!("boom"));
        let resp = roundtrip(&addr, "GET / HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 500"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn oversized_content_length_rejected_413() {
        let (addr, shutdown) = start(|_req| HttpResponse::text(200, "ok"));
        // Declared body far past MAX_BODY_BYTES: rejected up front, never
        // allocated (the old parser silently truncated to the cap).
        let resp = roundtrip(
            &addr,
            &format!(
                "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            ),
        );
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn too_many_headers_rejected_400() {
        let (addr, shutdown) = start(|_req| HttpResponse::text(200, "ok"));
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            raw.push_str(&format!("X-H{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let resp = roundtrip(&addr, &raw);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn oversized_header_line_rejected_400() {
        let (addr, shutdown) = start(|_req| HttpResponse::text(200, "ok"));
        let raw = format!(
            "GET / HTTP/1.1\r\nX-Big: {}\r\n\r\n",
            "a".repeat(MAX_HEADER_LINE_BYTES as usize + 16)
        );
        let resp = roundtrip(&addr, &raw);
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn body_at_limit_still_parses() {
        let (addr, shutdown) = start(|req| {
            HttpResponse::json(200, &format!("{{\"len\":{}}}", req.body.len()))
        });
        let body = "b".repeat(1024);
        let resp = roundtrip(
            &addr,
            &format!(
                "POST /echo HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            ),
        );
        assert!(resp.contains("\"len\":1024"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }
}
