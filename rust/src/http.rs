//! Minimal HTTP/1.1 server substrate (no axum/hyper offline).
//!
//! Thread-per-connection, request-line + headers + Content-Length body
//! parsing, keep-alive off (Connection: close) for simplicity. Enough for
//! the OpenAI-style JSON frontend in `server/`.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }
}

/// A response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn json(status: u16, body: &str) -> Self {
        HttpResponse {
            status,
            content_type: "application/json".into(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        HttpResponse {
            status,
            content_type: "text/plain".into(),
            body: body.as_bytes().to_vec(),
        }
    }

    pub fn not_found() -> Self {
        Self::json(404, "{\"error\":\"not found\"}")
    }

    fn status_text(&self) -> &'static str {
        match self.status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "Unknown",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.status_text(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Parse one request from a stream. Returns None on clean EOF.
pub fn parse_request(stream: &mut TcpStream) -> std::io::Result<Option<HttpRequest>> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let path = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "bad request line",
        ));
    }
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
    }
    let len: usize = headers
        .get("content-length")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    let mut body = vec![0u8; len.min(64 << 20)]; // 64 MB cap
    if len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Some(HttpRequest {
        method,
        path,
        headers,
        body,
    }))
}

/// Serve until `shutdown` flips true. `handler` runs on a per-connection
/// thread; panics in handlers are converted to 500s.
pub fn serve<F>(addr: &str, shutdown: Arc<AtomicBool>, handler: F) -> std::io::Result<()>
where
    F: Fn(&HttpRequest) -> HttpResponse + Send + Sync + 'static,
{
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    let handler = Arc::new(handler);
    println!("http: listening on {addr}");
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return Ok(());
        }
        match listener.accept() {
            Ok((mut stream, _)) => {
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || {
                    stream.set_nonblocking(false).ok();
                    let resp = match parse_request(&mut stream) {
                        Ok(Some(req)) => {
                            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || handler(&req),
                            )) {
                                Ok(r) => r,
                                Err(_) => HttpResponse::json(
                                    500,
                                    "{\"error\":\"internal handler panic\"}",
                                ),
                            }
                        }
                        Ok(None) => return,
                        Err(_) => HttpResponse::json(400, "{\"error\":\"bad request\"}"),
                    };
                    let _ = resp.write_to(&mut stream);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    fn start(
        handler: impl Fn(&HttpRequest) -> HttpResponse + Send + Sync + 'static,
    ) -> (String, Arc<AtomicBool>) {
        // Bind on port 0 to get a free port, then serve on it.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let a = addr.clone();
        std::thread::spawn(move || serve(&a, sd, handler));
        std::thread::sleep(std::time::Duration::from_millis(50));
        (addr, shutdown)
    }

    fn roundtrip(addr: &str, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn get_and_post_roundtrip() {
        let (addr, shutdown) = start(|req| match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => HttpResponse::text(200, "ok"),
            ("POST", "/echo") => {
                HttpResponse::json(200, &format!("{{\"len\":{}}}", req.body.len()))
            }
            _ => HttpResponse::not_found(),
        });

        let get = roundtrip(&addr, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(get.starts_with("HTTP/1.1 200"), "{get}");
        assert!(get.ends_with("ok"), "{get}");

        let body = "{\"a\":1}";
        let post = roundtrip(
            &addr,
            &format!(
                "POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            ),
        );
        assert!(post.contains("\"len\":7"), "{post}");

        let missing = roundtrip(&addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

        shutdown.store(true, Ordering::Relaxed);
    }

    #[test]
    fn handler_panic_returns_500() {
        let (addr, shutdown) = start(|_req| panic!("boom"));
        let resp = roundtrip(&addr, "GET / HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 500"), "{resp}");
        shutdown.store(true, Ordering::Relaxed);
    }
}
