//! Bench-baseline comparator (PR 4 satellite): `ci.sh` emits fresh
//! `BENCH_*.json` files in smoke mode and runs
//!
//! ```text
//! benchdiff <committed-baseline.json> <fresh.json> [max-regression]
//! ```
//!
//! per bench. Exit codes:
//! * `0` — no baseline / placeholder baseline (warns; the gate is INERT
//!   until a measured baseline is committed — ROADMAP open item), or every
//!   fresh headline metric is within `max-regression` (default 0.20,
//!   i.e. fresh >= 0.8 × baseline for higher-is-better metrics and
//!   fresh <= 1.2 × baseline for lower-is-better ones);
//! * `1` — measurable regression beyond the threshold, or an unreadable
//!   fresh file (CI wiring bug — fail loudly, never silently skip).
//!
//! Headline metrics per bench family:
//! * `simulator` — arrow events/s (from `systems[]`),
//! * `scheduler` — `worst_placement_decisions_per_sec`,
//! * `scale` — `min_decisions_per_sec`,
//! * `sweep` — `events_per_sec` (higher is better) AND
//!   `peak_alloc_bytes` (lower is better — a memory regression fails the
//!   gate exactly like a throughput one, PR 7),
//! * `server` — `sustained_rps` (higher is better) AND `p99_ttft_s`
//!   (lower is better), from the `arrow loadgen` open-loop soak (PR 9).
//!
//! Claims reports (`"report": "claims"`, PR 8) diff on the count of
//! *core* holding claims — `slo_class:`-prefixed claims (PR 8) and the
//! `deflect:`/`unified:` adversary claims (PR 10) are excluded from the
//! headline so a baseline emitted before those claims existed still
//! compares like-for-like against a fresh report that carries them (the
//! excluded claims are gated by `tests/claims.rs` and `arrow claims`
//! itself, not by benchdiff).

use arrow::json::Json;

/// Which way a headline metric improves.
#[derive(Clone, Copy, PartialEq)]
enum Dir {
    Higher,
    Lower,
}

/// Headline metrics of a bench JSON; empty when the document is a schema
/// placeholder (no measured number in it).
fn headlines(doc: &Json) -> Vec<(String, f64, Dir)> {
    let mut out: Vec<(String, f64, Dir)> = Vec::new();
    let mut push = |label: &str, v: Option<f64>, dir: Dir| {
        if let Some(v) = v.filter(|v| v.is_finite() && *v > 0.0) {
            out.push((label.to_string(), v, dir));
        }
    };
    if doc.get("report").as_str() == Some("claims") {
        // Count only core claims: slo_class:* (PR 8) and the
        // deflect:*/unified:* adversary claims (PR 10) were added later
        // and must not break comparisons against older baselines.
        let is_core = |n: &str| {
            !n.starts_with("slo_class:")
                && !n.starts_with("deflect:")
                && !n.starts_with("unified:")
        };
        let holding = doc.get("claims").as_arr().map(|claims| {
            claims
                .iter()
                .filter(|c| {
                    c.get("claim").as_str().map_or(true, is_core)
                        && c.get("holds").as_bool() == Some(true)
                })
                .count() as f64
        });
        push("core claims holding", holding, Dir::Higher);
        return out;
    }
    match doc.get("bench").as_str() {
        Some("simulator") => push(
            "arrow events/s",
            doc.get("systems")
                .as_arr()
                .and_then(|rows| {
                    rows.iter()
                        .find(|r| r.get("system").as_str() == Some("arrow"))
                })
                .and_then(|r| r.get("events_per_sec").as_f64()),
            Dir::Higher,
        ),
        Some("scheduler") => push(
            "worst placement decisions/s",
            doc.get("worst_placement_decisions_per_sec").as_f64(),
            Dir::Higher,
        ),
        Some("scale") => push(
            "min placement decisions/s",
            doc.get("min_decisions_per_sec").as_f64(),
            Dir::Higher,
        ),
        Some("sweep") => {
            push(
                "streamed events/s",
                doc.get("events_per_sec").as_f64(),
                Dir::Higher,
            );
            push(
                "peak alloc bytes",
                doc.get("peak_alloc_bytes").as_f64(),
                Dir::Lower,
            );
        }
        Some("server") => {
            push(
                "sustained rps",
                doc.get("sustained_rps").as_f64(),
                Dir::Higher,
            );
            push("p99 ttft s", doc.get("p99_ttft_s").as_f64(), Dir::Lower);
        }
        other => {
            eprintln!("benchdiff: unknown bench family {other:?}");
        }
    }
    out
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 {
        eprintln!("usage: benchdiff <baseline.json> <fresh.json> [max-regression]");
        std::process::exit(1);
    }
    let max_regress: f64 = args
        .get(3)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.20);

    let baseline_raw = match std::fs::read_to_string(&args[1]) {
        Ok(s) => s,
        Err(e) => {
            println!(
                "benchdiff WARN: no committed baseline at {} ({e}) — regression gate \
                 skipped. Commit a measured BENCH file to arm it.",
                args[1]
            );
            return;
        }
    };
    let fresh_raw = match std::fs::read_to_string(&args[2]) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("benchdiff FAIL: fresh bench output {} unreadable: {e}", args[2]);
            std::process::exit(1);
        }
    };
    let (baseline, fresh) = match (Json::parse(&baseline_raw), Json::parse(&fresh_raw)) {
        (Ok(b), Ok(f)) => (b, f),
        (Err(e), _) => {
            println!(
                "benchdiff WARN: baseline {} is not valid JSON ({e}) — gate skipped.",
                args[1]
            );
            return;
        }
        (_, Err(e)) => {
            eprintln!("benchdiff FAIL: fresh output {} is not valid JSON: {e}", args[2]);
            std::process::exit(1);
        }
    };

    // Smoke mode (Bencher::quick: short warmup/measure windows) and full
    // mode are systematically different measurement regimes; diffing one
    // against the other would turn window bias into false alarms (or
    // mask real regressions). Only like-for-like comparisons arm the
    // gate — ci.sh runs smoke mode, so commit smoke-mode baselines
    // (or a full-mode baseline plus full-mode CI) to enable it.
    let (base_smoke, fresh_smoke) = (
        baseline.get("smoke").as_bool().unwrap_or(false),
        fresh.get("smoke").as_bool().unwrap_or(false),
    );
    if base_smoke != fresh_smoke {
        println!(
            "benchdiff WARN: {} was measured with smoke={base_smoke} but {} with \
             smoke={fresh_smoke} — regimes differ, regression gate skipped. \
             Regenerate the baseline in the mode CI runs (smoke).",
            args[1], args[2]
        );
        return;
    }

    let base_metrics = headlines(&baseline);
    if base_metrics.is_empty() {
        println!(
            "benchdiff WARN: {} is a placeholder (no measured headline metric) — \
             regression gate skipped until a measured baseline is committed \
             (ROADMAP open item).",
            args[1]
        );
        return;
    }
    let fresh_metrics = headlines(&fresh);

    let mut failed = false;
    for (label, base_v, dir) in &base_metrics {
        let Some((_, fresh_v, _)) = fresh_metrics.iter().find(|(l, _, _)| l == label) else {
            eprintln!(
                "benchdiff FAIL: fresh output {} carries no measured '{label}' metric",
                args[2]
            );
            failed = true;
            continue;
        };
        match dir {
            Dir::Higher => {
                let floor = (1.0 - max_regress) * base_v;
                if *fresh_v < floor {
                    eprintln!(
                        "benchdiff FAIL: {label} regressed {:.1}%: {fresh_v:.0} < {floor:.0} \
                         (baseline {base_v:.0}, allowed -{:.0}%)",
                        100.0 * (1.0 - fresh_v / base_v),
                        100.0 * max_regress
                    );
                    failed = true;
                    continue;
                }
                println!(
                    "benchdiff OK: {label} {fresh_v:.0} vs baseline {base_v:.0} \
                     ({:+.1}%, floor {floor:.0})",
                    100.0 * (fresh_v / base_v - 1.0)
                );
            }
            Dir::Lower => {
                let ceil = (1.0 + max_regress) * base_v;
                if *fresh_v > ceil {
                    eprintln!(
                        "benchdiff FAIL: {label} regressed {:.1}%: {fresh_v:.0} > {ceil:.0} \
                         (baseline {base_v:.0}, allowed +{:.0}%)",
                        100.0 * (fresh_v / base_v - 1.0),
                        100.0 * max_regress
                    );
                    failed = true;
                    continue;
                }
                println!(
                    "benchdiff OK: {label} {fresh_v:.0} vs baseline {base_v:.0} \
                     ({:+.1}%, ceiling {ceil:.0})",
                    100.0 * (fresh_v / base_v - 1.0)
                );
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
