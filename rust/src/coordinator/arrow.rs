//! The Arrow adaptive scheduler (paper §5) — the system contribution.
//!
//! Combines:
//! * stateless instances + elastic pools ([`Pools`]),
//! * the startup-profiled [`TtftPredictor`] (Insight 1),
//! * SLO-aware prefill request scheduling (Algorithm 1),
//! * SLO-aware decode request scheduling (Algorithm 2),
//! * instance scheduling `try_move_decode_to_prefill` /
//!   `try_move_prefill_to_decode` (Algorithms 3 & 4),
//! * monitor-tick instance scheduling: TPOT-violation flips, drained-pool
//!   settling, idle-prefill harvesting (§5.5),
//! * the overload policy: decode is prioritized, D→P flips are abandoned
//!   when decode load is high (§5.5 "Scheduling in Overload Scenario").
//!
//! The policy is **substrate-agnostic** (PR 2): it reads the cluster
//! exclusively through [`ClusterView`] and profiles through
//! [`ProfileSource`], so the identical object schedules both the
//! discrete-event simulator (via `sim::SimView`) and the live PJRT
//! server (via `server::view::ServerView`). It must never import
//! `SimInstance` or any other engine type.

use super::pools::{Pool, Pools};
use super::predictor::TtftPredictor;
use crate::request::{InstanceId, Request, SloClass, Time};
use crate::sched::{
    f64_from_key_bits, f64_key_bits, ClusterView, MembershipEvent, Policy,
    PrefillQueueMoments, ProfileSource, EPOCH_UNKNOWN,
};

/// Tunables for the Arrow policy (defaults follow the paper's text).
#[derive(Debug, Clone)]
pub struct ArrowConfig {
    /// TTFT SLO (Table 1, per workload).
    pub ttft_slo: f64,
    /// TPOT SLO (Table 1, per workload).
    pub tpot_slo: f64,
    /// Initial number of prefill instances (rest start as decode).
    pub initial_prefill: usize,
    /// Decode load (fraction of max running tokens) below which Alg. 1 is
    /// allowed to steal a decode instance (overload guard, §5.5).
    pub decode_low_watermark: f64,
    /// Consecutive monitor ticks of TPOT violation before flipping a
    /// prefill instance to decode (§5.5 condition 2).
    pub tpot_violation_ticks: u32,
    /// Fraction of decode-capable instances whose token interval must
    /// exceed the TPOT threshold to count a violation tick.
    pub tpot_violation_frac: f64,
    /// Judge Alg. 1/2 acceptance against each request's *own*
    /// [`SloClass`] targets (PR 8) and deprioritize lax-SLO (batch) work
    /// under pressure. With all-Standard traffic the class targets *are*
    /// the base pair, so this flag changes nothing — it exists so the
    /// claims harness can run a class-blind Arrow against mixed-class
    /// traffic as the comparison baseline.
    pub class_aware: bool,
}

impl ArrowConfig {
    pub fn new(ttft_slo: f64, tpot_slo: f64, n_instances: usize) -> Self {
        ArrowConfig {
            ttft_slo,
            tpot_slo,
            initial_prefill: n_instances / 2,
            decode_low_watermark: 0.5,
            tpot_violation_ticks: 2,
            tpot_violation_frac: 0.5,
            class_aware: true,
        }
    }
}

pub struct ArrowPolicy {
    cfg: ArrowConfig,
    pools: Pools,
    /// One TTFT predictor per instance — heterogeneous clusters (paper
    /// §8) profile each instance type separately at startup.
    predictors: Vec<TtftPredictor>,
    /// Profiled "Max Running Tokens" (paper §5.3) per instance: largest
    /// decode batch token count that still meets the TPOT SLO, capped by
    /// that instance's KV memory.
    max_running_tokens: Vec<u64>,
    /// Consecutive ticks with cluster-wide TPOT violation.
    violation_ticks: u32,
    // --- argmin-index refresh cache (PR 4) ---
    /// `ClusterView::change_epoch` at the last index refresh;
    /// `EPOCH_UNKNOWN` = cannot prove freshness, verify per slot.
    cache_epoch: u64,
    /// `Pools::structure_version` at the last refresh (flips/membership
    /// drop index entries, so a mismatch forces a rebuild pass).
    cache_structure: u64,
    /// Aggregates each cached key was computed from — the per-slot
    /// freshness check when the epoch can't vouch for the whole view.
    seen_moments: Vec<PrefillQueueMoments>,
    seen_tokens: Vec<u64>,
}

impl ArrowPolicy {
    pub fn new(cfg: ArrowConfig, n_instances: usize) -> Self {
        let pools = Pools::new(n_instances, cfg.initial_prefill.min(n_instances));
        ArrowPolicy {
            cfg,
            pools,
            predictors: Vec::new(),
            max_running_tokens: Vec::new(),
            violation_ticks: 0,
            cache_epoch: EPOCH_UNKNOWN,
            cache_structure: u64::MAX,
            seen_moments: Vec::new(),
            seen_tokens: Vec::new(),
        }
    }

    pub fn pools(&self) -> &Pools {
        &self.pools
    }

    fn predictor(&self, inst: usize) -> &TtftPredictor {
        self.predictors.get(inst).expect("policy not initialized")
    }

    /// Per-instance Max Running Tokens (∞ before init — tests only).
    fn mrt(&self, inst: usize) -> u64 {
        self.max_running_tokens.get(inst).copied().unwrap_or(u64::MAX)
    }

    // ------------------------------------------------------ load queries

    /// Bring the pools' keyed argmin index up to date with the view
    /// (PR 4). Three cost tiers, cheapest first:
    ///
    /// 1. **O(1) skip** — the substrate's [`ClusterView::change_epoch`]
    ///    matches the last refresh and no pool transition happened: every
    ///    cached key is provably current.
    /// 2. **Verify scan** — compare each member's O(1) aggregates
    ///    (moments / running tokens) against the values its key was
    ///    computed from; only changed slots are re-keyed (O(log n) each).
    /// 3. **Re-key** — O(1) per slot via
    ///    [`TtftPredictor::queue_delay_moments`]; the old queue *walk*
    ///    survives as a debug-mode oracle.
    ///
    /// Placement therefore never walks a queue, and on a quiescent view
    /// it never even touches the per-instance aggregates.
    fn refresh_index(&mut self, view: &dyn ClusterView) {
        let epoch = view.change_epoch();
        if epoch != EPOCH_UNKNOWN
            && epoch == self.cache_epoch
            && self.pools.structure_version() == self.cache_structure
        {
            return;
        }
        let n = self.pools.len();
        if self.seen_moments.len() < n {
            self.seen_moments.resize(n, PrefillQueueMoments::default());
            self.seen_tokens.resize(n, 0);
        }
        for i in 0..n {
            let id = InstanceId(i);
            let Some(pool) = self.pools.pool_of(id) else { continue };
            if pool.prefill_capable() {
                // P / D→P are keyed by predicted prefill delay.
                let m = view.prefill_queue_moments(i);
                if self.pools.key_of(id).is_none() || m != self.seen_moments[i] {
                    let pred = self.predictors.get(i).expect("policy not initialized");
                    let delay = pred.queue_delay_moments(&m);
                    #[cfg(debug_assertions)]
                    {
                        // Debug-mode oracle: the O(1) moments path must
                        // agree with the full queue walk it replaced.
                        // Since PR 8 both paths share one clamp
                        // convention (raw per-task costs summed, the
                        // *total* clamped), so strict agreement holds for
                        // every fit — including degenerate ones with
                        // negative coefficients.
                        let walk = pred.queue_delay_view(view, i);
                        let tol = 1e-6 * walk.abs().max(1.0);
                        let ok = if delay.is_nan() || walk.is_nan() {
                            delay.is_nan() && walk.is_nan()
                        } else {
                            (delay - walk).abs() <= tol
                        };
                        debug_assert!(ok, "inst {i}: moments delay {delay} != walk {walk}");
                    }
                    self.pools.set_key(id, f64_key_bits(delay));
                    self.seen_moments[i] = m;
                }
            } else {
                // D / P→D are keyed by running tokens (already integers).
                let t = view.running_tokens(i);
                if self.pools.key_of(id).is_none() || t != self.seen_tokens[i] {
                    self.pools.set_key(id, t);
                    self.seen_tokens[i] = t;
                }
            }
        }
        self.cache_epoch = epoch;
        self.cache_structure = self.pools.structure_version();
    }

    /// Argmin of predicted prefill delay over a pool: an O(log n) read of
    /// the keyed index (ties to the lowest id, NaN delays ordered last —
    /// byte-identical semantics to the member scan this replaced). Runs
    /// once per arriving request.
    fn min_prefill_delay(
        &mut self,
        pool: Pool,
        view: &dyn ClusterView,
    ) -> Option<(InstanceId, f64)> {
        self.refresh_index(view);
        self.pools
            .min_keyed(pool)
            .map(|(id, bits)| (id, f64_from_key_bits(bits)))
    }

    /// Argmin of running tokens over a pool (indexed, O(log n)).
    fn min_running_tokens(
        &mut self,
        pool: Pool,
        view: &dyn ClusterView,
    ) -> Option<(InstanceId, u64)> {
        self.refresh_index(view);
        self.pools.min_keyed(pool)
    }

    /// Is cluster-wide decode load low enough to steal an instance for
    /// prefill? (overload guard in Alg. 1, §5.5)
    fn decode_load_low(&self, view: &dyn ClusterView) -> bool {
        // Mean utilization relative to each instance's own capacity,
        // accumulated in one allocation-free pass over D ∪ P→D.
        let mut n = 0usize;
        let mut util_sum = 0.0;
        for id in self
            .pools
            .members_iter(Pool::Decode)
            .chain(self.pools.members_iter(Pool::PrefillToDecode))
        {
            let cap = self.mrt(id.0).min(view.max_kv_tokens(id.0)) as f64;
            util_sum += view.running_tokens(id.0) as f64 / cap.max(1.0);
            n += 1;
        }
        if n == 0 {
            return false;
        }
        util_sum / n as f64 < self.cfg.decode_low_watermark
    }

    /// Recent token interval of an instance against the given TPOT
    /// target, NaN treated as "no evidence".
    fn interval_ok(&self, view: &dyn ClusterView, inst: usize, tpot_slo: f64) -> bool {
        let v = view.avg_token_interval(inst);
        v.is_nan() || v <= tpot_slo
    }

    /// The TTFT target `req` is judged against in Alg. 1: its own class
    /// target when class-aware (PR 8), the base SLO otherwise. Standard's
    /// class target *is* the base pair, so all-Standard traffic is
    /// unaffected by the flag.
    fn ttft_slo_for(&self, req: &Request) -> f64 {
        if self.cfg.class_aware {
            req.class.ttft_slo(self.cfg.ttft_slo)
        } else {
            self.cfg.ttft_slo
        }
    }

    /// The TPOT target `req` is judged against in Alg. 2 (see
    /// [`ArrowPolicy::ttft_slo_for`]).
    fn tpot_slo_for(&self, req: &Request) -> f64 {
        if self.cfg.class_aware {
            req.class.tpot_slo(self.cfg.tpot_slo)
        } else {
            self.cfg.tpot_slo
        }
    }

    // -------------------------------------------- Algorithms 3 & 4 (§5.5)

    /// Algorithm 3: reassign a decode instance to prefill duty. Returns
    /// the flipped instance. Keeps ≥ 2 decode-capable instances' worth of
    /// service by requiring |D| + |P→D| > 1.
    fn try_move_decode_to_prefill(&mut self, view: &dyn ClusterView) -> Option<InstanceId> {
        if self.pools.decode_capable_count() <= 1 {
            return None;
        }
        // Prefer an instance that was only *scheduled* for decode (P→D);
        // else the least-loaded decode instance.
        let pick = self
            .min_running_tokens(Pool::PrefillToDecode, view)
            .or_else(|| self.min_running_tokens(Pool::Decode, view))?;
        let id = pick.0;
        self.pools.flip_to_prefill(id, view.has_decode_work(id.0));
        Some(id)
    }

    /// Algorithm 4: reassign a prefill instance to decode duty.
    fn try_move_prefill_to_decode(&mut self, view: &dyn ClusterView) -> Option<InstanceId> {
        if self.pools.prefill_capable_count() <= 1 {
            return None;
        }
        let pick = self
            .min_prefill_delay(Pool::DecodeToPrefill, view)
            .or_else(|| self.min_prefill_delay(Pool::Prefill, view))?;
        let id = pick.0;
        self.pools.flip_to_decode(id, view.has_prefill_work(id.0));
        Some(id)
    }
}

impl Policy for ArrowPolicy {
    fn name(&self) -> &'static str {
        "arrow-slo-aware"
    }

    fn init(&mut self, profile: &dyn ProfileSource) {
        // Startup profiling (paper §5.3): fit one TTFT quadratic and
        // measure Max Running Tokens per instance — heterogeneous
        // instances (different TP degree / hardware, §8) get their own
        // curves, so placement decisions stay accurate across them. The
        // substrate decides *how* to profile (cost-model queries in the
        // simulator, timed probe prompts on the live server).
        let n = profile.n_instances();
        self.predictors = (0..n).map(|i| profile.fit_predictor(i)).collect();
        self.max_running_tokens = (0..n)
            .map(|i| profile.max_running_tokens(i, self.cfg.tpot_slo))
            .collect();
        // New curves invalidate every cached delay key: rebuild the
        // argmin index from scratch on the next decision.
        self.pools.reset_keys();
        self.cache_epoch = EPOCH_UNKNOWN;
    }

    /// Algorithm 1: SLO-aware prefill scheduling.
    fn place_prefill(
        &mut self,
        _now: Time,
        req: &Request,
        view: &dyn ClusterView,
    ) -> InstanceId {
        // PR 8: Alg. 1 acceptance is tested against the request's *own*
        // class target — an interactive request demands a tighter queue,
        // a batch request tolerates a deep one.
        let ttft_slo = self.ttft_slo_for(req);
        // "Own" prefill time is instance-dependent on heterogeneous
        // clusters; evaluate per candidate below via its own predictor.
        let own_on = |p: &ArrowPolicy, id: InstanceId| {
            p.predictor(id.0).prefill_seconds(req.input_len)
        };
        // PR 6: a Degraded (straggler) argmin never wins the SLO test —
        // its predictor was fit on healthy timings, so the promise is
        // hollow. Fault-free clusters have no Degraded instances and the
        // acceptance conditions below evaluate exactly as before.
        let t1 = self.min_prefill_delay(Pool::Prefill, view);
        if let Some((id, delay)) = t1 {
            if delay + own_on(self, id) <= ttft_slo
                && !view.liveness(id.0).is_degraded()
            {
                return id;
            }
        }
        let t2 = self.min_prefill_delay(Pool::DecodeToPrefill, view);
        if let Some((id, delay)) = t2 {
            if delay + own_on(self, id) <= ttft_slo
                && !view.liveness(id.0).is_degraded()
            {
                return id;
            }
        }
        // Hopeless requests — prefill time alone exceeds the TTFT SLO on
        // the best candidate — can never comply (Insight 2's monotonicity:
        // no remedial action exists); growing the prefill pool would burn
        // a flip for nothing.
        let best = t1.or(t2);
        if let Some((id, _)) = best {
            if own_on(self, id) > ttft_slo {
                return id;
            }
        }
        // Try to grow the prefill pool — but only if decode can spare an
        // instance (overload policy: decode has priority). Batch-class
        // work never burns a flip (PR 8): its lax deadline is what the
        // deep queue is *for* — stealing decode capacity to rescue it
        // would trade interactive decode headroom for worthless slack.
        let may_steal =
            !(self.cfg.class_aware && req.class == SloClass::Batch);
        if may_steal && self.decode_load_low(view) {
            if let Some(t3) = self.try_move_decode_to_prefill(view) {
                return t3;
            }
        }
        // Fall back to the least-loaded prefill-capable instance.
        t1.or(t2)
            .map(|(id, _)| id)
            .or_else(|| {
                // No prefill-capable instance at all: force a flip.
                self.try_move_decode_to_prefill(view)
            })
            .or_else(|| {
                // Flip guard refused (a lone decode member must keep
                // serving decode): dispatch onto any member — stateless
                // instances accept both phases, the pool label only
                // steers placement preference.
                self.pools.any_member()
            })
            .unwrap_or_else(|| {
                // Pools empty (everything lost/draining). Last ditch:
                // first *healthy* live instance in the view, then any
                // placeable (a straggler beats nothing), else 0 — the
                // substrate fails the request if nothing is left.
                (0..view.n_instances())
                    .map(InstanceId)
                    .find(|id| {
                        let l = view.liveness(id.0);
                        l.placeable() && !l.is_degraded()
                    })
                    .or_else(|| {
                        (0..view.n_instances())
                            .map(InstanceId)
                            .find(|id| view.liveness(id.0).placeable())
                    })
                    .unwrap_or(InstanceId(0))
            })
    }

    /// Algorithm 2: SLO-aware decode scheduling.
    fn place_decode(
        &mut self,
        _now: Time,
        req: &Request,
        prefill_instance: InstanceId,
        view: &dyn ClusterView,
    ) -> InstanceId {
        // If the prefill instance was meanwhile reassigned toward decode,
        // keep the request local — zero KV transfer (§5.3). A departed
        // instance (drained/lost between prefill and decode placement)
        // has no capability at all: `pool_of` is None and the request
        // migrates to a live decode instance.
        if self
            .pools
            .pool_of(prefill_instance)
            .is_some_and(|p| p.decode_capable())
        {
            return prefill_instance;
        }
        // Admission counts the incoming request's own KV footprint. A
        // Degraded (straggler, PR 6) argmin fails acceptance the same way
        // a TPOT-violating interval does — Alg. 2 escalates to a healthy
        // pool or a flip instead of feeding the slow instance. The
        // interval is judged against the request's own class TPOT target
        // (PR 8): batch work accepts a busier instance than interactive.
        let tpot_slo = self.tpot_slo_for(req);
        let incoming = req.input_len as u64;
        let t1 = self.min_running_tokens(Pool::Decode, view);
        if let Some((id, tokens)) = t1 {
            if tokens + incoming <= self.mrt(id.0)
                && self.interval_ok(view, id.0, tpot_slo)
                && !view.liveness(id.0).is_degraded()
            {
                return id;
            }
        }
        let t2 = self.min_running_tokens(Pool::PrefillToDecode, view);
        if let Some((id, tokens)) = t2 {
            if tokens + incoming <= self.mrt(id.0)
                && self.interval_ok(view, id.0, tpot_slo)
                && !view.liveness(id.0).is_degraded()
            {
                return id;
            }
        }
        // A batch-class miss never forces a P→D flip either (PR 8): the
        // lax TPOT target already absorbed the pressure check above, and
        // flips are reserved for work that can still meet a tight SLO.
        let may_flip = !(self.cfg.class_aware && req.class == SloClass::Batch);
        if may_flip {
            if let Some(t3) = self.try_move_prefill_to_decode(view) {
                return t3;
            }
        }
        // Fallback: lesser-loaded of t1/t2 (Alg. 2's final branch).
        match (t1, t2) {
            (Some((a, ta)), Some((b, tb))) => {
                if ta <= tb {
                    a
                } else {
                    b
                }
            }
            (Some((a, _)), None) => a,
            (None, Some((b, _))) => b,
            // No decode-capable member and the flip guard refused: any
            // member beats a possibly-departed prefill instance.
            (None, None) => self.pools.any_member().unwrap_or(prefill_instance),
        }
    }

    /// Monitor tick (§5.5): settle drained transition pools, flip on
    /// sustained TPOT violations, harvest idle prefill instances.
    fn on_tick(&mut self, _now: Time, view: &dyn ClusterView) {
        // 1. Settle P→D / D→P instances that drained their old work.
        for i in 0..view.n_instances() {
            let id = InstanceId(i);
            self.pools
                .settle(id, view.has_prefill_work(i), view.has_decode_work(i));
        }

        // 2. Sustained TPOT violation => move a prefill instance to decode
        //    (condition 2 of §5.5; Insight 3: monitor real token gaps).
        //    One pass over D ∪ P→D counts members/violators and evaluates
        //    the step-3 busy predicate without materializing the id list.
        //    `decode_busy` is deliberately computed over the *pre-flip*
        //    membership (the historical snapshot semantics): the instance
        //    a violation flip moves into the decode pools this tick must
        //    not retrigger step 3 in the same tick.
        let mut n_decode = 0usize;
        let mut violating = 0usize;
        let mut decode_busy = false;
        for id in self
            .pools
            .members_iter(Pool::Decode)
            .chain(self.pools.members_iter(Pool::PrefillToDecode))
        {
            n_decode += 1;
            let v = view.avg_token_interval(id.0);
            if !v.is_nan() && v > self.cfg.tpot_slo {
                violating += 1;
            }
            decode_busy |= view.running_tokens(id.0)
                > (self.cfg.decode_low_watermark
                    * self.mrt(id.0).min(view.max_kv_tokens(id.0)) as f64)
                    as u64;
        }
        if n_decode > 0 {
            if (violating as f64) >= self.cfg.tpot_violation_frac * n_decode as f64 {
                self.violation_ticks += 1;
            } else {
                self.violation_ticks = 0;
            }
            if self.violation_ticks >= self.cfg.tpot_violation_ticks {
                self.try_move_prefill_to_decode(view);
                self.violation_ticks = 0;
            }
        }

        // 3. Idle prefill + busy decode => harvest the idle instance
        //    (condition 3 of §5.5). "Busy" = any decode-capable instance
        //    above the watermark or with parked work (computed above).
        if decode_busy {
            let idle_prefill: Vec<InstanceId> = self
                .pools
                .members(Pool::Prefill)
                .into_iter()
                .filter(|id| view.is_idle(id.0))
                .collect();
            for id in idle_prefill {
                if self.pools.prefill_capable_count() <= 1 {
                    break;
                }
                self.pools.flip_to_decode(id, false);
            }
        }
    }

    /// Elastic membership (PR 3): re-seed the pools and re-run the
    /// Alg. 2/4 capacity logic against the new instance set. The
    /// substrate owns work recovery; only scheduling state changes here.
    fn on_membership(
        &mut self,
        _now: Time,
        ev: MembershipEvent,
        view: &dyn ClusterView,
        profile: &dyn ProfileSource,
    ) {
        match ev {
            MembershipEvent::InstanceJoined { id } => {
                if self.pools.contains(id) {
                    return; // duplicate join — membership is idempotent
                }
                // Profile the joiner exactly like the startup set (§5.3);
                // late joiners may extend the table (live scale-out), and
                // a rejoining slot may carry different hardware, so the
                // slot's curve is always refreshed.
                let i = id.0;
                while self.predictors.len() <= i {
                    let j = self.predictors.len();
                    self.predictors.push(profile.fit_predictor(j));
                    self.max_running_tokens
                        .push(profile.max_running_tokens(j, self.cfg.tpot_slo));
                }
                self.predictors[i] = profile.fit_predictor(i);
                self.max_running_tokens[i] = profile.max_running_tokens(i, self.cfg.tpot_slo);
                // Re-run the Alg. 1 SLO test against the new capacity:
                // the joiner lands in Prefill when the current prefill
                // pool is (or is about to be) missing its TTFT SLO —
                // exactly the condition under which Alg. 1 would steal an
                // instance — and in Decode otherwise (decode priority,
                // §5.5 overload rule). NaN delays (broken predictor)
                // count as pressure, never as a free pass.
                let best_delay = self
                    .min_prefill_delay(Pool::Prefill, view)
                    .or_else(|| self.min_prefill_delay(Pool::DecodeToPrefill, view));
                let prefill_pressed = match best_delay {
                    Some((_, delay)) => !(delay <= self.cfg.ttft_slo),
                    None => true, // no prefill capability at all
                };
                let pool = if prefill_pressed { Pool::Prefill } else { Pool::Decode };
                self.pools.join(id, pool);
            }
            MembershipEvent::InstanceDraining { id } | MembershipEvent::InstanceLost { id } => {
                self.pools.remove(id);
                // Re-run the Alg. 3/4 flip logic against the shrunk
                // capacity: if the departed instance held the last
                // capability of one phase, flip a survivor so both phases
                // stay servable.
                if self.pools.decode_capable_count() == 0 {
                    self.try_move_prefill_to_decode(view);
                } else if self.pools.prefill_capable_count() == 0 {
                    self.try_move_decode_to_prefill(view);
                }
            }
        }
    }

    fn pool_sizes(&self) -> Option<[usize; 4]> {
        Some(self.pools.sizes())
    }

    fn flip_count(&self) -> u64 {
        self.pools.flip_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::CostModel;
    use crate::engine::SimInstance;
    use crate::sim::SimView;

    fn cluster(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect()
    }

    fn policy(n: usize) -> (ArrowPolicy, Vec<SimInstance>) {
        let insts = cluster(n);
        let mut p = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, n), n);
        p.init(&SimView(&insts));
        (p, insts)
    }

    fn req(id: u64, input: u32, output: u32) -> Request {
        Request::new(id, 0.0, input, output)
    }

    #[test]
    fn prefill_goes_to_least_loaded_prefill_instance() {
        let (mut p, mut insts) = policy(4);
        // Load instance 0's prefill queue.
        insts[0].enqueue_prefill(crate::request::RequestId(9), 50_000);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(1), "empty prefill instance preferred");
    }

    #[test]
    fn prefill_overflows_to_dp_pool_when_slo_violated() {
        let (mut p, mut insts) = policy(4);
        // Both prefill instances (0, 1) heavily backlogged.
        for i in 0..2 {
            for r in 0..4 {
                insts[i].enqueue_prefill(crate::request::RequestId(100 + r), 100_000);
            }
        }
        // Move instance 2 into D→P so it is prefill-capable.
        p.pools.flip_to_prefill(InstanceId(2), true);
        assert_eq!(p.pools.pool_of(InstanceId(2)), Some(Pool::DecodeToPrefill));
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_eq!(t, InstanceId(2));
    }

    #[test]
    fn prefill_steals_decode_instance_under_burst() {
        let (mut p, mut insts) = policy(4);
        // Prefill pool (0,1) backlogged far beyond the 3s TTFT SLO;
        // decode pool (2,3) idle => decode load low => Alg. 1 must flip a
        // decode instance to prefill.
        for i in 0..2 {
            for r in 0..4 {
                insts[i].enqueue_prefill(crate::request::RequestId(100 + r), 100_000);
            }
        }
        let before = p.pools.sizes();
        assert_eq!(before, [2, 2, 0, 0]);
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert!(t == InstanceId(2) || t == InstanceId(3), "stole {t}");
        assert_eq!(p.pools.sizes()[0], 3, "prefill pool grew");
        assert!(p.flip_count() >= 1);
    }

    #[test]
    fn overload_guard_blocks_steal_when_decode_busy() {
        let (mut p, mut insts) = policy(4);
        for i in 0..2 {
            for r in 0..4 {
                insts[i].enqueue_prefill(crate::request::RequestId(100 + r), 100_000);
            }
        }
        // Decode instances loaded above the watermark.
        for i in 2..4 {
            let cap = p.mrt(i).min(insts[i].cost.max_kv_tokens);
            let load = (cap as f64 * 0.9) as u64;
            assert!(insts[i].try_reserve_kv(load));
            insts[i].enqueue_decode(crate::request::RequestId(200 + i as u64), load as u32, 100);
        }
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        // Falls back to a prefill instance — decode priority preserved.
        assert!(t.0 < 2, "must not steal decode under load, got {t}");
        assert_eq!(p.pools.sizes()[1], 2);
    }

    #[test]
    fn decode_stays_local_when_prefill_instance_flipped() {
        let (mut p, insts) = policy(4);
        // Instance 0 (prefill) got flipped toward decode while the
        // request prefilled there.
        p.pools.flip_to_decode(InstanceId(0), false);
        let t = p.place_decode(0.0, &req(1, 1000, 10), InstanceId(0), &SimView(&insts));
        assert_eq!(t, InstanceId(0), "local handoff avoids KV transfer");
    }

    #[test]
    fn decode_picks_min_running_tokens() {
        let (mut p, mut insts) = policy(4);
        assert!(insts[2].try_reserve_kv(10_000));
        insts[2].enqueue_decode(crate::request::RequestId(50), 10_000, 100);
        let t = p.place_decode(0.0, &req(1, 1000, 10), InstanceId(0), &SimView(&insts));
        assert_eq!(t, InstanceId(3), "less-loaded decode instance");
    }

    #[test]
    fn decode_flips_prefill_instance_when_all_decode_overloaded() {
        let (mut p, mut insts) = policy(4);
        for i in 2..4 {
            let cap = insts[i].cost.max_kv_tokens;
            assert!(insts[i].try_reserve_kv(cap));
            insts[i].enqueue_decode(crate::request::RequestId(60 + i as u64), cap as u32, 100);
        }
        let before_decode = p.pools.decode_capable_count();
        let t = p.place_decode(0.0, &req(1, 1000, 10), InstanceId(0), &SimView(&insts));
        assert!(
            p.pools.pool_of(t).unwrap().decode_capable(),
            "target must be decode-capable"
        );
        assert!(p.pools.decode_capable_count() > before_decode);
    }

    #[test]
    fn tick_settles_drained_transition_pools() {
        let (mut p, insts) = policy(4);
        p.pools.flip_to_decode(InstanceId(0), true); // P→D, but no work
        p.on_tick(1.0, &SimView(&insts));
        assert_eq!(p.pools.pool_of(InstanceId(0)), Some(Pool::Decode));
    }

    #[test]
    fn tick_harvests_idle_prefill_when_decode_busy() {
        let (mut p, mut insts) = policy(4);
        // Decode instance 2 busy above watermark.
        let cap = p.mrt(2).min(insts[2].cost.max_kv_tokens);
        let load = (cap as f64 * 0.9) as u64;
        assert!(insts[2].try_reserve_kv(load));
        insts[2].enqueue_decode(crate::request::RequestId(70), load as u32, 100);
        // Prefill instances 0,1 idle.
        p.on_tick(1.0, &SimView(&insts));
        let sizes = p.pools.sizes();
        assert_eq!(sizes[0], 1, "one idle prefill harvested, one kept: {sizes:?}");
        assert!(sizes[1] + sizes[2] == 3);
    }

    #[test]
    fn sustained_tpot_violation_flips_prefill_to_decode() {
        let (mut p, mut insts) = policy(4);
        // Give decode instances a violating token-interval history.
        for i in 2..4 {
            assert!(insts[i].try_reserve_kv(100));
            insts[i].enqueue_decode(crate::request::RequestId(80 + i as u64), 100, 500);
        }
        // Simulate: directly feed the sliding window by running iterations
        // with manipulated times.
        for i in 2..4 {
            let mut now = 0.0;
            for _ in 0..8 {
                if let Some(plan) = insts[i].plan_iteration() {
                    now += 0.5; // 0.5s per token >> 0.1s TPOT SLO
                    insts[i].finish_iteration(&plan, now);
                }
            }
            assert!(insts[i].avg_token_interval() > p.cfg.tpot_slo);
        }
        let before = p.pools.sizes();
        p.on_tick(1.0, &SimView(&insts));
        p.on_tick(2.0, &SimView(&insts));
        let after = p.pools.sizes();
        assert!(
            after[1] + after[2] > before[1] + before[2],
            "decode capacity grew: {before:?} -> {after:?}"
        );
    }

    #[test]
    fn joiner_lands_in_decode_when_calm_and_prefill_when_pressed() {
        // Calm cluster: a joiner lands in Decode (decode priority).
        let (mut p, mut insts) = policy(5);
        insts[4].life = crate::sched::Liveness::Dead;
        p.on_membership(
            0.0,
            MembershipEvent::InstanceLost { id: InstanceId(4) },
            &SimView(&insts),
            &SimView(&insts),
        );
        assert_eq!(p.pools.member_count(), 4);
        insts[4].life = crate::sched::Liveness::Active;
        p.on_membership(
            1.0,
            MembershipEvent::InstanceJoined { id: InstanceId(4) },
            &SimView(&insts),
            &SimView(&insts),
        );
        assert_eq!(p.pools.pool_of(InstanceId(4)), Some(Pool::Decode));

        // Prefill pool far past the TTFT SLO: the next joiner must land
        // in Prefill (the Alg. 1 condition re-run against new capacity).
        let (mut p, mut insts) = policy(5);
        insts[4].life = crate::sched::Liveness::Dead;
        p.on_membership(
            0.0,
            MembershipEvent::InstanceLost { id: InstanceId(4) },
            &SimView(&insts),
            &SimView(&insts),
        );
        for i in 0..2 {
            for r in 0..4 {
                insts[i].enqueue_prefill(crate::request::RequestId(100 + r), 100_000);
            }
        }
        insts[4].life = crate::sched::Liveness::Active;
        p.on_membership(
            1.0,
            MembershipEvent::InstanceJoined { id: InstanceId(4) },
            &SimView(&insts),
            &SimView(&insts),
        );
        assert_eq!(p.pools.pool_of(InstanceId(4)), Some(Pool::Prefill));
    }

    #[test]
    fn losing_the_whole_decode_pool_flips_a_survivor() {
        let (mut p, insts) = policy(4);
        // Instances 2, 3 form the decode pool; lose both.
        for i in [2usize, 3] {
            p.on_membership(
                0.0,
                MembershipEvent::InstanceLost { id: InstanceId(i) },
                &SimView(&insts),
                &SimView(&insts),
            );
        }
        // A prefill survivor was flipped so decode stays servable.
        assert!(p.pools.decode_capable_count() >= 1, "{:?}", p.pools.sizes());
        assert!(p.pools.prefill_capable_count() >= 1);
        assert_eq!(p.pools.member_count(), 2);
    }

    #[test]
    fn departed_instance_never_receives_a_placement() {
        let (mut p, mut insts) = policy(4);
        insts[1].life = crate::sched::Liveness::Draining;
        p.on_membership(
            0.0,
            MembershipEvent::InstanceDraining { id: InstanceId(1) },
            &SimView(&insts),
            &SimView(&insts),
        );
        insts[3].life = crate::sched::Liveness::Dead;
        p.on_membership(
            0.0,
            MembershipEvent::InstanceLost { id: InstanceId(3) },
            &SimView(&insts),
            &SimView(&insts),
        );
        for step in 0..40u64 {
            let r = req(step, 2_000, 10);
            let t = p.place_prefill(step as f64, &r, &SimView(&insts));
            assert!(t != InstanceId(1) && t != InstanceId(3), "placed on departed {t}");
            let d = p.place_decode(step as f64, &r, t, &SimView(&insts));
            assert!(d != InstanceId(1) && d != InstanceId(3), "decoded on departed {d}");
        }
    }

    #[test]
    fn degraded_straggler_is_deprioritized_but_still_placeable() {
        // PR 6: a straggler flagged Degraded loses the t1/t2 acceptance
        // even when its queue-delay argmin wins; placement escalates to
        // healthy capacity instead.
        let (mut p, mut insts) = policy(4);
        // Load instance 0 so the prefill argmin is instance 1, then mark
        // 1 as a straggler: the SLO test must refuse it and Alg. 1 steals
        // an (idle) decode instance instead.
        insts[0].enqueue_prefill(crate::request::RequestId(9), 50_000);
        insts[1].life = crate::sched::Liveness::Degraded;
        let t = p.place_prefill(0.0, &req(1, 1000, 10), &SimView(&insts));
        assert_ne!(t, InstanceId(1), "degraded argmin must not win acceptance");
        // Decode: the min-running-tokens argmin (tie → lowest id = 2) is
        // degraded; Alg. 2 must escalate rather than feed the straggler.
        let (mut p2, mut insts2) = policy(4);
        insts2[2].life = crate::sched::Liveness::Degraded;
        let d = p2.place_decode(0.0, &req(2, 1000, 10), InstanceId(0), &SimView(&insts2));
        assert_ne!(d, InstanceId(2), "degraded decode argmin must not win");
        // Degraded is still placeable (last resort): liveness contract.
        assert!(crate::sched::Liveness::Degraded.placeable());
        assert!(crate::sched::Liveness::Degraded.in_cluster());
        assert!(crate::sched::Liveness::Degraded.is_degraded());
    }

    #[test]
    fn batch_class_never_steals_a_decode_instance() {
        // PR 8: the same burst that makes a Standard request steal a
        // decode instance (see prefill_steals_decode_instance_under_burst)
        // must leave the pools untouched for a Batch request — its lax
        // deadline is absorbed by the deep prefill queue instead.
        let (mut p, mut insts) = policy(4);
        for i in 0..2 {
            for r in 0..4 {
                insts[i].enqueue_prefill(crate::request::RequestId(100 + r), 100_000);
            }
        }
        assert_eq!(p.pools.sizes(), [2, 2, 0, 0]);
        let r = req(1, 1000, 10).with_class(SloClass::Batch);
        let t = p.place_prefill(0.0, &r, &SimView(&insts));
        assert!(t.0 < 2, "batch must land on the prefill pool, got {t}");
        assert_eq!(p.pools.sizes(), [2, 2, 0, 0], "no flip for batch work");
        assert_eq!(p.flip_count(), 0);
    }

    #[test]
    fn interactive_class_rejects_a_queue_standard_accepts() {
        // A queue whose delay fits the base TTFT target but not the
        // interactive (0.5x) target: Standard accepts the argmin,
        // Interactive escalates to a steal. Class-blind mode treats both
        // identically — the claims-harness baseline.
        use crate::sched::FixedProfile;
        let profile = FixedProfile {
            predictors: vec![
                TtftPredictor::from_coefficients([0.0, 1e-4, 0.0], 2048, 0.0);
                4
            ],
            max_running_tokens: vec![1_000_000; 4],
        };
        let mut insts = cluster(4);
        // Instances 0,1 prefill / 2,3 decode. Both prefill queues price
        // at 0.6s; own time 0.1s: 0.7 <= 1.0 (standard) but > 0.5
        // (interactive).
        insts[0].enqueue_prefill(crate::request::RequestId(8), 6000);
        insts[1].enqueue_prefill(crate::request::RequestId(9), 6000);
        let mk = |class_aware: bool| {
            let mut cfg = ArrowConfig::new(1.0, 0.1, 4);
            cfg.class_aware = class_aware;
            let mut p = ArrowPolicy::new(cfg, 4);
            p.init(&profile);
            p
        };
        let std_req = req(1, 1000, 10);
        let int_req = req(2, 1000, 10).with_class(SloClass::Interactive);
        assert_eq!(mk(true).place_prefill(0.0, &std_req, &SimView(&insts)), InstanceId(0));
        let stolen = mk(true).place_prefill(0.0, &int_req, &SimView(&insts));
        assert!(
            stolen.0 >= 2,
            "interactive must escalate off the too-deep queue, got {stolen}"
        );
        assert_eq!(
            mk(false).place_prefill(0.0, &int_req, &SimView(&insts)),
            InstanceId(0),
            "class-blind mode ignores the class"
        );
    }

    #[test]
    fn indexed_argmin_matches_walk_argmin_under_churn() {
        // PR 4: placements read the keyed argmin index instead of
        // scanning members. Under arbitrary queue/decode churn the index
        // must keep answering exactly what a fresh walk-based scan would
        // (delays within fp tolerance, running tokens exactly).
        use crate::request::RequestId;
        use crate::util::{prop, rng::Rng};
        prop::check_with(59, 48, |rng: &mut Rng| {
            let n = rng.index(6) + 2;
            let insts = cluster(n);
            // Generous SLOs: Alg. 1/2 always return their first-branch
            // argmin, so the chosen instance IS the index's answer.
            let mut p = ArrowPolicy::new(ArrowConfig::new(1e9, 1e9, n), n);
            p.init(&SimView(&insts));
            let mut insts = insts;
            let mut next = 1000u64;
            for step in 0..40u64 {
                // Churn: enqueue prefill work, park decode work, or run
                // an iteration somewhere.
                match rng.index(3) {
                    0 => {
                        let i = rng.index(n);
                        insts[i].enqueue_prefill(
                            RequestId(next),
                            rng.int_range(100, 30_000) as u32,
                        );
                        next += 1;
                    }
                    1 => {
                        let i = rng.index(n);
                        let ctx = rng.int_range(50, 2_000) as u64;
                        if insts[i].try_reserve_kv(ctx) {
                            insts[i].enqueue_decode(RequestId(next), ctx as u32, 4);
                            next += 1;
                        }
                    }
                    _ => {
                        let i = rng.index(n);
                        if let Some(plan) = insts[i].plan_iteration() {
                            insts[i].finish_iteration(&plan, step as f64);
                        }
                    }
                }
                // Prefill: chosen delay must be minimal over the P pool
                // (walk-computed, so this also cross-checks moments).
                let t = p.place_prefill(step as f64, &req(step, 500, 8), &SimView(&insts));
                let delay_of = |i: usize| {
                    TtftPredictor::profile(&insts[i].cost, insts[i].chunk_tokens)
                        .queue_delay_iter(insts[i].prefill_queue_iter())
                };
                let best = p
                    .pools()
                    .members_iter(Pool::Prefill)
                    .map(|id| delay_of(id.0))
                    .min_by(|a, b| a.total_cmp(b))
                    .unwrap();
                crate::prop_assert!(
                    delay_of(t.0) <= best + 1e-9 * best.max(1.0),
                    "step {step}: placed {t} at delay {} but pool min is {best}",
                    delay_of(t.0)
                );
                // Decode: running tokens are integers — exact argmin.
                let d = p.place_decode(step as f64, &req(step, 200, 8), t, &SimView(&insts));
                if p.pools().pool_of(d) == Some(Pool::Decode)
                    && p.pools().pool_of(t).map(|pl| !pl.decode_capable()).unwrap_or(true)
                {
                    let min_tokens = p
                        .pools()
                        .members_iter(Pool::Decode)
                        .map(|id| insts[id.0].running_tokens())
                        .min()
                        .unwrap();
                    crate::prop_assert!(
                        insts[d.0].running_tokens() == min_tokens,
                        "step {step}: decode placed {d} with {} tokens, min {min_tokens}",
                        insts[d.0].running_tokens()
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn guard_never_empties_capability() {
        // Property: any sequence of placements keeps >=1 prefill-capable
        // and >=1 decode-capable instance.
        use crate::util::{prop, rng::Rng};
        prop::check_with(17, 64, |rng: &mut Rng| {
            let n = rng.index(6) + 2;
            let (mut p, mut insts) = policy(n);
            for step in 0..40 {
                let r = req(step, rng.int_range(100, 60_000) as u32, 10);
                if rng.bool(0.5) {
                    let t = p.place_prefill(step as f64, &r, &SimView(&insts));
                    insts[t.0].enqueue_prefill(crate::request::RequestId(step), r.input_len);
                } else {
                    let from = InstanceId(rng.index(n));
                    let t = p.place_decode(step as f64, &r, from, &SimView(&insts));
                    if t != from && insts[t.0].try_reserve_kv(r.input_len as u64) {
                        insts[t.0].enqueue_decode(
                            crate::request::RequestId(step),
                            r.input_len,
                            8,
                        );
                    }
                }
                p.on_tick(step as f64, &SimView(&insts));
                crate::prop_assert!(
                    p.pools.prefill_capable_count() >= 1,
                    "no prefill-capable instance left"
                );
                crate::prop_assert!(
                    p.pools.decode_capable_count() >= 1,
                    "no decode-capable instance left"
                );
            }
            Ok(())
        });
    }
}
