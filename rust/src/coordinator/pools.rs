//! Elastic instance pools (paper §5.2, Fig. 5 V).
//!
//! Four pools — Prefill, Decode, P→D, D→P — where P→D holds instances
//! scheduled to handle decode but still draining prefill work, and D→P the
//! converse. "Flipping" an instance is a constant-time pool move with zero
//! wait and zero restart, which is the paper's core mechanism for
//! real-time PD-ratio adjustment.
//!
//! Invariant (property-tested): every *member* instance is in exactly one
//! pool at all times, and every move follows the Fig. 5 transition
//! diagram. Since PR 3 membership is dynamic: instances join and leave at
//! runtime (`join` / `remove`), slots of departed instances stay in the
//! table as non-members (ids are table indices and are never recycled),
//! and non-members are invisible to every pool query — a lost instance
//! can never be returned by `members_iter` and therefore never receives a
//! placement.

use std::collections::BTreeSet;

use crate::request::InstanceId;

/// Pool membership of one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pool {
    /// Handling prefill requests.
    Prefill,
    /// Handling decode requests.
    Decode,
    /// Scheduled for decode, still draining prefill (P→D).
    PrefillToDecode,
    /// Scheduled for prefill, still draining decode (D→P).
    DecodeToPrefill,
}

impl Pool {
    /// Does this pool currently *accept new prefill* dispatches?
    pub fn prefill_capable(self) -> bool {
        matches!(self, Pool::Prefill | Pool::DecodeToPrefill)
    }

    /// Does this pool currently *accept new decode* dispatches?
    pub fn decode_capable(self) -> bool {
        matches!(self, Pool::Decode | Pool::PrefillToDecode)
    }
}

impl Pool {
    fn idx(self) -> usize {
        match self {
            Pool::Prefill => 0,
            Pool::Decode => 1,
            Pool::PrefillToDecode => 2,
            Pool::DecodeToPrefill => 3,
        }
    }
}

/// Pool bookkeeping over a dynamic instance set. `None` = not a member
/// (never joined, draining/left, or failed).
///
/// # Keyed argmin index (PR 4)
///
/// Each pool carries an ordered index over caller-supplied `u64` keys
/// (predicted prefill delay as total-order bits for P / D→P, running
/// tokens for D / P→D), so `min_prefill_delay` / `min_running_tokens`
/// are an O(log n) first-element read instead of a full member scan.
/// Division of labor: `Pools` owns the *structure* — every membership or
/// pool transition drops the moved slot's key and bumps
/// [`Pools::structure_version`] — while the policy owns the *values*,
/// re-keying slots whose underlying aggregates changed (see
/// `ArrowPolicy::refresh_index`). Ties break toward the lowest id,
/// exactly like the `min_by`-over-`members_iter` scan this replaces.
#[derive(Debug, Clone)]
pub struct Pools {
    membership: Vec<Option<Pool>>,
    flips: u64,
    /// Cached key bits per slot; `None` = not indexed (needs re-keying).
    keys: Vec<Option<u64>>,
    /// `(key_bits, id)` per pool, ascending — argmin is the first entry.
    index: [BTreeSet<(u64, usize)>; 4],
    /// Bumped on every membership/pool transition; policies compare it to
    /// detect that index entries were dropped and a refresh pass is due.
    structure: u64,
}

impl Pools {
    /// Start with the first `n_prefill` instances in Prefill, the rest in
    /// Decode (the static 4P/4D starting point of §7.3).
    pub fn new(n_instances: usize, n_prefill: usize) -> Self {
        assert!(n_instances >= 1);
        assert!(n_prefill <= n_instances);
        Pools {
            membership: (0..n_instances)
                .map(|i| Some(if i < n_prefill { Pool::Prefill } else { Pool::Decode }))
                .collect(),
            flips: 0,
            keys: vec![None; n_instances],
            index: Default::default(),
            structure: 0,
        }
    }

    // ---------------------------------------------- keyed argmin index

    /// See the type-level docs: bumped on every structural change.
    pub fn structure_version(&self) -> u64 {
        self.structure
    }

    /// Drop `id`'s index entry (if any). Must run *before* the slot's
    /// pool changes — the entry lives in the old pool's set.
    fn invalidate_key(&mut self, id: usize) {
        let Some(slot) = self.keys.get_mut(id) else { return };
        if let Some(k) = slot.take() {
            let pool = self.membership[id].expect("keyed slot must be a member");
            let removed = self.index[pool.idx()].remove(&(k, id));
            debug_assert!(removed, "index entry missing for keyed slot {id}");
        }
    }

    /// Record a structural transition of `id`: its key (computed against
    /// the old pool/value) is dropped and the structure version bumps.
    fn structural_change(&mut self, id: usize) {
        self.invalidate_key(id);
        self.structure += 1;
    }

    /// (Re-)key a current member. The caller computed `key_bits` from
    /// the pool's metric (delay bits or running tokens); replacing an
    /// unchanged key is a no-op.
    pub fn set_key(&mut self, id: InstanceId, key_bits: u64) {
        let Some(pool) = self.pool_of(id) else {
            debug_assert!(false, "set_key on non-member {id}");
            return;
        };
        if self.keys.len() <= id.0 {
            self.keys.resize(id.0 + 1, None);
        }
        if self.keys[id.0] == Some(key_bits) {
            return;
        }
        if let Some(old) = self.keys[id.0].take() {
            self.index[pool.idx()].remove(&(old, id.0));
        }
        self.index[pool.idx()].insert((key_bits, id.0));
        self.keys[id.0] = Some(key_bits);
    }

    /// Cached key of a slot, `None` when it needs re-keying.
    pub fn key_of(&self, id: InstanceId) -> Option<u64> {
        self.keys.get(id.0).copied().flatten()
    }

    /// Argmin over `pool` by cached key, ties to the lowest id — O(log n).
    /// Only complete after the policy's refresh pass: every member of the
    /// queried pool must currently hold a key.
    pub fn min_keyed(&self, pool: Pool) -> Option<(InstanceId, u64)> {
        debug_assert!(
            self.index[pool.idx()].len() == self.members_iter(pool).count(),
            "argmin index incomplete for {pool:?} — refresh_index not run?"
        );
        self.index[pool.idx()]
            .iter()
            .next()
            .map(|&(k, i)| (InstanceId(i), k))
    }

    /// Drop every key (e.g. after re-profiling changed what keys mean)
    /// and force the next refresh pass to rebuild the index.
    pub fn reset_keys(&mut self) {
        for ids in 0..self.keys.len() {
            self.invalidate_key(ids);
        }
        self.structure += 1;
    }

    /// Table size (member slots + departed slots). Ids are table indices.
    pub fn len(&self) -> usize {
        self.membership.len()
    }

    pub fn is_empty(&self) -> bool {
        self.membership.is_empty()
    }

    /// Number of instances currently in some pool.
    pub fn member_count(&self) -> usize {
        self.membership.iter().filter(|m| m.is_some()).count()
    }

    /// Is `id` currently a member of any pool?
    pub fn contains(&self, id: InstanceId) -> bool {
        self.membership.get(id.0).is_some_and(|m| m.is_some())
    }

    /// Lowest-index member of any pool — the deterministic last-resort
    /// dispatch target when a whole capability class is missing.
    pub fn any_member(&self) -> Option<InstanceId> {
        self.membership.iter().position(|m| m.is_some()).map(InstanceId)
    }

    /// Pool of `id`, or `None` when the instance is not (or no longer) a
    /// member — callers must treat departed instances as having no
    /// capability at all.
    pub fn pool_of(&self, id: InstanceId) -> Option<Pool> {
        self.membership.get(id.0).copied().flatten()
    }

    /// Admit an instance into `pool`, growing the table if `id` is a new
    /// slot (live-server scale-out appends engines). Rejoining a departed
    /// slot reuses it. Joining an existing member is a no-op (membership
    /// is owned by the substrate; duplicate events must not reshuffle).
    pub fn join(&mut self, id: InstanceId, pool: Pool) {
        if id.0 >= self.membership.len() {
            self.membership.resize(id.0 + 1, None);
            self.keys.resize(id.0 + 1, None);
        }
        if self.membership[id.0].is_none() {
            debug_assert!(self.keys[id.0].is_none(), "non-member held a key");
            self.membership[id.0] = Some(pool);
            self.structure += 1;
        }
    }

    /// Remove an instance from whatever pool holds it (drain or loss).
    /// The slot stays in the table so ids remain stable.
    pub fn remove(&mut self, id: InstanceId) {
        if self.pool_of(id).is_some() {
            self.structural_change(id.0);
            self.membership[id.0] = None;
        }
    }

    pub fn flip_count(&self) -> u64 {
        self.flips
    }

    /// [P, D, P→D, D→P] sizes over current members.
    pub fn sizes(&self) -> [usize; 4] {
        let mut s = [0usize; 4];
        for p in self.membership.iter().flatten() {
            match p {
                Pool::Prefill => s[0] += 1,
                Pool::Decode => s[1] += 1,
                Pool::PrefillToDecode => s[2] += 1,
                Pool::DecodeToPrefill => s[3] += 1,
            }
        }
        s
    }

    /// Instances currently in `pool`.
    ///
    /// Allocates; prefer [`Pools::members_iter`] on scheduler hot paths
    /// (placement decisions run once per request).
    pub fn members(&self, pool: Pool) -> Vec<InstanceId> {
        self.members_iter(pool).collect()
    }

    /// Allocation-free iterator over the instances currently in `pool`.
    /// Non-members are skipped, so departed instances are unreachable
    /// from every placement path.
    pub fn members_iter(&self, pool: Pool) -> impl Iterator<Item = InstanceId> + '_ {
        self.membership
            .iter()
            .enumerate()
            .filter(move |(_, &p)| p == Some(pool))
            .map(|(i, _)| InstanceId(i))
    }

    /// Count of instances that can take decode work (|D| + |P→D|) —
    /// Alg. 3's guard term.
    pub fn decode_capable_count(&self) -> usize {
        self.membership
            .iter()
            .flatten()
            .filter(|p| p.decode_capable())
            .count()
    }

    /// Count of instances that can take prefill work (|P| + |D→P|).
    pub fn prefill_capable_count(&self) -> usize {
        self.membership
            .iter()
            .flatten()
            .filter(|p| p.prefill_capable())
            .count()
    }

    /// Flip an instance toward *prefill* duty. Transition diagram:
    /// D → (P if drained else D→P); P→D → P (cancel a pending flip);
    /// already-prefill pools — and non-members — are no-ops. A flip never
    /// changes membership (conservation is property-tested).
    ///
    /// `has_decode_work`: whether the instance still holds decode tasks.
    pub fn flip_to_prefill(&mut self, id: InstanceId, has_decode_work: bool) {
        let Some(cur) = self.pool_of(id) else { return };
        let new = match cur {
            Pool::Decode => {
                if has_decode_work {
                    Pool::DecodeToPrefill
                } else {
                    Pool::Prefill
                }
            }
            Pool::PrefillToDecode => Pool::Prefill, // cancel pending P→D
            other => other,
        };
        if new != cur {
            self.structural_change(id.0);
            self.membership[id.0] = Some(new);
            self.flips += 1;
        }
    }

    /// Flip an instance toward *decode* duty (mirror of above).
    pub fn flip_to_decode(&mut self, id: InstanceId, has_prefill_work: bool) {
        let Some(cur) = self.pool_of(id) else { return };
        let new = match cur {
            Pool::Prefill => {
                if has_prefill_work {
                    Pool::PrefillToDecode
                } else {
                    Pool::Decode
                }
            }
            Pool::DecodeToPrefill => Pool::Decode, // cancel pending D→P
            other => other,
        };
        if new != cur {
            self.structural_change(id.0);
            self.membership[id.0] = Some(new);
            self.flips += 1;
        }
    }

    /// Drain maintenance (monitor tick): a P→D instance with no prefill
    /// work left settles into Decode; a D→P instance with no decode work
    /// settles into Prefill — the black edges in Fig. 5. Non-members are
    /// no-ops.
    pub fn settle(&mut self, id: InstanceId, has_prefill_work: bool, has_decode_work: bool) {
        let new = match self.pool_of(id) {
            Some(Pool::PrefillToDecode) if !has_prefill_work => Pool::Decode,
            Some(Pool::DecodeToPrefill) if !has_decode_work => Pool::Prefill,
            _ => return,
        };
        self.structural_change(id.0);
        self.membership[id.0] = Some(new);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_split() {
        let p = Pools::new(8, 4);
        assert_eq!(p.sizes(), [4, 4, 0, 0]);
        assert_eq!(p.pool_of(InstanceId(0)), Some(Pool::Prefill));
        assert_eq!(p.pool_of(InstanceId(7)), Some(Pool::Decode));
        assert_eq!(p.member_count(), 8);
    }

    #[test]
    fn flip_decode_to_prefill_drained_goes_direct() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(1), false);
        assert_eq!(p.pool_of(InstanceId(1)), Some(Pool::Prefill));
        assert_eq!(p.flip_count(), 1);
    }

    #[test]
    fn flip_decode_with_work_goes_via_transition_pool() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(1), true);
        assert_eq!(p.pool_of(InstanceId(1)), Some(Pool::DecodeToPrefill));
        // D→P still accepts prefill dispatches.
        assert!(p.pool_of(InstanceId(1)).unwrap().prefill_capable());
        // Settle once decode drains.
        p.settle(InstanceId(1), false, false);
        assert_eq!(p.pool_of(InstanceId(1)), Some(Pool::Prefill));
    }

    #[test]
    fn flip_cancellation() {
        let mut p = Pools::new(2, 1);
        p.flip_to_decode(InstanceId(0), true); // P → P→D
        assert_eq!(p.pool_of(InstanceId(0)), Some(Pool::PrefillToDecode));
        p.flip_to_prefill(InstanceId(0), false); // cancel
        assert_eq!(p.pool_of(InstanceId(0)), Some(Pool::Prefill));
    }

    #[test]
    fn settle_requires_drain() {
        let mut p = Pools::new(2, 1);
        p.flip_to_decode(InstanceId(0), true);
        p.settle(InstanceId(0), true, false); // prefill not drained
        assert_eq!(p.pool_of(InstanceId(0)), Some(Pool::PrefillToDecode));
        p.settle(InstanceId(0), false, true);
        assert_eq!(p.pool_of(InstanceId(0)), Some(Pool::Decode));
    }

    #[test]
    fn remove_hides_instance_from_every_query() {
        let mut p = Pools::new(4, 2);
        p.remove(InstanceId(0));
        assert_eq!(p.pool_of(InstanceId(0)), None);
        assert!(!p.contains(InstanceId(0)));
        assert_eq!(p.member_count(), 3);
        assert_eq!(p.sizes(), [1, 2, 0, 0]);
        assert_eq!(p.prefill_capable_count(), 1);
        assert!(p.members_iter(Pool::Prefill).all(|id| id != InstanceId(0)));
        // Flips and settles on a non-member are no-ops and count nothing.
        p.flip_to_decode(InstanceId(0), false);
        p.settle(InstanceId(0), false, false);
        assert_eq!(p.pool_of(InstanceId(0)), None);
        assert_eq!(p.flip_count(), 0);
        // The table keeps the slot: len is stable, ids never shift.
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn join_rejoins_old_slot_and_grows_for_new_slots() {
        let mut p = Pools::new(2, 1);
        p.remove(InstanceId(1));
        p.join(InstanceId(1), Pool::Prefill); // rejoin reuses the slot
        assert_eq!(p.pool_of(InstanceId(1)), Some(Pool::Prefill));
        assert_eq!(p.len(), 2);
        p.join(InstanceId(4), Pool::Decode); // scale-out appends slots
        assert_eq!(p.len(), 5);
        assert_eq!(p.pool_of(InstanceId(4)), Some(Pool::Decode));
        assert_eq!(p.pool_of(InstanceId(3)), None, "gap slots stay empty");
        assert_eq!(p.member_count(), 3);
        // Joining an existing member never reshuffles it.
        p.join(InstanceId(4), Pool::Prefill);
        assert_eq!(p.pool_of(InstanceId(4)), Some(Pool::Decode));
    }

    #[test]
    fn capability_counts() {
        let mut p = Pools::new(4, 2);
        assert_eq!(p.prefill_capable_count(), 2);
        assert_eq!(p.decode_capable_count(), 2);
        p.flip_to_decode(InstanceId(0), true); // P→D counts as decode-capable
        assert_eq!(p.decode_capable_count(), 3);
        assert_eq!(p.prefill_capable_count(), 1);
    }

    #[test]
    fn idempotent_flips_do_not_count() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(0), false); // already prefill
        assert_eq!(p.flip_count(), 0);
    }

    #[test]
    fn keyed_index_tracks_min_and_ties_to_lowest_id() {
        let mut p = Pools::new(4, 4); // all Prefill
        assert_eq!(p.min_keyed(Pool::Decode), None, "empty pool has no min");
        p.set_key(InstanceId(0), 30);
        p.set_key(InstanceId(1), 10);
        p.set_key(InstanceId(2), 10);
        p.set_key(InstanceId(3), 20);
        assert_eq!(p.min_keyed(Pool::Prefill), Some((InstanceId(1), 10)));
        // Re-keying moves the entry; equal keys tie to the lowest id.
        p.set_key(InstanceId(1), 40);
        assert_eq!(p.min_keyed(Pool::Prefill), Some((InstanceId(2), 10)));
        p.set_key(InstanceId(1), 10);
        assert_eq!(p.min_keyed(Pool::Prefill), Some((InstanceId(1), 10)));
    }

    #[test]
    fn structural_changes_drop_keys_and_bump_version() {
        let mut p = Pools::new(4, 2);
        for i in 0..4 {
            p.set_key(InstanceId(i), i as u64);
        }
        let v0 = p.structure_version();
        // A flip drops only the moved slot's key…
        p.flip_to_decode(InstanceId(0), true); // P -> P→D
        assert!(p.structure_version() > v0);
        assert_eq!(p.key_of(InstanceId(0)), None);
        assert_eq!(p.key_of(InstanceId(1)), Some(1));
        assert_eq!(p.min_keyed(Pool::Prefill), Some((InstanceId(1), 1)));
        // …as do settle, remove and (re)join.
        p.settle(InstanceId(0), false, false); // P→D -> D
        assert_eq!(p.key_of(InstanceId(0)), None);
        p.set_key(InstanceId(0), 7);
        p.remove(InstanceId(0));
        assert_eq!(p.key_of(InstanceId(0)), None);
        p.join(InstanceId(0), Pool::Decode);
        assert_eq!(p.key_of(InstanceId(0)), None);
        // Value updates alone do NOT bump the structure version.
        let v1 = p.structure_version();
        p.set_key(InstanceId(0), 9);
        assert_eq!(p.structure_version(), v1);
        // reset_keys clears everything for a full rebuild.
        p.reset_keys();
        assert!(p.structure_version() > v1);
        for i in 0..4 {
            assert_eq!(p.key_of(InstanceId(i)), None);
        }
    }

    #[test]
    fn join_grows_key_table_with_membership() {
        let mut p = Pools::new(2, 1);
        p.join(InstanceId(5), Pool::Decode); // scale-out appends slots
        p.set_key(InstanceId(1), 8);
        p.set_key(InstanceId(5), 3);
        assert_eq!(p.min_keyed(Pool::Decode), Some((InstanceId(5), 3)));
        assert_eq!(p.key_of(InstanceId(3)), None, "gap slots stay unkeyed");
    }

    #[test]
    fn prop_membership_is_partition_and_transitions_legal() {
        use crate::util::{prop, rng::Rng};
        prop::check_with(41, 128, |rng: &mut Rng| {
            let n = rng.index(8) + 2;
            let mut pools = Pools::new(n, rng.index(n + 1));
            let mut members = n;
            for _ in 0..64 {
                let id = InstanceId(rng.index(n));
                let before = pools.pool_of(id);
                let was_member = before.is_some();
                // Flips/settles (3/5 of ops) interleaved with membership
                // churn (join/remove) so the partition invariant is
                // exercised under elastic membership too.
                match rng.index(5) {
                    0 => pools.flip_to_prefill(id, rng.bool(0.5)),
                    1 => pools.flip_to_decode(id, rng.bool(0.5)),
                    2 => pools.settle(id, rng.bool(0.5), rng.bool(0.5)),
                    3 => {
                        pools.remove(id);
                        if was_member {
                            members -= 1;
                        }
                    }
                    _ => {
                        let pool = if rng.bool(0.5) { Pool::Prefill } else { Pool::Decode };
                        pools.join(id, pool);
                        if !was_member {
                            members += 1;
                        }
                    }
                }
                let after = pools.pool_of(id);
                // Legal transitions only (Fig. 5 diagram + join/leave).
                let legal = match (before, after) {
                    (x, y) if x == y => true,
                    // Flips between pools (member stays a member).
                    (Some(x), Some(y)) => matches!(
                        (x, y),
                        (Pool::Decode, Pool::Prefill)
                            | (Pool::Decode, Pool::DecodeToPrefill)
                            | (Pool::Prefill, Pool::Decode)
                            | (Pool::Prefill, Pool::PrefillToDecode)
                            | (Pool::PrefillToDecode, Pool::Prefill)
                            | (Pool::PrefillToDecode, Pool::Decode)
                            | (Pool::DecodeToPrefill, Pool::Decode)
                            | (Pool::DecodeToPrefill, Pool::Prefill)
                    ),
                    // Leave from any pool; join only into P or D.
                    (Some(_), None) => true,
                    (None, Some(p)) => matches!(p, Pool::Prefill | Pool::Decode),
                };
                crate::prop_assert!(legal, "illegal {before:?} -> {after:?}");
                // Partition: sizes sum to the live member count, table
                // size never shrinks (ids stay stable).
                let s = pools.sizes();
                crate::prop_assert!(
                    s.iter().sum::<usize>() == members,
                    "pool sizes {s:?} don't partition {members} members"
                );
                crate::prop_assert!(
                    pools.member_count() == members,
                    "member_count {} != tracked {members}",
                    pools.member_count()
                );
                crate::prop_assert!(pools.len() == n, "table size changed");
            }
            Ok(())
        });
    }
}
