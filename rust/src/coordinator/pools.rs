//! Elastic instance pools (paper §5.2, Fig. 5 V).
//!
//! Four pools — Prefill, Decode, P→D, D→P — where P→D holds instances
//! scheduled to handle decode but still draining prefill work, and D→P the
//! converse. "Flipping" an instance is a constant-time pool move with zero
//! wait and zero restart, which is the paper's core mechanism for
//! real-time PD-ratio adjustment.
//!
//! Invariant (property-tested): every instance is in exactly one pool at
//! all times, and every move follows the Fig. 5 transition diagram.

use crate::request::InstanceId;

/// Pool membership of one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pool {
    /// Handling prefill requests.
    Prefill,
    /// Handling decode requests.
    Decode,
    /// Scheduled for decode, still draining prefill (P→D).
    PrefillToDecode,
    /// Scheduled for prefill, still draining decode (D→P).
    DecodeToPrefill,
}

impl Pool {
    /// Does this pool currently *accept new prefill* dispatches?
    pub fn prefill_capable(self) -> bool {
        matches!(self, Pool::Prefill | Pool::DecodeToPrefill)
    }

    /// Does this pool currently *accept new decode* dispatches?
    pub fn decode_capable(self) -> bool {
        matches!(self, Pool::Decode | Pool::PrefillToDecode)
    }
}

/// Pool bookkeeping for a fixed instance set.
#[derive(Debug, Clone)]
pub struct Pools {
    membership: Vec<Pool>,
    flips: u64,
}

impl Pools {
    /// Start with the first `n_prefill` instances in Prefill, the rest in
    /// Decode (the static 4P/4D starting point of §7.3).
    pub fn new(n_instances: usize, n_prefill: usize) -> Self {
        assert!(n_instances >= 1);
        assert!(n_prefill <= n_instances);
        Pools {
            membership: (0..n_instances)
                .map(|i| if i < n_prefill { Pool::Prefill } else { Pool::Decode })
                .collect(),
            flips: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.membership.len()
    }

    pub fn is_empty(&self) -> bool {
        self.membership.is_empty()
    }

    pub fn pool_of(&self, id: InstanceId) -> Pool {
        self.membership[id.0]
    }

    pub fn flip_count(&self) -> u64 {
        self.flips
    }

    /// [P, D, P→D, D→P] sizes.
    pub fn sizes(&self) -> [usize; 4] {
        let mut s = [0usize; 4];
        for p in &self.membership {
            match p {
                Pool::Prefill => s[0] += 1,
                Pool::Decode => s[1] += 1,
                Pool::PrefillToDecode => s[2] += 1,
                Pool::DecodeToPrefill => s[3] += 1,
            }
        }
        s
    }

    /// Instances currently in `pool`.
    ///
    /// Allocates; prefer [`Pools::members_iter`] on scheduler hot paths
    /// (placement decisions run once per request).
    pub fn members(&self, pool: Pool) -> Vec<InstanceId> {
        self.members_iter(pool).collect()
    }

    /// Allocation-free iterator over the instances currently in `pool`.
    pub fn members_iter(&self, pool: Pool) -> impl Iterator<Item = InstanceId> + '_ {
        self.membership
            .iter()
            .enumerate()
            .filter(move |(_, &p)| p == pool)
            .map(|(i, _)| InstanceId(i))
    }

    /// Count of instances that can take decode work (|D| + |P→D|) —
    /// Alg. 3's guard term.
    pub fn decode_capable_count(&self) -> usize {
        self.membership
            .iter()
            .filter(|p| p.decode_capable())
            .count()
    }

    /// Count of instances that can take prefill work (|P| + |D→P|).
    pub fn prefill_capable_count(&self) -> usize {
        self.membership
            .iter()
            .filter(|p| p.prefill_capable())
            .count()
    }

    /// Flip an instance toward *prefill* duty. Transition diagram:
    /// D → (P if drained else D→P); P→D → P (cancel a pending flip);
    /// already-prefill pools are no-ops.
    ///
    /// `has_decode_work`: whether the instance still holds decode tasks.
    pub fn flip_to_prefill(&mut self, id: InstanceId, has_decode_work: bool) {
        let m = &mut self.membership[id.0];
        let new = match *m {
            Pool::Decode => {
                if has_decode_work {
                    Pool::DecodeToPrefill
                } else {
                    Pool::Prefill
                }
            }
            Pool::PrefillToDecode => Pool::Prefill, // cancel pending P→D
            other => other,
        };
        if new != *m {
            *m = new;
            self.flips += 1;
        }
    }

    /// Flip an instance toward *decode* duty (mirror of above).
    pub fn flip_to_decode(&mut self, id: InstanceId, has_prefill_work: bool) {
        let m = &mut self.membership[id.0];
        let new = match *m {
            Pool::Prefill => {
                if has_prefill_work {
                    Pool::PrefillToDecode
                } else {
                    Pool::Decode
                }
            }
            Pool::DecodeToPrefill => Pool::Decode, // cancel pending D→P
            other => other,
        };
        if new != *m {
            *m = new;
            self.flips += 1;
        }
    }

    /// Drain maintenance (monitor tick): a P→D instance with no prefill
    /// work left settles into Decode; a D→P instance with no decode work
    /// settles into Prefill — the black edges in Fig. 5.
    pub fn settle(&mut self, id: InstanceId, has_prefill_work: bool, has_decode_work: bool) {
        let m = &mut self.membership[id.0];
        match *m {
            Pool::PrefillToDecode if !has_prefill_work => *m = Pool::Decode,
            Pool::DecodeToPrefill if !has_decode_work => *m = Pool::Prefill,
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_split() {
        let p = Pools::new(8, 4);
        assert_eq!(p.sizes(), [4, 4, 0, 0]);
        assert_eq!(p.pool_of(InstanceId(0)), Pool::Prefill);
        assert_eq!(p.pool_of(InstanceId(7)), Pool::Decode);
    }

    #[test]
    fn flip_decode_to_prefill_drained_goes_direct() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(1), false);
        assert_eq!(p.pool_of(InstanceId(1)), Pool::Prefill);
        assert_eq!(p.flip_count(), 1);
    }

    #[test]
    fn flip_decode_with_work_goes_via_transition_pool() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(1), true);
        assert_eq!(p.pool_of(InstanceId(1)), Pool::DecodeToPrefill);
        // D→P still accepts prefill dispatches.
        assert!(p.pool_of(InstanceId(1)).prefill_capable());
        // Settle once decode drains.
        p.settle(InstanceId(1), false, false);
        assert_eq!(p.pool_of(InstanceId(1)), Pool::Prefill);
    }

    #[test]
    fn flip_cancellation() {
        let mut p = Pools::new(2, 1);
        p.flip_to_decode(InstanceId(0), true); // P → P→D
        assert_eq!(p.pool_of(InstanceId(0)), Pool::PrefillToDecode);
        p.flip_to_prefill(InstanceId(0), false); // cancel
        assert_eq!(p.pool_of(InstanceId(0)), Pool::Prefill);
    }

    #[test]
    fn settle_requires_drain() {
        let mut p = Pools::new(2, 1);
        p.flip_to_decode(InstanceId(0), true);
        p.settle(InstanceId(0), true, false); // prefill not drained
        assert_eq!(p.pool_of(InstanceId(0)), Pool::PrefillToDecode);
        p.settle(InstanceId(0), false, true);
        assert_eq!(p.pool_of(InstanceId(0)), Pool::Decode);
    }

    #[test]
    fn capability_counts() {
        let mut p = Pools::new(4, 2);
        assert_eq!(p.prefill_capable_count(), 2);
        assert_eq!(p.decode_capable_count(), 2);
        p.flip_to_decode(InstanceId(0), true); // P→D counts as decode-capable
        assert_eq!(p.decode_capable_count(), 3);
        assert_eq!(p.prefill_capable_count(), 1);
    }

    #[test]
    fn idempotent_flips_do_not_count() {
        let mut p = Pools::new(2, 1);
        p.flip_to_prefill(InstanceId(0), false); // already prefill
        assert_eq!(p.flip_count(), 0);
    }

    #[test]
    fn prop_membership_is_partition_and_transitions_legal() {
        use crate::util::{prop, rng::Rng};
        prop::check_with(41, 128, |rng: &mut Rng| {
            let n = rng.index(8) + 2;
            let mut pools = Pools::new(n, rng.index(n + 1));
            for _ in 0..64 {
                let id = InstanceId(rng.index(n));
                let before = pools.pool_of(id);
                match rng.index(3) {
                    0 => pools.flip_to_prefill(id, rng.bool(0.5)),
                    1 => pools.flip_to_decode(id, rng.bool(0.5)),
                    _ => pools.settle(id, rng.bool(0.5), rng.bool(0.5)),
                }
                let after = pools.pool_of(id);
                // Legal transitions only (Fig. 5 diagram).
                let legal = matches!(
                    (before, after),
                    (x, y) if x == y
                ) || matches!(
                    (before, after),
                    (Pool::Decode, Pool::Prefill)
                        | (Pool::Decode, Pool::DecodeToPrefill)
                        | (Pool::Prefill, Pool::Decode)
                        | (Pool::Prefill, Pool::PrefillToDecode)
                        | (Pool::PrefillToDecode, Pool::Prefill)
                        | (Pool::PrefillToDecode, Pool::Decode)
                        | (Pool::DecodeToPrefill, Pool::Decode)
                        | (Pool::DecodeToPrefill, Pool::Prefill)
                );
                crate::prop_assert!(legal, "illegal {before:?} -> {after:?}");
                // Partition: sizes sum to n.
                let s = pools.sizes();
                crate::prop_assert!(
                    s.iter().sum::<usize>() == n,
                    "pool sizes {s:?} don't partition {n}"
                );
            }
            Ok(())
        });
    }
}
