//! TTFT predictor (paper Fig. 5 I, §5.3).
//!
//! At cluster launch the predictor "profiles each instance's prefill
//! processing capability … and fits a quadratic curve to model the
//! relationship between TTFT and input length". The global scheduler then
//! predicts, for any queued/incoming request, how long its prefill will
//! take on that instance — Insight 1's strong predictability of TTFT.
//!
//! The predictor deliberately *does not* read the simulator's cost model
//! at query time: it knows only its fitted coefficients plus the public
//! queue view, exactly like the real system's profiler.

use crate::costmodel::CostModel;
use crate::sched::{ClusterView, PrefillQueueMoments};
use crate::util::stats;

/// Input lengths sampled during startup profiling.
const PROFILE_LENGTHS: [u32; 6] = [128, 512, 2048, 8192, 32_768, 100_000];

/// Quadratic TTFT model for one instance type.
#[derive(Debug, Clone)]
pub struct TtftPredictor {
    /// prefill_seconds(len) ≈ c[0] + c[1]·len + c[2]·len².
    c: [f64; 3],
    /// Chunk size assumed for per-chunk overhead accounting.
    chunk: u32,
    /// Per-iteration overhead learned from profiling (c[0] proxy).
    overhead: f64,
}

impl TtftPredictor {
    /// Startup profiling: measure whole-prompt prefill latency at several
    /// lengths on the given instance hardware (simulated by querying its
    /// cost model — the stand-in for running real probe prompts).
    pub fn profile(cost: &CostModel, chunk: u32) -> TtftPredictor {
        let xs: Vec<f64> = PROFILE_LENGTHS.iter().map(|&l| l as f64).collect();
        let ys: Vec<f64> = PROFILE_LENGTHS
            .iter()
            .map(|&l| {
                let chunks = l.div_ceil(chunk) as f64;
                cost.prefill_time(l) + (chunks - 1.0).max(0.0) * cost.iter_overhead
            })
            .collect();
        let c = stats::quadratic_fit(&xs, &ys);
        TtftPredictor {
            c,
            chunk,
            overhead: cost.iter_overhead,
        }
    }

    /// Construct directly from coefficients (tests / real-mode loading).
    pub fn from_coefficients(c: [f64; 3], chunk: u32, overhead: f64) -> Self {
        TtftPredictor { c, chunk, overhead }
    }

    pub fn coefficients(&self) -> [f64; 3] {
        self.c
    }

    /// Chunk size this predictor prices per-iteration overhead with. A
    /// view's [`PrefillQueueMoments::sum_chunks`] must be computed with
    /// the same chunk for the O(1) path to agree with the walk.
    pub fn chunk_tokens(&self) -> u32 {
        self.chunk
    }

    /// Per-iteration overhead (seconds) this predictor prices chunks at.
    pub fn overhead_s(&self) -> f64 {
        self.overhead
    }

    /// Predicted seconds to prefill a fresh `len`-token prompt.
    /// `clamp`, not `max(0.0)`: a NaN-poisoned fit must predict NaN
    /// (which placement orders last via `total_cmp`), never a
    /// too-good-to-be-true 0 seconds.
    pub fn prefill_seconds(&self, len: u32) -> f64 {
        let l = len as f64;
        (self.c[0] + self.c[1] * l + self.c[2] * l * l).clamp(0.0, f64::INFINITY)
    }

    /// Unclamped marginal cost of finishing a partially prefilled prompt.
    /// Queue-delay aggregation sums these *raw* values and clamps the
    /// total — the same convention as [`TtftPredictor::queue_delay_moments`],
    /// which cannot clamp per task (it only ever sees the aggregates). A
    /// fitted curve with a negative linear term used to diverge here: the
    /// walk clamped each task to 0 while the moments path let negative
    /// terms cancel, tripping the refresh-index debug oracle (PR 8 fix).
    fn remaining_seconds_raw(&self, input_len: u32, remaining: u32) -> f64 {
        let l = input_len as f64;
        let done = (input_len - remaining) as f64;
        let lin = self.c[1] * remaining as f64;
        let quad = self.c[2] * (l * l - done * done);
        let chunks = remaining.div_ceil(self.chunk.max(1)) as f64;
        lin + quad + chunks * self.overhead
    }

    /// Predicted seconds to *finish* a partially prefilled prompt
    /// (`remaining` of `input_len` tokens left). Uses the quadratic's
    /// marginal cost over the remaining context range.
    pub fn remaining_seconds(&self, input_len: u32, remaining: u32) -> f64 {
        // clamp (not max): NaN coefficients propagate, see prefill_seconds.
        self.remaining_seconds_raw(input_len, remaining)
            .clamp(0.0, f64::INFINITY)
    }

    /// Predicted prefill queueing delay of an instance, given its public
    /// queue view `[(input_len, remaining); ..]` (Insight 1: queue state
    /// fully determines the new request's TTFT).
    pub fn queue_delay(&self, queue: &[(u32, u32)]) -> f64 {
        self.queue_delay_iter(queue.iter().copied())
    }

    /// Allocation-free [`TtftPredictor::queue_delay`]: consumes any
    /// `(input_len, remaining)` stream (e.g.
    /// [`crate::engine::SimInstance::prefill_queue_iter`]) so the
    /// per-request placement path never materializes a queue-view `Vec`.
    pub fn queue_delay_iter(&self, queue: impl Iterator<Item = (u32, u32)>) -> f64 {
        // Sum raw per-task costs, clamp the *total* — one clamp
        // convention shared with `queue_delay_moments`, so the walk is a
        // valid oracle for the O(1) path even when a fitted curve has a
        // negative linear term. An empty queue sums to exactly 0.0.
        queue
            .map(|(l, r)| self.remaining_seconds_raw(l, r))
            .sum::<f64>()
            .clamp(0.0, f64::INFINITY)
    }

    /// Predicted prefill queueing delay of instance `inst` as seen
    /// through a substrate-agnostic [`ClusterView`] snapshot. Visits the
    /// queue in place (internal iteration) and accumulates in the same
    /// order as [`TtftPredictor::queue_delay_iter`], so simulator and
    /// live-server predictions over equal queues are byte-identical.
    pub fn queue_delay_view(&self, view: &dyn ClusterView, inst: usize) -> f64 {
        let mut total = 0.0;
        view.for_each_queued_prefill(inst, &mut |l, r| {
            total += self.remaining_seconds_raw(l, r)
        });
        total.clamp(0.0, f64::INFINITY)
    }

    /// O(1) queue delay from incrementally maintained aggregates (PR 4
    /// tentpole) — the hot-path replacement for the per-member queue walk
    /// of [`TtftPredictor::queue_delay_view`]:
    ///
    /// ```text
    /// Σ remaining_seconds(len, rem)
    ///   = c1·Σrem + c2·Σ(len² − done²) + overhead·Σ⌈rem/chunk⌉
    /// ```
    ///
    /// Because the moments are exact integers, the result is a
    /// deterministic function of queue *content* (independent of update
    /// history and of substrate), which is what keeps cross-substrate
    /// placements byte-identical. It differs from the walk only in f64
    /// summation order (≤ ~1e-12 relative; property-tested at 1e-9) —
    /// both paths clamp the *total*, never individual tasks (PR 8), so
    /// the walk is a valid oracle even for fits with negative
    /// coefficients. NaN coefficients yield NaN
    /// (never a free 0 s) exactly like the walk, and an empty queue is
    /// 0 s even under a NaN-poisoned fit.
    pub fn queue_delay_moments(&self, m: &PrefillQueueMoments) -> f64 {
        if m.count == 0 {
            return 0.0;
        }
        (self.c[1] * m.sum_remaining as f64
            + self.c[2] * m.sum_sq_span as f64
            + m.sum_chunks as f64 * self.overhead)
            .clamp(0.0, f64::INFINITY)
    }

    /// Predicted TTFT if a request of `len` tokens is appended to the
    /// queue now (paper Eq. 1 with q1 = queue_delay).
    pub fn predict_ttft(&self, len: u32, queue: &[(u32, u32)]) -> f64 {
        self.queue_delay(queue) + self.prefill_seconds(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn predictor() -> (TtftPredictor, CostModel) {
        let cost = CostModel::h800_llama8b();
        (TtftPredictor::profile(&cost, 2048), cost)
    }

    #[test]
    fn fit_matches_ground_truth_within_tolerance() {
        let (p, cost) = predictor();
        for len in [256u32, 1024, 4096, 16_384, 65_536] {
            let chunks = len.div_ceil(2048) as f64;
            let truth = cost.prefill_time(len) + (chunks - 1.0).max(0.0) * cost.iter_overhead;
            let pred = p.prefill_seconds(len);
            let rel = (pred - truth).abs() / truth;
            assert!(rel < 0.25, "len={len} truth={truth} pred={pred}");
        }
    }

    #[test]
    fn prediction_monotone_in_length() {
        let (p, _) = predictor();
        let mut prev = 0.0;
        for len in [100u32, 1000, 10_000, 100_000] {
            let t = p.prefill_seconds(len);
            assert!(t > prev, "len={len}");
            prev = t;
        }
    }

    #[test]
    fn remaining_less_than_full() {
        let (p, _) = predictor();
        let full = p.remaining_seconds(10_000, 10_000);
        let half = p.remaining_seconds(10_000, 5_000);
        assert!(half < full);
        // Second half costs more than first half (quadratic context).
        let first_half = full - half;
        assert!(half > first_half, "half={half} first={first_half}");
    }

    #[test]
    fn queue_delay_additive() {
        let (p, _) = predictor();
        let q1 = p.queue_delay(&[(4096, 4096)]);
        let q2 = p.queue_delay(&[(4096, 4096), (4096, 4096)]);
        assert!((q2 - 2.0 * q1).abs() < 1e-9);
        assert_eq!(p.queue_delay(&[]), 0.0);
    }

    #[test]
    fn predict_ttft_includes_own_time() {
        let (p, _) = predictor();
        let empty = p.predict_ttft(2048, &[]);
        assert!((empty - p.prefill_seconds(2048)).abs() < 1e-12);
        let queued = p.predict_ttft(2048, &[(8192, 8192)]);
        assert!(queued > empty);
    }

    #[test]
    fn nan_coefficients_predict_nan_not_zero() {
        // A NaN-poisoned fit (see stats::quadratic_fit) must surface as
        // NaN predictions — total_cmp orders them after every finite
        // delay, steering placement away from the broken instance — and
        // never as a "free" 0-second prediction.
        let broken = TtftPredictor::from_coefficients([f64::NAN; 3], 2048, 0.001);
        assert!(broken.prefill_seconds(1000).is_nan());
        assert!(broken.remaining_seconds(1000, 500).is_nan());
        let healthy = TtftPredictor::from_coefficients([0.0, 1e-4, 0.0], 2048, 0.001);
        let delays = [broken.prefill_seconds(1000), healthy.prefill_seconds(1000)];
        let best = delays
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(best, 1, "NaN must lose the argmin to any finite delay");
    }

    #[test]
    fn queue_delay_view_matches_iter_bit_for_bit() {
        use crate::engine::SimInstance;
        use crate::request::{InstanceId, RequestId};
        let (p, cost) = predictor();
        let mut inst = SimInstance::new(InstanceId(0), cost);
        inst.enqueue_prefill(RequestId(1), 4096);
        inst.enqueue_prefill(RequestId(2), 512);
        inst.enqueue_prefill(RequestId(3), 30_000);
        let insts = vec![inst];
        let via_iter = p.queue_delay_iter(insts[0].prefill_queue_iter());
        let via_view = p.queue_delay_view(&crate::sim::SimView(&insts), 0);
        // Same visit order + same accumulation order => identical bits.
        assert_eq!(via_iter.to_bits(), via_view.to_bits());
    }

    #[test]
    fn remaining_zero_is_zero() {
        let (p, _) = predictor();
        assert_eq!(p.remaining_seconds(5000, 0), 0.0);
    }

    #[test]
    fn moments_match_walk_within_tolerance() {
        let (p, _) = predictor();
        let queue = [(4096u32, 4096u32), (512, 512), (30_000, 30_000), (9_000, 3_500)];
        let walk = p.queue_delay_iter(queue.iter().copied());
        let mut m = PrefillQueueMoments::default();
        for &(l, r) in &queue {
            m.add_task(l, r, p.chunk_tokens());
        }
        let fast = p.queue_delay_moments(&m);
        let rel = (fast - walk).abs() / walk.max(1e-12);
        assert!(rel < 1e-9, "walk={walk} moments={fast} rel={rel}");
    }

    #[test]
    fn moments_empty_queue_is_zero_even_with_nan_fit() {
        let broken = TtftPredictor::from_coefficients([f64::NAN; 3], 2048, 0.001);
        assert_eq!(broken.queue_delay_moments(&PrefillQueueMoments::default()), 0.0);
        let mut m = PrefillQueueMoments::default();
        m.add_task(1000, 1000, 2048);
        assert!(
            broken.queue_delay_moments(&m).is_nan(),
            "a poisoned fit must price a non-empty queue as NaN"
        );
    }

    #[test]
    fn negative_linear_coefficient_walk_matches_moments() {
        // PR 8 regression: least-squares on noisy probe timings can fit a
        // (slightly) negative linear term with a positive quadratic. The
        // old per-task clamp zeroed short tasks' negative contributions
        // in the walk while the O(1) moments path let them cancel inside
        // the aggregate — walk > moments beyond the 1e-9 property band,
        // tripping the refresh-index debug oracle. Both paths now clamp
        // only the total.
        let p = TtftPredictor::from_coefficients([0.0, -1e-5, 1e-9], 2048, 1e-4);
        // Short tasks price negative raw; the long one positive.
        let queue = [(64u32, 64u32), (128, 128), (50_000, 50_000), (256, 96)];
        // Sanity: the per-task clamp genuinely differs on this queue.
        let clamped_sum: f64 = queue.iter().map(|&(l, r)| p.remaining_seconds(l, r)).sum();
        let walk = p.queue_delay_iter(queue.iter().copied());
        assert!(
            clamped_sum > walk + 1e-6,
            "queue must exercise the divergent regime: clamped={clamped_sum} walk={walk}"
        );
        let mut m = PrefillQueueMoments::default();
        for &(l, r) in &queue {
            m.add_task(l, r, p.chunk_tokens());
        }
        let fast = p.queue_delay_moments(&m);
        let rel = (fast - walk).abs() / walk.abs().max(1e-12);
        assert!(rel < 1e-9, "walk={walk} moments={fast} rel={rel}");
        // A queue whose raw total goes negative clamps to 0 on both paths.
        let shorts = [(64u32, 64u32), (96, 96)];
        let walk_neg = p.queue_delay_iter(shorts.iter().copied());
        let mut mn = PrefillQueueMoments::default();
        for &(l, r) in &shorts {
            mn.add_task(l, r, p.chunk_tokens());
        }
        assert_eq!(walk_neg, 0.0);
        assert_eq!(p.queue_delay_moments(&mn), 0.0);
    }

    #[test]
    fn moments_deterministic_across_substrate_histories() {
        // Two different maintenance histories reaching the same queue
        // content must produce bit-identical predictions — the PR-4
        // cross-substrate contract ("identical moment updates").
        let (p, _) = predictor();
        let chunk = p.chunk_tokens();
        // History A: enqueue three, head advances twice.
        let mut a = PrefillQueueMoments::default();
        a.add_task(6000, 6000, chunk);
        a.add_task(800, 800, chunk);
        a.add_task(10_000, 10_000, chunk);
        a.advance_head(6000, 6000, 3952, chunk);
        a.advance_head(6000, 3952, 1904, chunk);
        // History B: mirror rebuilt from the public (len, remaining) view.
        let mut b = PrefillQueueMoments::default();
        for (l, r) in [(6000u32, 1904u32), (800, 800), (10_000, 10_000)] {
            b.add_task(l, r, chunk);
        }
        assert_eq!(a, b);
        assert_eq!(
            p.queue_delay_moments(&a).to_bits(),
            p.queue_delay_moments(&b).to_bits()
        );
    }
}
