//! The Arrow coordinator (paper §5): TTFT predictor, elastic instance
//! pools, and the SLO-aware global scheduling policy.
//!
//! `ArrowPolicy` implements the substrate-agnostic
//! [`crate::sched::Policy`] trait: it reads cluster load only through
//! [`crate::sched::ClusterView`], so the identical object schedules the
//! discrete-event simulator and the live PJRT server.

pub mod arrow;
pub mod pools;
pub mod predictor;

pub use arrow::{ArrowConfig, ArrowPolicy};
pub use pools::{Pool, Pools};
pub use predictor::TtftPredictor;
