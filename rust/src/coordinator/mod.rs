//! The Arrow coordinator (paper §5): TTFT predictor, elastic instance
//! pools, and the SLO-aware global scheduling policy.

pub mod arrow;
pub mod pools;
pub mod predictor;

pub use arrow::{ArrowConfig, ArrowPolicy};
pub use pools::{Pool, Pools};
pub use predictor::TtftPredictor;
