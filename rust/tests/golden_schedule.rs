//! Golden-schedule regression (PR 3 satellite).
//!
//! The complete placement schedule — `(req, prefill instance, decode
//! instance, every token timestamp)` — of the clipped azure_code trace is
//! hashed into one digest per system. The digest must be:
//!
//! * **byte-stable across runs** in the same build (determinism),
//! * **identical between the calendar-cursor loop and the pre-pushed
//!   heap reference** (`Cluster::run_reference`), membership events
//!   included — the PR-1 equivalence contract extended to PR 3, and
//! * **stable across commits**, via the recorded golden file
//!   `tests/golden/schedule_digests.json`. The file is written on first
//!   run (or under `ARROW_BLESS=1`) and enforced afterwards, so an
//!   unintended scheduling change fails loudly in CI.
//!
//! PR 5 adds `*@normalized` entries: the same workload digested under
//! `CostModel::normalized()` for all six systems, so placement drift on
//! the paper-claims conformance path is caught by the same golden gate.
//! PR 10 extends the normalized set with the two scheduling adversaries
//! (`deflect`, `unified`) the claims sweep now also measures.

use arrow::costmodel::CostModel;
use arrow::json::Json;
use arrow::scenarios::{build, decode_node_failure, spike_scale_out, System};
use arrow::sim::SimResult;
use arrow::trace::{catalog, Trace};

/// FNV-1a over the full schedule, bit-exact (token times hashed as f64
/// bits, so even a 1-ulp drift is caught).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }
    fn eat(&mut self, x: u64) {
        self.0 ^= x;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }
}

fn digest(res: &SimResult) -> u64 {
    let mut h = Fnv::new();
    for rec in &res.records {
        h.eat(rec.id.0);
        h.eat(rec.prefill_instance.map_or(u64::MAX, |i| i.0 as u64));
        h.eat(rec.decode_instance.map_or(u64::MAX, |i| i.0 as u64));
        h.eat(rec.token_times.len() as u64);
        for &t in &rec.token_times {
            h.eat(t.to_bits());
        }
    }
    h.eat(res.events_processed);
    h.eat(res.total_iterations);
    h.eat(res.total_flips);
    h.0
}

fn workload() -> (Trace, f64, f64) {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(3).clip_seconds(60.0);
    let t = trace.with_rate(trace.rate() * 4.0);
    (t, w.ttft_slo, w.tpot_slo)
}

#[test]
fn schedule_digests_stable_across_runs_modes_and_commits() {
    let (trace, ttft, tpot) = workload();
    let base = CostModel::h800_llama8b();
    let d = trace.duration();

    // Each case: run twice (in-build stability), then against the heap
    // reference (cursor/heap equivalence) — Arrow + both §7.3 baseline
    // arms, plus the elastic scenarios so membership events are
    // digest-covered too.
    let mut entries: Vec<(&'static str, String)> = Vec::new();
    let mut check = |label: &'static str, mk: &dyn Fn() -> arrow::sim::Cluster| {
        let a = digest(&mk().run(&trace));
        let b = digest(&mk().run(&trace));
        assert_eq!(a, b, "{label}: schedule digest not byte-stable across runs");
        let r = digest(&mk().run_reference(&trace));
        assert_eq!(
            a, r,
            "{label}: cursor and heap-reference schedules diverge (membership \
             events must sequence identically in both modes)"
        );
        entries.push((label, format!("{a:016x}")));
    };
    check("arrow", &|| build(System::Arrow, 8, &base, ttft, tpot, false));
    check("minimal-load", &|| {
        build(System::MinimalLoad, 8, &base, ttft, tpot, false)
    });
    check("round-robin", &|| {
        build(System::RoundRobin, 8, &base, ttft, tpot, false)
    });
    check("arrow+decode-failure", &|| {
        decode_node_failure(8, 1, &base, ttft, tpot, 0.5 * d)
    });
    check("arrow+spike-scale-out", &|| {
        spike_scale_out(6, 2, &base, ttft, tpot, 0.25 * d)
    });

    // Claims-path coverage (PR 5): the paper-claims tier runs every
    // system under `CostModel::normalized()`, so placement drift on the
    // normalized path must fail CI exactly like drift on the calibrated
    // path — all eight systems are digested (the claims sweep exercises
    // all eight since PR 10).
    let norm = CostModel::normalized();
    check("arrow@normalized", &|| {
        build(System::Arrow, 8, &norm, ttft, tpot, false)
    });
    check("vllm@normalized", &|| {
        build(System::VllmColocated, 8, &norm, ttft, tpot, false)
    });
    check("vllm-disagg@normalized", &|| {
        build(System::VllmDisaggregated, 8, &norm, ttft, tpot, false)
    });
    check("distserve@normalized", &|| {
        build(System::DistServe, 8, &norm, ttft, tpot, false)
    });
    check("minimal-load@normalized", &|| {
        build(System::MinimalLoad, 8, &norm, ttft, tpot, false)
    });
    check("round-robin@normalized", &|| {
        build(System::RoundRobin, 8, &norm, ttft, tpot, false)
    });
    // PR 10: the scheduling adversaries get their own stable digests —
    // the deflection trigger and the cut controller are placement paths
    // like any other, so drift there must fail CI identically.
    check("deflect@normalized", &|| {
        build(System::Deflect, 8, &norm, ttft, tpot, false)
    });
    check("unified@normalized", &|| {
        build(System::Unified, 8, &norm, ttft, tpot, false)
    });

    // Cross-commit regression: enforce (or record) the golden file.
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/golden/schedule_digests.json"
    );
    let bless = std::env::var("ARROW_BLESS").map_or(false, |v| v != "0" && !v.is_empty());
    match std::fs::read_to_string(path) {
        Ok(text) if !bless => {
            let g = Json::parse(&text).expect("golden digest file parses");
            for (label, hex) in &entries {
                assert_eq!(
                    g.get(label).as_str(),
                    Some(hex.as_str()),
                    "{label}: schedule digest drifted from the recorded golden. \
                     If the scheduling change is intentional, re-record with \
                     ARROW_BLESS=1 cargo test --test golden_schedule"
                );
            }
        }
        _ => {
            let body = Json::obj(
                entries
                    .iter()
                    .map(|(l, h)| (*l, Json::Str(h.clone())))
                    .collect(),
            );
            std::fs::create_dir_all(std::path::Path::new(path).parent().unwrap()).ok();
            std::fs::write(path, body.encode()).expect("record golden digests");
            eprintln!("recorded golden schedule digests -> {path}");
        }
    }
}
