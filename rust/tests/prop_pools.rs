//! Membership conformance property test (PR 3 satellite).
//!
//! Across randomized interleavings of placements, monitor ticks, engine
//! progress, and membership churn (join / drain / failure), the Arrow
//! policy must maintain:
//!
//! 1. **Live partition** — every live (Active) instance is in exactly
//!    one pool: the pool sizes sum to the live count after every op.
//! 2. **Flip conservation** — flips move instances *between* pools,
//!    never in or out of membership.
//! 3. **No dead placements** — a lost or draining instance never
//!    receives a prefill or decode placement.
//!
//! The whole sequence runs in lockstep through BOTH adapters — the
//! simulator's `SimView` (borrow of the instance table) and a scripted
//! `server::view::ServerView` (materialized snapshots, exactly what the
//! live coordinator builds) — and every placement, pool state, and flip
//! count must agree bit-for-bit, extending the PR-2 cross-substrate
//! contract to elastic membership.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::prop_assert;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::sched::{Liveness, MembershipEvent, Policy};
// Shared conformance materializers (see server::view): one definition of
// "the identical snapshot" for every cross-substrate test.
use arrow::server::view::{
    mirror_sim_instances as snapshot, profile_sim_instances as fixed_profile,
};
use arrow::sim::SimView;
use arrow::util::{prop, rng::Rng};

fn pick(rng: &mut Rng, insts: &[SimInstance], want: Liveness) -> Option<usize> {
    let c: Vec<usize> = insts
        .iter()
        .enumerate()
        .filter(|(_, i)| i.life == want)
        .map(|(i, _)| i)
        .collect();
    if c.is_empty() {
        None
    } else {
        Some(c[rng.index(c.len())])
    }
}

#[test]
fn prop_live_partition_flip_conservation_no_dead_placements() {
    prop::check_with(97, 48, |rng: &mut Rng| {
        let n = rng.index(5) + 3; // 3..=7 instances
        let mut insts: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect();
        let mut sim_p = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
        let mut srv_p = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
        sim_p.init(&SimView(&insts));
        srv_p.init(&SimView(&insts));
        let profile = fixed_profile(&insts, 0.1);
        // Number of Active (= pool-member) instances we expect.
        let mut live = n;

        for step in 0..80u64 {
            let now = step as f64;
            match rng.index(6) {
                0 | 1 => {
                    // Prefill placement (Alg. 1, may flip via Alg. 3).
                    let r =
                        Request::new(step, now, rng.int_range(100, 60_000) as u32, 16);
                    let snap = snapshot(&insts);
                    let a = sim_p.place_prefill(now, &r, &SimView(&insts));
                    let b = srv_p.place_prefill(now, &r, &snap);
                    prop_assert!(a == b, "step {step}: prefill diverged {a} vs {b}");
                    prop_assert!(
                        insts[a.0].life.placeable(),
                        "step {step}: prefill placed on departed {a}"
                    );
                    insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
                }
                2 => {
                    // Decode placement (Alg. 2, may flip via Alg. 4). The
                    // substrate only asks on behalf of an in-cluster
                    // prefill instance (Active, or Draining finishing
                    // its last prefills).
                    let from = pick(rng, &insts, Liveness::Active)
                        .or_else(|| pick(rng, &insts, Liveness::Draining));
                    if let Some(from) = from {
                        let r = Request::new(
                            step,
                            now,
                            rng.int_range(100, 20_000) as u32,
                            16,
                        );
                        let snap = snapshot(&insts);
                        let a = sim_p.place_decode(
                            now,
                            &r,
                            InstanceId(from),
                            &SimView(&insts),
                        );
                        let b = srv_p.place_decode(now, &r, InstanceId(from), &snap);
                        prop_assert!(a == b, "step {step}: decode diverged {a} vs {b}");
                        prop_assert!(
                            insts[a.0].life.placeable(),
                            "step {step}: decode placed on departed {a}"
                        );
                        if a.0 != from && insts[a.0].try_reserve_kv(r.input_len as u64) {
                            insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
                        }
                    }
                }
                3 => {
                    // Engine progress + monitor tick (settling, TPOT
                    // flips, harvesting).
                    for i in 0..n {
                        if !insts[i].life.in_cluster() {
                            continue;
                        }
                        if let Some(plan) = insts[i].plan_iteration() {
                            let t = now + 0.01 * (i + 1) as f64;
                            insts[i].finish_iteration(&plan, t);
                        }
                    }
                    let snap = snapshot(&insts);
                    sim_p.on_tick(now, &SimView(&insts));
                    srv_p.on_tick(now, &snap);
                }
                4 => {
                    // Drain or fail an Active instance — but never below
                    // two members (a real deployment keeps quorum; the
                    // degenerate 1-member cluster is covered by unit
                    // tests).
                    if live > 2 {
                        if let Some(i) = pick(rng, &insts, Liveness::Active) {
                            let id = InstanceId(i);
                            let ev = if rng.bool(0.5) {
                                insts[i].life = Liveness::Dead;
                                // The substrate re-queues lost work.
                                let mut scrap = Vec::new();
                                insts[i].drain_request_ids(&mut scrap);
                                MembershipEvent::InstanceLost { id }
                            } else {
                                insts[i].life = Liveness::Draining;
                                MembershipEvent::InstanceDraining { id }
                            };
                            let snap = snapshot(&insts);
                            sim_p.on_membership(now, ev, &SimView(&insts), &SimView(&insts));
                            srv_p.on_membership(now, ev, &snap, &profile);
                            live -= 1;
                        }
                    }
                }
                _ => {
                    // Rejoin a dead slot.
                    if let Some(i) = pick(rng, &insts, Liveness::Dead) {
                        insts[i].life = Liveness::Active;
                        let ev = MembershipEvent::InstanceJoined { id: InstanceId(i) };
                        let snap = snapshot(&insts);
                        sim_p.on_membership(now, ev, &SimView(&insts), &SimView(&insts));
                        srv_p.on_membership(now, ev, &snap, &profile);
                        live += 1;
                    }
                }
            }

            // Invariants, after every single operation:
            let sizes = sim_p.pool_sizes().expect("arrow exposes pools");
            prop_assert!(
                sizes.iter().sum::<usize>() == live,
                "step {step}: pools {sizes:?} don't partition {live} live instances"
            );
            prop_assert!(
                sim_p.pool_sizes() == srv_p.pool_sizes(),
                "step {step}: pool states diverged across adapters"
            );
            prop_assert!(
                sim_p.flip_count() == srv_p.flip_count(),
                "step {step}: flip counts diverged across adapters"
            );
        }
        Ok(())
    });
}

/// PR 10: the same randomized membership gauntlet, generic over the new
/// scheduling adversaries. `check_sizes(sizes, live)` encodes each
/// policy's own pool contract; everything else (live partition, adapter
/// bit-identity, no dead placements) is shared.
fn adversary_partition_prop<P, F>(seed: u64, mk: F, check_sizes: fn(&[usize; 4], usize) -> bool)
where
    P: Policy,
    F: Fn(usize) -> P,
{
    prop::check_with(seed, 48, |rng: &mut Rng| {
        let n = rng.index(5) + 3; // 3..=7 instances
        let mut insts: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
            .collect();
        let mut sim_p = mk(n);
        let mut srv_p = mk(n);
        sim_p.init(&SimView(&insts));
        srv_p.init(&SimView(&insts));
        let profile = fixed_profile(&insts, 0.1);
        let mut live = n;

        for step in 0..80u64 {
            let now = step as f64;
            match rng.index(6) {
                0 | 1 => {
                    // Mix small (deflectable) and large prefills so the
                    // deflection interceptor sees both sides of its cap.
                    let input = if rng.bool(0.5) {
                        rng.int_range(100, 2_048) as u32
                    } else {
                        rng.int_range(100, 60_000) as u32
                    };
                    let r = Request::new(step, now, input, 16);
                    let snap = snapshot(&insts);
                    let a = sim_p.place_prefill(now, &r, &SimView(&insts));
                    let b = srv_p.place_prefill(now, &r, &snap);
                    prop_assert!(a == b, "step {step}: prefill diverged {a} vs {b}");
                    prop_assert!(
                        insts[a.0].life.placeable(),
                        "step {step}: prefill placed on departed {a}"
                    );
                    insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
                }
                2 => {
                    let from = pick(rng, &insts, Liveness::Active)
                        .or_else(|| pick(rng, &insts, Liveness::Draining));
                    if let Some(from) = from {
                        let r = Request::new(
                            step,
                            now,
                            rng.int_range(100, 20_000) as u32,
                            16,
                        );
                        let snap = snapshot(&insts);
                        let a = sim_p.place_decode(
                            now,
                            &r,
                            InstanceId(from),
                            &SimView(&insts),
                        );
                        let b = srv_p.place_decode(now, &r, InstanceId(from), &snap);
                        prop_assert!(a == b, "step {step}: decode diverged {a} vs {b}");
                        prop_assert!(
                            insts[a.0].life.placeable(),
                            "step {step}: decode placed on departed {a}"
                        );
                        if a.0 != from && insts[a.0].try_reserve_kv(r.input_len as u64) {
                            insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
                        }
                    }
                }
                3 => {
                    for i in 0..n {
                        if !insts[i].life.in_cluster() {
                            continue;
                        }
                        if let Some(plan) = insts[i].plan_iteration() {
                            let t = now + 0.01 * (i + 1) as f64;
                            insts[i].finish_iteration(&plan, t);
                        }
                    }
                    let snap = snapshot(&insts);
                    sim_p.on_tick(now, &SimView(&insts));
                    srv_p.on_tick(now, &snap);
                }
                4 => {
                    if live > 2 {
                        if let Some(i) = pick(rng, &insts, Liveness::Active) {
                            let id = InstanceId(i);
                            let ev = if rng.bool(0.5) {
                                insts[i].life = Liveness::Dead;
                                let mut scrap = Vec::new();
                                insts[i].drain_request_ids(&mut scrap);
                                MembershipEvent::InstanceLost { id }
                            } else {
                                insts[i].life = Liveness::Draining;
                                MembershipEvent::InstanceDraining { id }
                            };
                            let snap = snapshot(&insts);
                            sim_p.on_membership(now, ev, &SimView(&insts), &SimView(&insts));
                            srv_p.on_membership(now, ev, &snap, &profile);
                            live -= 1;
                        }
                    }
                }
                _ => {
                    if let Some(i) = pick(rng, &insts, Liveness::Dead) {
                        insts[i].life = Liveness::Active;
                        let ev = MembershipEvent::InstanceJoined { id: InstanceId(i) };
                        let snap = snapshot(&insts);
                        sim_p.on_membership(now, ev, &SimView(&insts), &SimView(&insts));
                        srv_p.on_membership(now, ev, &snap, &profile);
                        live += 1;
                    }
                }
            }

            let sizes = sim_p.pool_sizes().expect("adversaries expose pools");
            prop_assert!(
                check_sizes(&sizes, live),
                "step {step}: pools {sizes:?} violate the policy's contract \
                 for {live} live instances"
            );
            prop_assert!(
                sim_p.pool_sizes() == srv_p.pool_sizes(),
                "step {step}: pool states diverged across adapters"
            );
            prop_assert!(
                sim_p.flip_count() == srv_p.flip_count(),
                "step {step}: flip counts diverged across adapters"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_deflect_preserves_live_partition_and_flip_conservation() {
    use arrow::sched::{DeflectConfig, DeflectPolicy};
    // Deflection is a placement-time interception: the Arrow pools
    // underneath must keep partitioning the live set exactly as before.
    adversary_partition_prop(
        911,
        |n| DeflectPolicy::new(DeflectConfig::new(2.0, 0.1, n), n),
        |sizes, live| sizes.iter().sum::<usize>() == live,
    );
}

#[test]
fn prop_unified_keeps_every_instance_in_exactly_one_slot() {
    use arrow::sched::{UnifiedConfig, UnifiedPolicy};
    // Unified has no P/D split: every live instance sits in exactly one
    // pool slot (the first), and nothing ever flips out of it.
    adversary_partition_prop(
        912,
        |n| UnifiedPolicy::new(UnifiedConfig::new(2.0, 0.1), n),
        |sizes, live| *sizes == [live, 0, 0, 0],
    );
}
