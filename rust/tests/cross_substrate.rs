//! Cross-substrate golden test (PR 2 tentpole): the scheduling brain must
//! be substrate-blind. Feeding *identical* cluster snapshot sequences
//! through the simulator adapter (`sim::SimView`, a zero-cost borrow of
//! the `SimInstance` table) and the live-server adapter
//! (`server::view::ServerView`, a materialized per-engine snapshot) must
//! produce byte-identical Arrow placements, pool states, and flip
//! decisions — the property that lets sim-validated policies ship to
//! serving unchanged.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::sched::Policy;
use arrow::server::view::{EngineSnapshot, ServerView};
use arrow::sim::SimView;
use arrow::util::rng::Rng;

/// Materialize the exact state `SimView` exposes into the server's
/// snapshot form — the "identical snapshot" premise of the test.
fn snapshot(insts: &[SimInstance]) -> ServerView {
    ServerView {
        engines: insts
            .iter()
            .map(|i| EngineSnapshot {
                queued_prefills: i.prefill_queue_iter().collect(),
                running_tokens: i.running_tokens(),
                max_kv_tokens: i.cost.max_kv_tokens,
                avg_token_interval: i.avg_token_interval(),
                has_decode_work: i.has_decode_work(),
            })
            .collect(),
    }
}

fn cluster(n: usize) -> Vec<SimInstance> {
    (0..n)
        .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
        .collect()
}

#[test]
fn arrow_decisions_identical_across_adapters() {
    let n = 6;
    let mut insts = cluster(n);
    let mut sim_policy = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
    let mut srv_policy = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
    // Identical starting knowledge: both profile from the same source
    // (the live server would use real probe timings; equality of the
    // *adapters* is what is under test here).
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));

    let mut rng = Rng::new(42);
    for step in 0..200u64 {
        match rng.index(3) {
            0 => {
                // Prefill placement (Alg. 1, may flip via Alg. 3).
                let r = Request::new(step, step as f64, rng.int_range(100, 60_000) as u32, 16);
                let snap = snapshot(&insts);
                let a = sim_policy.place_prefill(step as f64, &r, &SimView(&insts));
                let b = srv_policy.place_prefill(step as f64, &r, &snap);
                assert_eq!(a, b, "step {step}: prefill placement diverged");
                insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
            }
            1 => {
                // Decode placement (Alg. 2, may flip via Alg. 4).
                let r = Request::new(step, step as f64, rng.int_range(100, 20_000) as u32, 16);
                let from = InstanceId(rng.index(n));
                let snap = snapshot(&insts);
                let a = sim_policy.place_decode(step as f64, &r, from, &SimView(&insts));
                let b = srv_policy.place_decode(step as f64, &r, from, &snap);
                assert_eq!(a, b, "step {step}: decode placement diverged");
                if a != from && insts[a.0].try_reserve_kv(r.input_len as u64) {
                    insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
                }
            }
            _ => {
                // Engine progress (evolves queues, KV, and the token-
                // interval windows the TPOT monitor reads), then a tick.
                for i in 0..n {
                    if let Some(plan) = insts[i].plan_iteration() {
                        let now = step as f64 + 0.01 * (i + 1) as f64;
                        insts[i].finish_iteration(&plan, now);
                    }
                }
                let snap = snapshot(&insts);
                sim_policy.on_tick(step as f64, &SimView(&insts));
                srv_policy.on_tick(step as f64, &snap);
            }
        }
        assert_eq!(
            sim_policy.pool_sizes(),
            srv_policy.pool_sizes(),
            "step {step}: pool states diverged"
        );
        assert_eq!(
            sim_policy.flip_count(),
            srv_policy.flip_count(),
            "step {step}: flip decisions diverged"
        );
    }
    // The sequence must actually exercise the interesting machinery.
    assert!(
        sim_policy.flip_count() > 0,
        "golden sequence never flipped an instance — test got weaker"
    );
}

#[test]
fn minimal_load_baseline_identical_across_adapters() {
    use arrow::baselines::{PickRule, StaticDisaggPolicy};
    let n = 4;
    let mut insts = cluster(n);
    let mk = || StaticDisaggPolicy::new("ml", vec![0, 1], vec![2, 3], PickRule::MinimalLoad);
    let mut sim_policy = mk();
    let mut srv_policy = mk();
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));

    let mut rng = Rng::new(7);
    for step in 0..80u64 {
        let r = Request::new(step, step as f64, rng.int_range(100, 30_000) as u32, 8);
        let snap = snapshot(&insts);
        let (a, b) = if step % 2 == 0 {
            (
                sim_policy.place_prefill(step as f64, &r, &SimView(&insts)),
                srv_policy.place_prefill(step as f64, &r, &snap),
            )
        } else {
            let from = InstanceId(rng.index(2));
            (
                sim_policy.place_decode(step as f64, &r, from, &SimView(&insts)),
                srv_policy.place_decode(step as f64, &r, from, &snap),
            )
        };
        assert_eq!(a, b, "step {step}: baseline placement diverged");
        if step % 2 == 0 {
            insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
        } else if insts[a.0].try_reserve_kv(r.input_len as u64) {
            insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
        }
    }
}
