//! Cross-substrate golden test (PR 2 tentpole): the scheduling brain must
//! be substrate-blind. Feeding *identical* cluster snapshot sequences
//! through the simulator adapter (`sim::SimView`, a zero-cost borrow of
//! the `SimInstance` table) and the live-server adapter
//! (`server::view::ServerView`, a materialized per-engine snapshot) must
//! produce byte-identical Arrow placements, pool states, and flip
//! decisions — the property that lets sim-validated policies ship to
//! serving unchanged. Since PR 3 the sequence also churns cluster
//! membership (joins / drains / losses), so the adapters stay
//! bit-for-bit identical through elastic regimes too.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::sched::{Liveness, MembershipEvent, Policy};
// The snapshot/profile materializers live next to `EngineSnapshot`
// itself, so snapshot-shape changes update every conformance test at
// once.
use arrow::server::view::{
    mirror_sim_instances as snapshot, profile_sim_instances as fixed_profile,
};
use arrow::sim::SimView;
use arrow::util::rng::Rng;

fn cluster(n: usize) -> Vec<SimInstance> {
    (0..n)
        .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
        .collect()
}

#[test]
fn arrow_decisions_identical_across_adapters() {
    let n = 6;
    let mut insts = cluster(n);
    let mut sim_policy = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
    let mut srv_policy = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, n), n);
    // Identical starting knowledge: both profile from the same source
    // (the live server would use real probe timings; equality of the
    // *adapters* is what is under test here).
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));
    let profile = fixed_profile(&insts, 0.1);

    let mut rng = Rng::new(42);
    let mut joins = 0u32;
    let mut departures = 0u32;
    for step in 0..240u64 {
        match rng.index(4) {
            0 => {
                // Prefill placement (Alg. 1, may flip via Alg. 3).
                let r = Request::new(step, step as f64, rng.int_range(100, 60_000) as u32, 16);
                let snap = snapshot(&insts);
                let a = sim_policy.place_prefill(step as f64, &r, &SimView(&insts));
                let b = srv_policy.place_prefill(step as f64, &r, &snap);
                assert_eq!(a, b, "step {step}: prefill placement diverged");
                assert!(insts[a.0].life.placeable(), "step {step}: placed on departed");
                insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
            }
            1 => {
                // Decode placement (Alg. 2, may flip via Alg. 4). The
                // prefill side of a decode placement is always an
                // in-cluster instance.
                let live: Vec<usize> = (0..n)
                    .filter(|&i| insts[i].life.in_cluster())
                    .collect();
                let from = InstanceId(live[rng.index(live.len())]);
                let r = Request::new(step, step as f64, rng.int_range(100, 20_000) as u32, 16);
                let snap = snapshot(&insts);
                let a = sim_policy.place_decode(step as f64, &r, from, &SimView(&insts));
                let b = srv_policy.place_decode(step as f64, &r, from, &snap);
                assert_eq!(a, b, "step {step}: decode placement diverged");
                assert!(insts[a.0].life.placeable(), "step {step}: decoded on departed");
                if a != from && insts[a.0].try_reserve_kv(r.input_len as u64) {
                    insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
                }
            }
            2 => {
                // Membership churn (PR 3): drain/lose an instance (never
                // below 3 members) or rejoin a dead slot — mirrored to
                // both adapters, like every other event.
                let dead: Vec<usize> =
                    (0..n).filter(|&i| insts[i].life == Liveness::Dead).collect();
                let active: Vec<usize> = (0..n)
                    .filter(|&i| insts[i].life == Liveness::Active)
                    .collect();
                let ev = if !dead.is_empty() && rng.bool(0.5) {
                    let i = dead[rng.index(dead.len())];
                    insts[i].life = Liveness::Active;
                    joins += 1;
                    Some(MembershipEvent::InstanceJoined { id: InstanceId(i) })
                } else if active.len() > 3 {
                    let i = active[rng.index(active.len())];
                    departures += 1;
                    if rng.bool(0.5) {
                        insts[i].life = Liveness::Dead;
                        // The substrate re-queues what the instance held.
                        let mut scrap = Vec::new();
                        insts[i].drain_request_ids(&mut scrap);
                        Some(MembershipEvent::InstanceLost { id: InstanceId(i) })
                    } else {
                        insts[i].life = Liveness::Draining;
                        Some(MembershipEvent::InstanceDraining { id: InstanceId(i) })
                    }
                } else {
                    None
                };
                if let Some(ev) = ev {
                    let snap = snapshot(&insts);
                    sim_policy.on_membership(
                        step as f64,
                        ev,
                        &SimView(&insts),
                        &SimView(&insts),
                    );
                    srv_policy.on_membership(step as f64, ev, &snap, &profile);
                }
            }
            _ => {
                // Engine progress (evolves queues, KV, and the token-
                // interval windows the TPOT monitor reads), then a tick.
                for i in 0..n {
                    if !insts[i].life.in_cluster() {
                        continue;
                    }
                    if let Some(plan) = insts[i].plan_iteration() {
                        let now = step as f64 + 0.01 * (i + 1) as f64;
                        insts[i].finish_iteration(&plan, now);
                    }
                }
                let snap = snapshot(&insts);
                sim_policy.on_tick(step as f64, &SimView(&insts));
                srv_policy.on_tick(step as f64, &snap);
            }
        }
        assert_eq!(
            sim_policy.pool_sizes(),
            srv_policy.pool_sizes(),
            "step {step}: pool states diverged"
        );
        assert_eq!(
            sim_policy.flip_count(),
            srv_policy.flip_count(),
            "step {step}: flip decisions diverged"
        );
    }
    // The sequence must actually exercise the interesting machinery.
    assert!(
        sim_policy.flip_count() > 0,
        "golden sequence never flipped an instance — test got weaker"
    );
    assert!(
        joins > 0 && departures > 0,
        "golden sequence never churned membership — test got weaker \
         (joins={joins} departures={departures})"
    );
}

/// PR 10: the scheduling adversaries are bound by the same substrate-
/// blindness contract as Arrow. One randomized sequence of placements,
/// ticks, engine progress and membership churn runs in lockstep through
/// `SimView` and the materialized `ServerView`; every placement, pool
/// state and flip count must agree bit-for-bit.
fn adversary_lockstep<P, F>(mk: F, seed: u64, bias_small: bool) -> (P, P)
where
    P: Policy,
    F: Fn() -> P,
{
    let n = 6;
    let mut insts = cluster(n);
    let mut sim_policy = mk();
    let mut srv_policy = mk();
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));
    let profile = fixed_profile(&insts, 0.1);

    let mut rng = Rng::new(seed);
    for step in 0..240u64 {
        match rng.index(4) {
            0 => {
                // Prefill placement. `bias_small` keeps a healthy share of
                // requests under the deflection cap so the intercepted
                // path is actually exercised.
                let input = if bias_small && rng.bool(0.4) {
                    rng.int_range(100, 2_048) as u32
                } else {
                    rng.int_range(100, 60_000) as u32
                };
                let r = Request::new(step, step as f64, input, 16);
                let snap = snapshot(&insts);
                let a = sim_policy.place_prefill(step as f64, &r, &SimView(&insts));
                let b = srv_policy.place_prefill(step as f64, &r, &snap);
                assert_eq!(a, b, "step {step}: prefill placement diverged");
                assert!(insts[a.0].life.placeable(), "step {step}: placed on departed");
                insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
            }
            1 => {
                let live: Vec<usize> = (0..n)
                    .filter(|&i| insts[i].life.in_cluster())
                    .collect();
                let from = InstanceId(live[rng.index(live.len())]);
                let r = Request::new(step, step as f64, rng.int_range(100, 20_000) as u32, 16);
                let snap = snapshot(&insts);
                let a = sim_policy.place_decode(step as f64, &r, from, &SimView(&insts));
                let b = srv_policy.place_decode(step as f64, &r, from, &snap);
                assert_eq!(a, b, "step {step}: decode placement diverged");
                assert!(insts[a.0].life.placeable(), "step {step}: decoded on departed");
                if a != from && insts[a.0].try_reserve_kv(r.input_len as u64) {
                    insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
                }
            }
            2 => {
                let dead: Vec<usize> =
                    (0..n).filter(|&i| insts[i].life == Liveness::Dead).collect();
                let active: Vec<usize> = (0..n)
                    .filter(|&i| insts[i].life == Liveness::Active)
                    .collect();
                let ev = if !dead.is_empty() && rng.bool(0.5) {
                    let i = dead[rng.index(dead.len())];
                    insts[i].life = Liveness::Active;
                    Some(MembershipEvent::InstanceJoined { id: InstanceId(i) })
                } else if active.len() > 3 {
                    let i = active[rng.index(active.len())];
                    if rng.bool(0.5) {
                        insts[i].life = Liveness::Dead;
                        let mut scrap = Vec::new();
                        insts[i].drain_request_ids(&mut scrap);
                        Some(MembershipEvent::InstanceLost { id: InstanceId(i) })
                    } else {
                        insts[i].life = Liveness::Draining;
                        Some(MembershipEvent::InstanceDraining { id: InstanceId(i) })
                    }
                } else {
                    None
                };
                if let Some(ev) = ev {
                    let snap = snapshot(&insts);
                    sim_policy.on_membership(
                        step as f64,
                        ev,
                        &SimView(&insts),
                        &SimView(&insts),
                    );
                    srv_policy.on_membership(step as f64, ev, &snap, &profile);
                }
            }
            _ => {
                for i in 0..n {
                    if !insts[i].life.in_cluster() {
                        continue;
                    }
                    if let Some(plan) = insts[i].plan_iteration() {
                        let now = step as f64 + 0.01 * (i + 1) as f64;
                        insts[i].finish_iteration(&plan, now);
                    }
                }
                let snap = snapshot(&insts);
                sim_policy.on_tick(step as f64, &SimView(&insts));
                srv_policy.on_tick(step as f64, &snap);
            }
        }
        assert_eq!(
            sim_policy.pool_sizes(),
            srv_policy.pool_sizes(),
            "step {step}: pool states diverged"
        );
        assert_eq!(
            sim_policy.flip_count(),
            srv_policy.flip_count(),
            "step {step}: flip decisions diverged"
        );
    }
    (sim_policy, srv_policy)
}

#[test]
fn deflect_decisions_identical_across_adapters() {
    use arrow::sched::{DeflectConfig, DeflectPolicy};
    let (sim_p, srv_p) = adversary_lockstep(
        || DeflectPolicy::new(DeflectConfig::new(2.0, 0.1, 6), 6),
        42,
        true,
    );
    assert_eq!(
        sim_p.deflection_count(),
        srv_p.deflection_count(),
        "deflection decisions diverged across adapters"
    );
    // The sequence must actually reach the pressure machinery one way or
    // the other — a run with neither a deflection nor a flip proves
    // nothing about the intercepted path.
    assert!(
        sim_p.deflection_count() > 0 || sim_p.flip_count() > 0,
        "golden sequence never pressured the prefill pool — test got weaker"
    );
}

#[test]
fn unified_decisions_identical_across_adapters() {
    use arrow::sched::{UnifiedConfig, UnifiedPolicy};
    let (sim_p, srv_p) = adversary_lockstep(
        || UnifiedPolicy::new(UnifiedConfig::new(2.0, 0.1), 6),
        1337,
        false,
    );
    // Unified never flips: the cut point moves instead, and it must move
    // identically over both adapters.
    assert_eq!(sim_p.flip_count(), 0, "unified must never flip an instance");
    assert_eq!(
        sim_p.cut().to_bits(),
        srv_p.cut().to_bits(),
        "cut controllers diverged across adapters"
    );
}

#[test]
fn minimal_load_baseline_identical_across_adapters() {
    use arrow::baselines::{PickRule, StaticDisaggPolicy};
    let n = 4;
    let mut insts = cluster(n);
    let mk = || StaticDisaggPolicy::new("ml", vec![0, 1], vec![2, 3], PickRule::MinimalLoad);
    let mut sim_policy = mk();
    let mut srv_policy = mk();
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));

    let mut rng = Rng::new(7);
    for step in 0..80u64 {
        let r = Request::new(step, step as f64, rng.int_range(100, 30_000) as u32, 8);
        let snap = snapshot(&insts);
        let (a, b) = if step % 2 == 0 {
            (
                sim_policy.place_prefill(step as f64, &r, &SimView(&insts)),
                srv_policy.place_prefill(step as f64, &r, &snap),
            )
        } else {
            let from = InstanceId(rng.index(2));
            (
                sim_policy.place_decode(step as f64, &r, from, &SimView(&insts)),
                srv_policy.place_decode(step as f64, &r, from, &snap),
            )
        };
        assert_eq!(a, b, "step {step}: baseline placement diverged");
        if step % 2 == 0 {
            insts[a.0].enqueue_prefill(RequestId(step), r.input_len);
        } else if insts[a.0].try_reserve_kv(r.input_len as u64) {
            insts[a.0].enqueue_decode(RequestId(step), r.input_len, 8);
        }
    }
}
